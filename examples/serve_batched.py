"""Batched serving: prefill + lockstep decode with top-k sampling.

Top-k runs through the sorting machinery (serve/sampling.py — the paper's
sample/splitter-select pattern over vocab-sharded logits at scale).

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import Model
from repro.serve import ServeConfig, ServeEngine

cfg = get_arch("tinyllama-1.1b").reduced()
model = Model(cfg)
params = model.init(jax.random.key(0))
engine = ServeEngine(model, params, ServeConfig(max_new_tokens=24, top_k=40, temperature=0.9))

prompts = jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab, dtype=jnp.int32)
t0 = time.perf_counter()
out = engine.generate(prompts)
jax.block_until_ready(out)
dt = time.perf_counter() - t0
print(f"generated {out.shape} tokens in {dt:.2f}s "
      f"({out.size / dt:,.0f} tok/s incl. compile)")
t0 = time.perf_counter()
out = engine.generate(prompts, rng=jax.random.key(2))
jax.block_until_ready(out)
dt = time.perf_counter() - t0
print(f"steady-state: {out.size / dt:,.0f} tok/s")
print("sample row:", out[0][:12].tolist())

# admission ordering: a burst of identical-length requests is the sort's
# adversarial one-bucket case — the overflow-safe driver escalates capacity
# tiers instead of dropping request ids.
import numpy as np

queue_lens = np.full(1024, 512, np.int32)
order = engine.admission_order(queue_lens)
print(f"admission order intact: {sorted(order.tolist()) == list(range(1024))}; "
      f"capacity stats: {engine.capacity_stats.as_row()}")
