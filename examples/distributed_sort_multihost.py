"""The same SPMD sort on REAL devices via shard_map (8 simulated here).

This is the exact code path the multi-pod mesh uses; on a TPU pod the mesh
axis spans chips and lax.all_to_all rides the ICI.

    python examples/distributed_sort_multihost.py     # sets its own XLA flag
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import SortConfig, bsp_sort_sharded, gathered_output, datagen

p = 8
mesh = Mesh(np.array(jax.devices()[:p]), ("procs",))
n_per_proc = 1 << 15
x = jnp.asarray(datagen.generate("S", p, n_per_proc, seed=3))  # adversarial staggered

for routing in ("a2a_dense", "ring", "allgather"):
    cfg = SortConfig(p=p, n_per_proc=n_per_proc, algorithm="iran", routing=routing)
    res, _ = bsp_sort_sharded(x, mesh, "procs", cfg)
    ok = np.array_equal(gathered_output(res), np.sort(np.asarray(x).ravel()))
    print(f"routing={routing:10s} sorted={ok} overflow={bool(res.overflow)} "
          f"devices={[d.id for d in jax.devices()[:p]]}")
