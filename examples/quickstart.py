"""Quickstart: distributed BSP sorting in five lines (paper Figs. 1 & 3).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import SortConfig, bsp_sort, gathered_output, datagen, predict, BSPMachine, CRAY_T3D

p, n_per_proc = 16, 1 << 16
x = jnp.asarray(datagen.generate("U", p, n_per_proc, seed=0))

for algo in ("det", "iran"):
    cfg = SortConfig(p=p, n_per_proc=n_per_proc, algorithm=algo)
    result, _ = bsp_sort(x, cfg)
    out = gathered_output(result)
    counts = np.asarray(result.count)
    print(
        f"[{algo}] sorted={np.array_equal(out, np.sort(np.asarray(x).ravel()))} "
        f"max-imbalance={counts.max() / n_per_proc - 1:+.2%} "
        f"(Lemma 5.1 capacity {cfg.n_max} = {cfg.n_max / n_per_proc:.2f}×n/p)"
    )

# the paper's BSP cost model: predicted efficiency on the Cray T3D
L, g = CRAY_T3D[16]
pred = predict(SortConfig(p=16, n_per_proc=n_per_proc, algorithm="det"), BSPMachine(16, L, g))
print(f"[model] predicted T3D efficiency at (n=1M, p=16): {pred.efficiency:.0%} "
      f"(π={pred.pi:.3f}, μ={pred.mu:.3f})")

# duplicate keys are free (§5.1.1): all-equal input, same capacity bound
dup = jnp.zeros((p, n_per_proc), jnp.int32)
res, _ = bsp_sort(dup, SortConfig(p=p, n_per_proc=n_per_proc, algorithm="det"))
print(f"[dups ] all-equal keys: balanced counts = {np.asarray(res.count).tolist()[:4]}…, "
      f"overflow={bool(res.overflow)}")
