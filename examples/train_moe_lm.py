"""End-to-end driver: train a ~130M-param MoE LM with sort-based dispatch.

The paper's technique (stable integer sort + balanced routing) runs inside
every MoE layer's token dispatch; checkpoints + stateless data make the run
crash-recoverable (kill it mid-run and re-invoke with --resume).

    PYTHONPATH=src python examples/train_moe_lm.py --steps 300
    PYTHONPATH=src python examples/train_moe_lm.py --steps 300 --resume
"""
import argparse
import dataclasses

from repro.configs.base import ArchConfig
from repro.launch.train import train
from repro.optim import OptConfig

# ~130M parameters: 8 layers, d=512, 8 experts (top-2), vocab 16k
CFG_100M = ArchConfig(
    name="moe-demo-130m",
    family="moe",
    n_layers=8,
    d_model=512,
    n_heads=8,
    n_kv_heads=4,
    d_ff=1024,
    vocab=16384,
    moe_experts=8,
    moe_top_k=2,
    param_sharding="1d",
    remat=False,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/repro_moe_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    print(f"params ≈ {CFG_100M.param_count()/1e6:.0f}M "
          f"(active {CFG_100M.active_param_count()/1e6:.0f}M)")
    _, _, losses = train(
        CFG_100M,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=50,
        resume=args.resume,
        opt_cfg=OptConfig(lr=6e-4, total_steps=args.steps, warmup_steps=20),
    )
    print(f"first-10 mean loss {sum(losses[:10])/10:.3f} → "
          f"last-10 mean loss {sum(losses[-10:])/10:.3f}")


if __name__ == "__main__":
    main()
