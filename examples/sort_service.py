"""Sort service demo: fuse 64 concurrent ragged sort requests into tagged
segmented BSP sorts (segment ids ride the key's high bits the way §5.1.1's
duplicate tags ride the comparator).

    PYTHONPATH=src python examples/sort_service.py
"""
import time

import numpy as np

from repro.core import datagen
from repro.core.api import SortExecutor
from repro.service import ServiceConfig, SortService

# a production-shaped burst: 64 requests, sizes Zipf-skewed (a few big sorts,
# a long tail of tiny ones), keys from mixed distributions
sizes = datagen.zipf_sizes(64, 1 << 15, seed=0)
mixes = ["U", "G", "DD", "zipf"]
requests = [
    datagen.generate(mixes[i % len(mixes)], 1, int(s), seed=i)[0]
    for i, s in enumerate(sizes)
]

service = SortService(ServiceConfig(p=8), executor=SortExecutor())
service.sort_many(requests)  # warm: compile one program per pow2 bucket

service = SortService(ServiceConfig(p=8), executor=service.executor)
t0 = time.perf_counter()
results = service.sort_many(requests)
wall = time.perf_counter() - t0

ok = all(
    np.array_equal(r.keys, np.sort(a)) and np.array_equal(a[r.order], r.keys)
    for a, r in zip(requests, results)
)
total = int(sizes.sum())
print(
    f"[fused] {len(requests)} requests ({total} keys, sizes "
    f"{int(sizes.min())}..{int(sizes.max())}) in {wall * 1e3:.1f} ms "
    f"= {total / wall / 1e3:.0f} k keys/s — all sorted: {ok}"
)
print(f"[telemetry] {service.telemetry()}")

# the capacity planner resolved each fused batch's starting tier from its
# fingerprint: multi-segment batches pack STRIPED and start at the
# segment-aware sub-exact "planned" capacity (PR 3 pinned them to exact)
from repro.planner import fingerprint_arrays, planned_cap_for

fp = fingerprint_arrays(requests, 8)
omega, cap = planned_cap_for(fp)
print(
    f"[planner] start tiers {service.start_tiers}; one-batch bound: "
    f"pair_cap {cap} vs exact {fp.n_per_proc} (omega {omega:.1f}, "
    f"dup {fp.dup_fraction:.2f}, lane spread ≤{fp.lane_spread_max})"
)

# an adversarial batch (every request one constant key value) escalates its
# OWN batch through the capacity ladder; nothing is ever dropped. (Shown on
# a whp-pinned service — the planner-backed default prices such batches at
# exact up front, where per-pair overflow is impossible by construction.)
whp_service = SortService(
    ServiceConfig(p=8, pair_capacity="whp"), executor=service.executor
)
adversarial = [np.full(2048, r * 1000, np.int32) for r in range(8)]
results = whp_service.sort_many(adversarial)
ok = all(np.array_equal(r.keys, a) for a, r in zip(adversarial, results))
print(
    f"[escalation] adversarial whp batch complete={ok}, tier counters "
    f"{whp_service.stats.as_row()}"
)
