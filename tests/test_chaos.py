"""Chaos layer: seeded fault injection, deadlines/cancel/backoff, and
graceful degradation — the recovery machinery exercised deterministically.

The contract under test everywhere: **innocents always complete, byte-
identical to an un-faulted run**; only explicitly poisoned requests fail,
and they fail *naming their rid*.
"""
import time

import numpy as np
import pytest

from repro import obs
from repro.chaos import ChaosError, FaultPlan, resolve_chaos
from repro.core import SortConfig, SortExecutor
from repro.delta import SortedView
from repro.service import (
    ServiceConfig,
    SortCancelledError,
    SortService,
    SortServiceError,
    SortTimeoutError,
)
from repro.train.elastic import StragglerMonitor

pytestmark = pytest.mark.fast

POISON_LEN = 777  # unique request length the poison monkeypatches key on


def _arrays(sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(-(2**31), 2**31, s).astype(np.int32) for s in sizes]


# ------------------------------------------------------------- the plan
def test_fault_plan_draws_are_deterministic_and_order_independent():
    """The same (seed, kind, key) decides identically regardless of how
    many other draws happened first — async scheduling cannot perturb the
    fault schedule."""
    a = FaultPlan(seed=5, capacity_fault_rate=0.5, capacity_fault_rungs=(0, 1))
    b = FaultPlan(seed=5, capacity_fault_rate=0.5, capacity_fault_rungs=(0, 1))
    # burn unrelated draws on b only
    for i in range(50):
        b.straggle_delay(i)
    hits_a = [(s, r) for s in range(40) for r in (0, 1) if a.fault_capacity(s, r)]
    hits_b = [(s, r) for s in range(40) for r in (0, 1) if b.fault_capacity(s, r)]
    assert hits_a == hits_b
    assert hits_a  # rate 0.5 over 80 opportunities: must fire
    assert len(hits_a) < 80  # ... and must not fire everywhere


def test_fault_plan_budget_caps_total_injections():
    plan = FaultPlan(seed=1, capacity_fault_rate=1.0, max_faults=3)
    fired = sum(plan.fault_capacity(s, 0) for s in range(10))
    assert fired == 3
    assert plan.injected_total == 3


def test_transient_faults_fire_each_rid_set_at_most_once():
    plan = FaultPlan(seed=2, transient_error_rate=1.0)
    with pytest.raises(ChaosError):
        plan.check_launch(0, (1, 2, 3))
    plan.check_launch(1, (1, 2, 3))  # same rid-set: recovered, no re-fault
    with pytest.raises(ChaosError):
        plan.check_launch(2, (1, 2))  # different set: its own fault


def test_resolve_chaos_duck_types():
    plan = FaultPlan()
    assert resolve_chaos(None) is None
    assert resolve_chaos(plan) is plan
    with pytest.raises(TypeError):
        resolve_chaos(object())


def test_chaos_is_hash_excluded_from_sort_config():
    """A faulted config and a clean one are EQUAL and share prepare keys —
    chaos must never fragment the compiled-program registry (same contract
    as ``obs``)."""
    clean = SortConfig(p=4, n_per_proc=64)
    faulted = SortConfig(p=4, n_per_proc=64, chaos=FaultPlan(seed=9))
    assert clean == faulted
    assert hash(clean) == hash(faulted)
    assert clean.prepare_key() == faulted.prepare_key()


# ------------------------------------------------------ capacity faults
def test_capacity_fault_escalates_byte_identically():
    """A forced rung fault walks the ladder exactly like an organic
    overflow: a later tier serves the sort, and the output bytes are
    identical to the clean run."""
    a = _arrays([600], seed=1)[0]
    ex = SortExecutor()
    clean = SortService(
        ServiceConfig(p=4, pair_capacity="whp"), executor=ex
    ).sort_one(a)
    plan = FaultPlan(seed=0, capacity_fault_rate=1.0, capacity_fault_rungs=(0, 1, 2))
    faulted = SortService(
        ServiceConfig(p=4, pair_capacity="whp", chaos=plan), executor=ex
    ).sort_one(a)
    assert plan.injected.get("capacity_fault", 0) >= 1
    assert faulted.tier != clean.tier  # it really escalated further
    assert np.array_equal(clean.keys, faulted.keys)
    assert np.array_equal(clean.order, faulted.order)


def test_capacity_fault_never_fires_on_terminal_rung():
    """Rate 1.0 over every rung still terminates: the terminal
    allgather rung is never faulted, so the sort always completes."""
    a = _arrays([400], seed=2)[0]
    plan = FaultPlan(
        seed=0, capacity_fault_rate=1.0, capacity_fault_rungs=(0, 1, 2, 3, 4)
    )
    svc = SortService(
        ServiceConfig(p=4, pair_capacity="whp", chaos=plan),
        executor=SortExecutor(),
    )
    res = svc.sort_one(a)
    assert np.array_equal(res.keys, np.sort(a))
    assert res.tier == "allgather"  # rode the whole ladder


# -------------------------------------------------------- launch faults
def test_poison_rid_fails_naming_rid_innocents_byte_identical():
    """Acceptance core: a FaultPlan poison rid fails terminally with the
    rid in the message; every innocent in the same batch completes with
    bytes identical to an un-faulted run of the same mix."""
    arrays = _arrays([300, 250, 400, 200], seed=3)
    ex = SortExecutor()
    ref_svc = SortService(ServiceConfig(p=4), executor=ex)
    ref_futs = [ref_svc.submit(a) for a in arrays]
    ref_svc.flush()

    plan = FaultPlan(seed=3, poison_rids=(1,))
    svc = SortService(ServiceConfig(p=4, chaos=plan), executor=ex)
    futs = [svc.submit(a) for a in arrays]
    svc.flush()  # never raises
    exc = futs[1].exception()
    assert isinstance(exc, SortServiceError) and "rid=1" in str(exc)
    assert isinstance(exc.__cause__, ChaosError)
    for i in (0, 2, 3):
        assert futs[i].exception() is None
        r, r0 = futs[i].result(), ref_futs[i].result()
        assert np.array_equal(r.keys, r0.keys)
        assert np.array_equal(r.order, r0.order)
    tele = svc.telemetry()["dispatch"]
    assert tele["failsink_errors"] == 1
    assert tele["recovered_batches"] >= 1


def test_transient_launch_fault_recovers_all_requests():
    """A transient fault (fires once per rid-set) is absorbed by failsink
    re-dispatch: every request completes, recovery is visible in
    telemetry."""
    arrays = _arrays([300, 250, 400], seed=4)
    plan = FaultPlan(seed=0, fail_batches=(0,))  # first launch faults once
    svc = SortService(ServiceConfig(p=4, chaos=plan), executor=SortExecutor())
    futs = [svc.submit(a) for a in arrays]
    svc.flush()
    for a, f in zip(arrays, futs):
        assert np.array_equal(f.result().keys, np.sort(a))
        assert f.result().failsink
    tele = svc.telemetry()["dispatch"]
    assert plan.injected.get("launch_error") == 1
    assert tele["recovered_batches"] >= 1
    assert tele["failsink_errors"] == 0


# ---------------------------------------------- stragglers + the monitor
def test_straggler_monitor_is_slow_is_pure():
    m = StragglerMonitor(threshold=2.0)
    for _ in range(6):
        m.record(0.01)
    ewma = m.ewma
    assert m.is_slow(0.1) and not m.is_slow(0.01)
    assert m.ewma == ewma  # no state advanced
    assert not StragglerMonitor().is_slow(100.0)  # warmup: never slow


def test_injected_straggle_counts_straggler_flights():
    """An explicit straggle_flights delay inflates one flight's wall time
    past the EWMA threshold and lands in svc.straggler_flights — the
    elastic monitor's first production wiring."""
    plan = FaultPlan(seed=0, straggle_flights=(5,), straggle_s=0.25)
    svc = SortService(
        ServiceConfig(p=4, chaos=plan),
        executor=SortExecutor(),
    )
    # tighten the monitor so CI timing noise can't mask the injection
    svc.dispatcher.stragglers = StragglerMonitor(threshold=3.0)
    for a in _arrays([256] * 7, seed=5):
        svc.sort_one(a)
    assert plan.injected.get("straggle") == 1
    assert svc.dispatcher.straggler_flights >= 1


# ------------------------------------------------- deadlines and cancel
def test_deadline_expires_pending_request_with_timeout_naming_rid():
    svc = SortService(ServiceConfig(p=4), executor=SortExecutor())
    keep = svc.submit(_arrays([100], seed=6)[0])
    doomed = svc.submit(_arrays([120], seed=7)[0], deadline_s=0.001)
    time.sleep(0.01)
    svc.run_pending(max_steps=0)
    exc = doomed.exception()
    assert isinstance(exc, SortTimeoutError)
    assert f"rid={doomed.rid}" in str(exc)
    assert svc.telemetry()["deadline_timeouts"] == 1
    # the innocent neighbour still completes normally
    assert keep.exception() is None and keep.result() is not None


def test_deadline_expires_formed_but_unlaunched_request():
    """A request already formed into the dispatcher queue (but not
    launched) is unpicked at expiry; its batch re-forms and the remaining
    rids complete."""
    svc = SortService(ServiceConfig(p=4, max_in_flight=1), executor=SortExecutor())
    blocker = svc.submit(_arrays([400], seed=8)[0])
    svc.flush_async()  # blocker launches, holding the only slot
    a1, a2 = _arrays([200, 220], seed=9)
    keep = svc.submit(a1)
    doomed = svc.submit(a2, deadline_s=0.001)
    svc.flush_async()  # formed + queued behind the blocker, not launched
    time.sleep(0.01)
    svc.run_pending(max_steps=0)
    assert isinstance(doomed.exception(), SortTimeoutError)
    assert np.array_equal(keep.result().keys, np.sort(a1))
    assert np.array_equal(blocker.result().keys, np.sort(_arrays([400], seed=8)[0]))


def test_launched_requests_are_never_expired():
    svc = SortService(ServiceConfig(p=4), executor=SortExecutor())
    a = _arrays([300], seed=10)[0]
    fut = svc.submit(a, deadline_s=0.001)
    svc.flush_async()  # launches immediately — past the point of expiry
    time.sleep(0.01)
    svc.run_pending()
    assert fut.exception() is None
    assert np.array_equal(fut.result().keys, np.sort(a))


def test_cancel_pending_request_never_launches():
    svc = SortService(ServiceConfig(p=4), executor=SortExecutor())
    fut = svc.submit(_arrays([100], seed=11)[0])
    assert fut.cancel()
    assert fut.cancelled() and fut.done()
    assert svc.dispatcher.launches == 0
    with pytest.raises(SortCancelledError, match=f"rid={fut.rid}"):
        fut.result()
    assert not fut.cancel()  # idempotent: already resolved


def test_cancel_unpicks_queued_request_and_batch_reforms():
    """Cancelling a formed-but-queued request re-forms its batch without
    it: the cancelled rid never launches, its batchmates complete."""
    svc = SortService(ServiceConfig(p=4, max_in_flight=1), executor=SortExecutor())
    blocker = svc.submit(_arrays([400], seed=12)[0])
    svc.flush_async()  # occupy the only launch slot
    arrays = _arrays([150, 170, 190], seed=13)
    futs = [svc.submit(a) for a in arrays]
    svc.flush_async()  # formed into the dispatcher queue behind the blocker
    assert futs[1].cancel()
    assert futs[1].cancelled()
    svc.flush()
    assert np.array_equal(futs[0].result().keys, np.sort(arrays[0]))
    assert np.array_equal(futs[2].result().keys, np.sort(arrays[2]))
    assert blocker.exception() is None
    assert svc.dispatcher.cancelled_rids == 1


def test_cancel_after_launch_returns_false_and_completes():
    svc = SortService(ServiceConfig(p=4), executor=SortExecutor())
    a = _arrays([250], seed=14)[0]
    fut = svc.submit(a)
    svc.flush_async()  # launched
    assert not fut.cancel()
    assert np.array_equal(fut.result().keys, np.sort(a))


# ------------------------------------- retry budget and circuit breaker
def test_retry_budget_explodes_to_solos(monkeypatch):
    """Budget 0: a failed multi-rid batch skips bisection entirely and
    isolates every rid solo at once — innocents still complete."""
    import repro.service.dispatch as disp_mod

    orig = disp_mod.segmented_sort_launch

    def poisoned(packed, **kw):  # fails only while fused with others
        if POISON_LEN in packed.sizes and len(packed.sizes) > 1:
            raise RuntimeError("backend error (simulated)")
        return orig(packed, **kw)

    monkeypatch.setattr(disp_mod, "segmented_sort_launch", poisoned)
    svc = SortService(
        ServiceConfig(p=4, fault_retry_budget=0, breaker_threshold=0),
        executor=SortExecutor(),
    )
    arrays = _arrays([200, POISON_LEN, 250, 300], seed=15)
    futs = [svc.submit(a) for a in arrays]
    svc.flush()
    for a, f in zip(arrays, futs):
        assert np.array_equal(f.result().keys, np.sort(a))
    tele = svc.telemetry()["dispatch"]
    assert tele["retry_budget_exceeded"] == 1
    assert tele["failsink_splits"] == 0  # no bisection happened


def test_circuit_breaker_degrades_bucket_to_solo_exact(monkeypatch):
    """After breaker_threshold consecutive fused failures in one bucket,
    fresh multi-rid traffic for that bucket dispatches per-request at the
    exact tier — the poisoned bucket stops dragging innocents into
    failing fused launches, and everything completes."""
    import repro.service.dispatch as disp_mod

    orig = disp_mod.segmented_sort_launch

    def poisoned(packed, **kw):
        if POISON_LEN in packed.sizes and len(packed.sizes) > 1:
            raise RuntimeError("backend error (simulated)")
        return orig(packed, **kw)

    monkeypatch.setattr(disp_mod, "segmented_sort_launch", poisoned)
    svc = SortService(
        ServiceConfig(p=4, breaker_threshold=2), executor=SortExecutor()
    )
    sizes = (200, POISON_LEN, 250)
    for rnd in range(3):
        arrays = _arrays(sizes, seed=20 + rnd)
        futs = [svc.submit(a) for a in arrays]
        svc.flush()
        for a, f in zip(arrays, futs):
            assert np.array_equal(f.result().keys, np.sort(a))
    tele = svc.telemetry()["dispatch"]
    assert tele["breaker_opened"] >= 1
    assert tele["breaker_degraded_batches"] >= 1


def test_circuit_breaker_closes_after_cooldown(monkeypatch):
    """Past the cooldown the bucket readmits fused batches (half-open);
    clean completions keep it closed."""
    import repro.service.dispatch as disp_mod

    orig = disp_mod.segmented_sort_launch
    fail = {"on": True}

    def flaky(packed, **kw):
        if fail["on"] and len(packed.sizes) > 1:
            raise RuntimeError("backend error (simulated)")
        return orig(packed, **kw)

    monkeypatch.setattr(disp_mod, "segmented_sort_launch", flaky)
    svc = SortService(
        # cooldown far longer than any compile stall in round 1 — the test
        # expires it explicitly by rewinding the open timestamp
        ServiceConfig(p=4, breaker_threshold=1, breaker_cooldown_s=60.0),
        executor=SortExecutor(),
    )
    arrays = _arrays([200, 250], seed=30)
    futs = [svc.submit(a) for a in arrays]
    svc.flush()  # fused failure opens the breaker (threshold 1)
    assert all(f.exception() is None for f in futs)
    assert svc.dispatcher.breaker_opened == 1
    fail["on"] = False
    # still inside the open window: same-bucket multi-rid traffic degrades
    arrays2 = _arrays([200, 250], seed=31)
    futs2 = [svc.submit(a) for a in arrays2]
    svc.flush()
    assert all(f.exception() is None for f in futs2)
    tele = svc.telemetry()["dispatch"]
    assert tele["breaker_degraded_batches"] == 1
    # cooldown passes (rewound, not slept) — breaker half-opens
    d = svc.dispatcher
    for bucket in list(d._breaker_open_at):
        d._breaker_open_at[bucket] -= 61.0
    arrays3 = _arrays([200, 250], seed=32)
    futs3 = [svc.submit(a) for a in arrays3]
    svc.flush()  # fused again, completes cleanly, breaker stays closed
    assert all(f.exception() is None for f in futs3)
    tele = svc.telemetry()["dispatch"]
    assert tele["breaker_degraded_batches"] == 1  # no new degradation
    assert tele["breaker_opened"] == 1  # never re-opened


# --------------------------------------------------- delta fold corruption
def test_fold_corruption_falls_back_to_resort_byte_identically():
    """An injected corrupt Δ run trips the post-merge monotonicity check;
    the view resorts from its preserved pre-fold state and stays
    byte-identical to the cold sort of the concatenated history."""
    rng = np.random.default_rng(32)
    b1 = rng.integers(0, 1000, 400).astype(np.int32)
    b2 = rng.integers(0, 1000, 60).astype(np.int32)
    plan = FaultPlan(seed=0, corrupt_folds=(0,))
    v = SortedView(p=4, chaos_handle=plan)
    v.fold(b1, (np.arange(400, dtype=np.int64),))
    route = v.fold(b2, (np.arange(400, 460, dtype=np.int64),))
    assert route == "resort"  # the fold fell back
    assert plan.injected.get("fold_corruption") == 1
    cat = np.concatenate([b1, b2])
    assert np.array_equal(v.keys, np.sort(cat))
    assert np.array_equal(v.payloads[0], np.argsort(cat, kind="stable"))
    counts = {
        str(lbl["view"]): c.value
        for lbl, c in obs.metrics().collect("delta.fold_fallback_resorts")
        if str(lbl["view"]) == v.label
    }
    assert counts[v.label] == 1


def test_uncorrupted_folds_never_fall_back():
    rng = np.random.default_rng(33)
    v = SortedView(p=4, chaos_handle=FaultPlan(seed=0))  # no corruption config
    hist = []
    for i in range(3):
        b = rng.integers(0, 1000, 200).astype(np.int32)
        base = sum(len(h) for h in hist)
        v.fold(b, (np.arange(base, base + 200, dtype=np.int64),))
        hist.append(b)
    cat = np.concatenate(hist)
    assert np.array_equal(v.keys, np.sort(cat))
    counts = {
        str(lbl["view"]): c.value
        for lbl, c in obs.metrics().collect("delta.fold_fallback_resorts")
        if str(lbl["view"]) == v.label
    }
    assert counts.get(v.label, 0) == 0


# ------------------------------------------------ driver pump and thread
def test_run_pending_fires_flush_after_s_without_any_caller():
    """ROADMAP gap: flush_after_s used to fire only when somebody called
    in. run_pending() is that somebody — a quiet service still flushes."""
    svc = SortService(
        ServiceConfig(p=4, flush_after_s=0.005), executor=SortExecutor()
    )
    a = _arrays([200], seed=34)[0]
    fut = svc.submit(a)
    time.sleep(0.02)
    assert not fut.done()
    svc.run_pending(max_steps=1)  # no submit, no claim — just the pump
    assert svc.pending == 0  # deadline flush fired
    assert fut.done()
    assert np.array_equal(fut.result().keys, np.sort(a))
    assert svc.flush_triggers.get("deadline", 0) == 1


def test_driver_thread_resolves_futures_in_background():
    svc = SortService(
        ServiceConfig(p=4, flush_after_s=0.002), executor=SortExecutor()
    )
    svc.start_driver(interval_s=0.002)
    try:
        a = _arrays([300], seed=35)[0]
        fut = svc.submit(a)
        deadline = time.time() + 5.0
        while not fut.done() and time.time() < deadline:
            time.sleep(0.005)
        assert fut.done(), "driver thread never resolved the future"
        assert np.array_equal(fut.result().keys, np.sort(a))
    finally:
        svc.stop_driver()


def test_chaos_service_end_to_end_soak_innocents_byte_identical():
    """Acceptance: seeded FaultPlan (capacity faults + 2 poison rids +
    stragglers) over a request mix — every innocent byte-identical to the
    un-faulted run; both poisons fail naming their rid."""
    sizes = [200, 350, 150, 420, 260, 180, 310, 240]
    arrays = _arrays(sizes, seed=36)
    poison = (2, 5)
    ex = SortExecutor()
    ref_svc = SortService(ServiceConfig(p=4, max_batch_keys=1 << 13), executor=ex)
    ref = {f.rid: f for f in [ref_svc.submit(a) for a in arrays]}
    ref_svc.flush()

    plan = FaultPlan(
        seed=36,
        poison_rids=poison,
        capacity_fault_rate=0.5,
        capacity_fault_rungs=(0,),
        transient_error_rate=0.4,
        straggle_flights=(0,),
        straggle_s=0.002,
    )
    svc = SortService(
        ServiceConfig(p=4, max_batch_keys=1 << 13, chaos=plan), executor=ex
    )
    futs = [svc.submit(a) for a in arrays]
    svc.flush()
    innocents_failed = 0
    for f in futs:
        if f.rid in poison:
            exc = f.exception()
            assert isinstance(exc, SortServiceError)
            assert f"rid={f.rid}" in str(exc)
            continue
        if f.exception() is not None:
            innocents_failed += 1
            continue
        r, r0 = f.result(), ref[f.rid].result()
        assert np.array_equal(r.keys, r0.keys)
        assert np.array_equal(r.order, r0.order)
    assert innocents_failed == 0
    assert plan.injected_total > 0
