"""Fast smoke sweep for the Pallas kernel packages (CPU interpret mode).

The full kernel suite (test_kernels.py) is property-based and auto-skips
when ``hypothesis`` is absent — which left the kernels with zero tier-1
coverage in minimal containers. This module is dependency-free and part of
the ``-m fast`` loop: one small shape sweep per kernel package against its
pure-jnp oracle.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.bitonic import ops as bops, ref as bref
from repro.kernels.merge_path import ops as mops, ref as mref
from repro.kernels.searchsorted import ops as sops, ref as sref

pytestmark = pytest.mark.fast


@pytest.mark.parametrize("dtype", [np.int32, np.float32])
@pytest.mark.parametrize("shape", [(1, 17), (3, 100), (2, 1024)])
def test_bitonic_sort_smoke(dtype, shape):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 2**20, shape).astype(dtype))
    assert np.array_equal(bops.sort(x), bref.sort(x))


def test_bitonic_sort_bf16_smoke():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 65)).astype(np.float32)).astype(
        jnp.bfloat16
    )
    assert np.array_equal(np.asarray(bops.sort(x)), np.asarray(bref.sort(x)))


def test_bitonic_kv_smoke():
    rng = np.random.default_rng(2)
    k = jnp.asarray(rng.integers(0, 30, (2, 128)).astype(np.int32))
    v = jnp.arange(2 * 128, dtype=jnp.int32).reshape(2, 128)
    ko, vo = bops.sort_kv(k, v)
    kr, _ = bref.sort_kv(k, v)
    assert np.array_equal(ko, kr)
    for r in range(2):  # values stay a permutation consistent with the keys
        assert np.array_equal(
            np.asarray(k)[r][np.asarray(vo)[r] % 128], np.asarray(ko)[r]
        )


@pytest.mark.parametrize("na,nb", [(33, 77), (128, 128), (1, 64)])
def test_merge_path_smoke(na, nb):
    rng = np.random.default_rng(3)
    a = jnp.sort(jnp.asarray(rng.integers(0, 500, (2, na)).astype(np.int32)), axis=-1)
    b = jnp.sort(jnp.asarray(rng.integers(0, 500, (2, nb)).astype(np.int32)), axis=-1)
    assert np.array_equal(mops.merge(a, b), mref.merge(a, b))


@pytest.mark.parametrize("na,nb", [(100, 300), (1500, 2500), (64, 64)])
def test_merge_partitioned_smoke(na, nb):
    """Partitioned merge-path variant == whole-row oracle, widths straddling
    the TILE boundary and including sentinel-valued real keys."""
    rng = np.random.default_rng(5)
    w = max(na, nb)
    sent = np.iinfo(np.int32).max
    a = np.sort(rng.integers(0, 1000, (3, w)).astype(np.int32), axis=-1)
    b = np.sort(rng.integers(0, 1000, (3, w)).astype(np.int32), axis=-1)
    a[:, na:] = sent  # pad tails the way the routing rows arrive
    b[:, nb:] = sent
    b[1, nb - 1 :] = sent  # a real key equal to the sentinel
    got = mops.merge_partitioned(jnp.asarray(a), jnp.asarray(b))
    want = np.sort(np.concatenate([a, b], axis=-1), axis=-1)
    assert np.array_equal(np.asarray(got), want)


@pytest.mark.parametrize("side", ["left", "right"])
@pytest.mark.parametrize("n,q", [(256, 256), (1000, 100), (5000, 2048)])
def test_rank_in_matches_searchsorted(side, n, q):
    rng = np.random.default_rng(6)
    data = jnp.sort(jnp.asarray(rng.integers(0, 50, n).astype(np.int32)))
    queries = jnp.asarray(rng.integers(-5, 55, q).astype(np.int32))
    got = sops.rank_in(data, queries, side=side)
    want = jnp.searchsorted(data, queries, side=side)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,s", [(256, 7), (1000, 31)])
def test_searchsorted_smoke(n, s):
    rng = np.random.default_rng(4)
    x = jnp.sort(jnp.asarray(rng.integers(0, 40, n).astype(np.int32)))
    sk = jnp.asarray(rng.integers(0, 40, s).astype(np.int32))
    sp = jnp.asarray(rng.integers(0, 8, s).astype(np.int32))
    si = jnp.asarray(rng.integers(0, n, s).astype(np.int32))
    me = jnp.asarray(3, jnp.int32)
    got = sops.splitter_ranks(x, sk, sp, si, me)
    want = sref.splitter_ranks(x, sk, sp, si, me)
    assert np.array_equal(got, want)
