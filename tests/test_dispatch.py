"""Async dispatch: futures, in-flight batches, failsink fault isolation,
bounded unclaimed store, admission-aware forming, telemetry memoization."""
import time

import numpy as np
import pytest

from repro.core import SortExecutor, sort_segments
from repro.service import (
    BatchFormer,
    ServiceConfig,
    SortFuture,
    SortService,
    SortServiceError,
)

pytestmark = pytest.mark.fast

POISON_LEN = 777  # unique request length the poison monkeypatches key on


def _arrays(sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(-(2**31), 2**31, s).astype(np.int32) for s in sizes]


def test_submit_returns_future_without_dispatching():
    """Acceptance: submit() queues and returns — nothing launches until a
    flush trigger or a claim forces it."""
    svc = SortService(ServiceConfig(p=8), executor=SortExecutor())
    arrays = _arrays([100, 300, 50])
    futs = [svc.submit(a) for a in arrays]
    assert all(isinstance(f, SortFuture) and not f.done() for f in futs)
    assert svc.pending == 3
    assert svc.dispatcher.idle and svc.dispatcher.launches == 0
    for a, f in zip(arrays, futs):
        res = f.result()  # the only blocking point
        assert np.array_equal(res.keys, np.sort(a))
        assert np.array_equal(a[res.order], res.keys)


def test_futures_path_byte_identical_to_fused_sync_path():
    """Acceptance: results claimed through futures are byte-identical to the
    core fused segmented sort (itself acceptance-tested against the
    per-request ``bsp_sort_safe`` reference in test_service.py)."""
    sizes = [5, 333, 64, 1000, 7, 512]
    arrays = _arrays(sizes, seed=9)
    ref = sort_segments(arrays, p=8)
    svc = SortService(ServiceConfig(p=8), executor=SortExecutor())
    futs = [svc.submit(a) for a in arrays]
    svc.flush()
    for i, f in enumerate(futs):
        res = f.result()
        assert res.keys.dtype == ref.keys[i].dtype == np.int32
        assert np.array_equal(res.keys, ref.keys[i])
        assert np.array_equal(res.order, ref.order[i])


def test_multiple_batches_in_flight_overlap():
    """The pipeline keeps max_in_flight batches launched at once: with four
    formed batches, two fly before anything is awaited, and later launches
    happen while earlier flights' device work is outstanding."""
    svc = SortService(
        ServiceConfig(p=8, max_batch_keys=400, max_in_flight=2),
        executor=SortExecutor(),
    )
    arrays = _arrays([300, 300, 300, 300], seed=3)
    futs = [svc.submit(a) for a in arrays]
    svc.flush_async()
    assert svc.dispatcher.in_flight == 2  # both slots filled, none awaited
    assert svc.dispatcher.launches == 2
    assert not any(f.done() for f in futs)
    svc.flush()  # drain the pipeline
    tele = svc.telemetry()["dispatch"]
    assert tele["in_flight_peak"] >= 2
    assert tele["overlapped_launches"] >= 1  # launched under outstanding work
    for a, f in zip(arrays, futs):
        assert np.array_equal(f.result().keys, np.sort(a))


def test_poison_request_failsink_isolates_and_resolves_solo(monkeypatch):
    """Satellite: one poison request in a fused batch. The failsink bisects
    until the poison stands alone; every innocent request completes, the
    poison sorts solo in its own bucket, and nothing raises."""
    import repro.service.dispatch as disp_mod

    orig = disp_mod.segmented_sort_launch

    def poisoned(packed, **kw):  # fails only while fused with others
        if POISON_LEN in packed.sizes and len(packed.sizes) > 1:
            raise RuntimeError("ladder exhausted (simulated)")
        return orig(packed, **kw)

    monkeypatch.setattr(disp_mod, "segmented_sort_launch", poisoned)
    svc = SortService(ServiceConfig(p=8), executor=SortExecutor())
    arrays = _arrays([300, 300, POISON_LEN, 300, 300], seed=5)
    futs = [svc.submit(a) for a in arrays]
    out = svc.flush()
    assert set(out) == {f.rid for f in futs}  # no rid lost
    for a, f in zip(arrays, futs):
        res = f.result()
        assert np.array_equal(res.keys, np.sort(a))
    poison = futs[2].result()
    assert poison.failsink  # routed through the failsink
    assert poison.n_per_proc == 128  # solo pow2 bucket for 777 keys over p=8
    tele = svc.telemetry()["dispatch"]
    assert tele["failsink_splits"] >= 1
    assert tele["failsink_errors"] == 0
    assert tele["failsink_resolved"] >= 1


def test_poison_request_failsink_terminal_error_spares_the_batch(monkeypatch):
    """Satellite: a request that fails even solo resolves with a
    SortServiceError naming its rid — every other request in the original
    batch still completes, and flush() itself never raises."""
    import repro.service.dispatch as disp_mod

    orig = disp_mod.segmented_sort_launch

    def poisoned(packed, **kw):  # fails every dispatch containing the rid
        if POISON_LEN in packed.sizes:
            raise RuntimeError("backend error (simulated)")
        return orig(packed, **kw)

    monkeypatch.setattr(disp_mod, "segmented_sort_launch", poisoned)
    svc = SortService(ServiceConfig(p=8), executor=SortExecutor())
    arrays = _arrays([200, POISON_LEN, 200, 200], seed=6)
    futs = [svc.submit(a) for a in arrays]
    svc.flush()  # does NOT raise: the failure lives on the poison future
    for i, (a, f) in enumerate(zip(arrays, futs)):
        if i == 1:
            continue
        assert np.array_equal(f.result().keys, np.sort(a))
    exc = futs[1].exception()
    assert isinstance(exc, SortServiceError)
    assert exc.rids == (futs[1].rid,) and str(futs[1].rid) in str(exc)
    with pytest.raises(SortServiceError):
        futs[1].result()
    with pytest.raises(SortServiceError):
        svc.take_result(futs[1])
    tele = svc.telemetry()
    assert tele["requests_failed"] == 1
    assert tele["dispatch"]["failsink_errors"] == 1
    # bisection isolated the poison; its one solo retry also failed
    assert tele["dispatch"]["failsink_splits"] >= 2
    assert tele["dispatch"]["failsink_solo_retries"] >= 1


def test_sort_many_surfaces_failure_as_service_error_not_keyerror(monkeypatch):
    """Satellite: the blocking conveniences never raise a bare KeyError for
    a failed request — they surface the SortServiceError naming the rid,
    and the other requests' results stay claimable."""
    import repro.service.dispatch as disp_mod

    orig = disp_mod.segmented_sort_launch

    def poisoned(packed, **kw):
        if POISON_LEN in packed.sizes:
            raise RuntimeError("backend error (simulated)")
        return orig(packed, **kw)

    monkeypatch.setattr(disp_mod, "segmented_sort_launch", poisoned)
    svc = SortService(ServiceConfig(p=8), executor=SortExecutor())
    arrays = _arrays([100, POISON_LEN, 150], seed=7)
    with pytest.raises(SortServiceError) as ei:
        svc.sort_many(arrays)
    assert ei.value.rids == (1,)  # the poison's rid, by submit order
    for rid, a in [(0, arrays[0]), (2, arrays[2])]:
        assert np.array_equal(svc.take_result(rid).keys, np.sort(a))
    # claiming an unknown/failed rid is a SortServiceError too, not KeyError
    with pytest.raises(SortServiceError, match="rid=1"):
        svc.take_result(1)


def test_unclaimed_store_bounded_with_eviction_counter():
    """Satellite: the unclaimed-result store is capped with oldest-first
    eviction; the eviction is telemetry-counted and the SortFuture's cached
    result survives it."""
    svc = SortService(
        ServiceConfig(p=8, max_unclaimed=4), executor=SortExecutor()
    )
    arrays = _arrays([50] * 6, seed=8)
    futs = [svc.submit(a) for a in arrays]
    out = svc.flush()
    assert set(out) == {f.rid for f in futs[2:]}  # oldest two evicted
    assert svc.evicted_results == 2
    assert svc.telemetry()["evicted_results"] == 2
    with pytest.raises(SortServiceError, match="evicted"):
        svc.take_result(futs[0].rid)  # store copy is gone
    res0 = futs[0].result()  # ...but the future's cached copy is not
    assert np.array_equal(res0.keys, np.sort(arrays[0]))
    assert np.array_equal(svc.take_result(futs[5]).keys, np.sort(arrays[5]))


def test_telemetry_latency_stats_memoized_per_completion(monkeypatch):
    """Satellite: polling telemetry() must not rescan the latency window
    when nothing new completed — quantiles recompute only after new
    results land."""
    svc = SortService(ServiceConfig(p=8), executor=SortExecutor())
    svc.sort_many(_arrays([100, 200, 300], seed=10))
    calls = {"n": 0}
    orig = np.quantile

    def counting(*args, **kw):
        calls["n"] += 1
        return orig(*args, **kw)

    monkeypatch.setattr(np, "quantile", counting)
    first = svc.telemetry()
    after_first = calls["n"]
    assert after_first >= 1 and first["lat_p99_ms"] > 0
    for _ in range(5):  # soak-loop polling: no new completions, no rescans
        again = svc.telemetry()
    assert calls["n"] == after_first
    assert again["lat_p99_ms"] == first["lat_p99_ms"]
    svc.sort_one(np.arange(64, dtype=np.int32)[::-1].copy())
    svc.telemetry()  # a new completion invalidates the memo
    assert calls["n"] > after_first


def test_form_ready_holds_partial_tail_and_flush_ready_launches_full():
    """Admission-aware forming: full batches dispatch, the underfilled tail
    is held for more traffic (and a plain flush clears it)."""
    former = BatchFormer(p=8, max_batch_keys=1000, min_n_per_proc=8)
    reqs = [(i, np.zeros(s, np.int32)) for i, s in enumerate([600, 300, 200])]
    ready, held = former.form_ready(reqs, min_keys=500)
    assert [b.rids for b in ready] == [[0, 1]]  # 900 keys: full enough
    assert [rid for rid, _ in held] == [2]  # 200-key tail held, FIFO order
    # default threshold is half the cap
    ready2, held2 = former.form_ready(reqs)
    assert [b.rids for b in ready2] == [[0, 1]] and len(held2) == 1
    assert former.form_ready([]) == ([], [])

    svc = SortService(
        ServiceConfig(p=8, max_batch_keys=1000), executor=SortExecutor()
    )
    arrays = _arrays([600, 300, 200], seed=11)
    futs = [svc.submit(a) for a in arrays]
    assert svc.flush_ready(min_keys=500)  # launches the 900-key batch only
    assert svc.pending == 1  # the tail stays queued
    assert svc.flush_triggers.get("ready") == 1
    assert not svc.flush_ready(min_keys=500)  # still underfilled: no-op
    svc.flush()  # deadline/manual path clears the held tail
    assert svc.pending == 0
    for a, f in zip(arrays, futs):
        assert np.array_equal(f.result().keys, np.sort(a))


def test_two_poison_requests_in_one_batch_both_isolated(monkeypatch):
    """Multi-poison failsink: two poison requests fused into one batch are
    BOTH bisected down to terminal solo failures naming their own rid, and
    every innocent in the batch completes."""
    import repro.service.dispatch as disp_mod

    orig = disp_mod.segmented_sort_launch
    POISON_LEN_2 = 778

    def poisoned(packed, **kw):  # each poison fails every dispatch it rides
        if POISON_LEN in packed.sizes or POISON_LEN_2 in packed.sizes:
            raise RuntimeError("backend error (simulated)")
        return orig(packed, **kw)

    monkeypatch.setattr(disp_mod, "segmented_sort_launch", poisoned)
    svc = SortService(
        # breaker off: this test pins the pure-bisection path
        ServiceConfig(p=8, breaker_threshold=0),
        executor=SortExecutor(),
    )
    sizes = [300, POISON_LEN, 250, POISON_LEN_2, 200, 350]
    arrays = _arrays(sizes, seed=12)
    futs = [svc.submit(a) for a in arrays]
    svc.flush()  # never raises
    for i, (a, f) in enumerate(zip(arrays, futs)):
        if i in (1, 3):
            exc = f.exception()
            assert isinstance(exc, SortServiceError), (i, exc)
            assert exc.rids == (f.rid,) and f"rid={f.rid}" in str(exc)
        else:
            assert f.exception() is None, (i, f.exception())
            assert np.array_equal(f.result().keys, np.sort(a))
    tele = svc.telemetry()["dispatch"]
    assert tele["failsink_errors"] == 2
    assert svc.telemetry()["requests_failed"] == 2


def test_backoff_does_not_starve_innocents_behind_retry_queue(monkeypatch):
    """Backoff ordering: while a failed batch's retries back off, freshly
    enqueued innocent batches launch ahead of them — the pump scans past
    backing-off entries instead of waiting at the queue head."""
    import repro.service.dispatch as disp_mod

    orig = disp_mod.segmented_sort_launch
    launched = []

    def recording(packed, **kw):
        launched.append(tuple(packed.sizes))
        if POISON_LEN in packed.sizes:
            raise RuntimeError("backend error (simulated)")
        return orig(packed, **kw)

    monkeypatch.setattr(disp_mod, "segmented_sort_launch", recording)
    svc = SortService(
        ServiceConfig(
            p=8,
            failsink_backoff_s=0.2,
            failsink_backoff_max_s=0.2,
            breaker_threshold=0,
            max_in_flight=1,
        ),
        executor=SortExecutor(),
    )
    poison_fut = svc.submit(_arrays([POISON_LEN], seed=13)[0])
    svc.flush_async()  # poison launches solo, fails, requeues with backoff
    assert launched == [(POISON_LEN,)]  # retry is parked behind not_before
    a = _arrays([200], seed=14)[0]
    innocent = svc.submit(a)
    res = innocent.result()  # must NOT wait out the poison's 0.2s backoff
    assert np.array_equal(res.keys, np.sort(a))
    # the innocent launched ahead of the backed-off retry: the pump scanned
    # past the not_before-gated head instead of blocking on it
    first_retry = launched.index((POISON_LEN,), 1) if \
        launched.count((POISON_LEN,)) > 1 else len(launched)
    assert launched.index((200,)) < first_retry, launched
    with pytest.raises(SortServiceError, match=f"rid={poison_fut.rid}"):
        poison_fut.result()  # drives through the backoff window to terminal
    assert launched.count((POISON_LEN,)) == 2  # original + its one solo retry
