"""Radix count-then-distribute route: sign/boundary behaviour of the
order-preserving unsigned mapping, the single-rung zero-retry guarantee,
and the segmented composite path."""
import numpy as np
import jax.numpy as jnp
import pytest
from jax.experimental import enable_x64

from repro.core import (
    SortConfig,
    TierStats,
    bsp_sort_safe,
    datagen,
    gathered_output,
)
from repro.core.radix import radix_argsort
from repro.core.segmented import sort_segments
from repro.core.sort_radix import radix_boundaries

pytestmark = pytest.mark.fast

I32 = np.iinfo(np.int32)
I64 = np.iinfo(np.int64)


# ------------------------------------------------ radix_argsort sign/boundary
def test_radix_argsort_negative_and_boundary_int32():
    x = np.array(
        [5, -1, I32.min, I32.max, 0, -7, I32.min, I32.max, 3, -1], np.int32
    )
    order = np.asarray(radix_argsort(jnp.asarray(x)))
    assert np.array_equal(order, np.argsort(x, kind="stable"))


def test_radix_argsort_int64_extremes():
    with enable_x64():
        x = np.array(
            [I64.max, I64.min, 0, -1, 1, I64.min, I64.max, I64.min + 1],
            np.int64,
        )
        order = np.asarray(radix_argsort(jnp.asarray(x), bits=8))
        assert np.array_equal(order, np.argsort(x, kind="stable"))


def test_radix_argsort_zipf_duplicates_stable():
    keys = datagen.generate("zipf", 1, 512, seed=3)[0]
    order = np.asarray(radix_argsort(jnp.asarray(keys)))
    assert np.array_equal(order, np.argsort(keys, kind="stable"))


# ------------------------------------------------- route-level sign/boundary
def _run_route(x, route, n_values=0, **kw):
    p, n_p = x.shape
    cfg = SortConfig(
        p=p, n_per_proc=n_p, routing="a2a_dense", route=route,
        pair_capacity="exact", **kw,
    )
    vals = [
        jnp.asarray(np.arange(x.size, dtype=np.int32).reshape(p, n_p))
        for _ in range(n_values)
    ]
    st = TierStats()
    res, vbufs, st = bsp_sort_safe(jnp.asarray(x), cfg, values=vals, stats=st)
    cnt = np.asarray(res.count)
    flat_vals = [
        np.concatenate([np.asarray(b)[k, : cnt[k]] for k in range(p)])
        for b in vbufs
    ]
    return gathered_output(res), flat_vals, st


def test_radix_route_boundary_keys():
    rng = np.random.default_rng(0)
    x = rng.integers(I32.min, I32.max, (4, 64), dtype=np.int64).astype(np.int32)
    x[0, :4] = (I32.min, I32.max, -1, 0)
    x[3, -2:] = (I32.min, I32.max)
    keys, _, st = _run_route(x, "radix")
    assert np.array_equal(keys, np.sort(x.reshape(-1)))
    assert st.retries == 0 and st.last_tier == "radix"


def test_radix_route_int64_extremes():
    with enable_x64():
        rng = np.random.default_rng(1)
        x = rng.integers(I64.min, I64.max, (4, 32), dtype=np.int64)
        x[0, :2] = (I64.min, I64.max)
        keys, _, st = _run_route(x, "radix")
        assert np.array_equal(keys, np.sort(x.reshape(-1)))
        assert st.retries == 0


def test_radix_route_single_rung_zero_retries_on_one_bucket_skew():
    """Every key identical: the whole input lands in one range bucket — the
    worst case for range bucketing — yet the counted capacity fits it on the
    first and only rung. No escalation path exists on this route."""
    x = np.full((8, 256), 123456, np.int32)
    keys, _, st = _run_route(x, "radix")
    assert np.array_equal(keys, np.sort(x.reshape(-1)))
    assert st.retries == 0 and st.last_tier == "radix"
    assert st.attempts == {"radix": 1}, st.as_row()


def test_radix_boundaries_monotone_and_complete():
    """The counted boundary vector is a valid partition of the local run:
    starts at 0, ends at n_p, nondecreasing — and equal keys never straddle
    a destination boundary (stability across the exchange)."""
    import jax

    p, n_p = 4, 128
    x = np.sort(datagen.dense_int(p, n_p, seed=5, domain=16), axis=1)

    def one(xs):
        return radix_boundaries(jnp.asarray(xs), p, "bsp")

    bounds = np.asarray(jax.vmap(one, axis_name="bsp")(jnp.asarray(x)))
    assert bounds.shape == (p, p + 1)
    for k in range(p):
        b = bounds[k]
        assert b[0] == 0 and b[-1] == n_p
        assert np.all(np.diff(b) >= 0)
        for cut in b[1:-1]:  # equal keys share a destination
            if 0 < cut < n_p:
                assert x[k, cut - 1] != x[k, cut]


# ----------------------------------------------- deterministic parity sweep
# (tests/test_radix_parity.py runs the hypothesis-driven version of this
# when hypothesis is installed; this fixed grid always executes)
@pytest.mark.parametrize("mix", ["U", "B", "DD", "zipf", "dense_int"])
@pytest.mark.parametrize("kv", [0, 1])
def test_radix_route_matches_sample_route(mix, kv):
    p, n_p = 4, 192
    x = (
        datagen.dense_int(p, n_p, seed=7, domain=2 * p)
        if mix == "dense_int"
        else datagen.generate(mix, p, n_p, seed=7)
    )
    k_r, v_r, st_r = _run_route(x, "radix", n_values=kv, algorithm="det")
    k_s, v_s, _ = _run_route(x, "sample", n_values=kv, algorithm="det")
    assert st_r.retries == 0, st_r.as_row()
    assert np.array_equal(k_r, np.sort(x.reshape(-1)))
    assert np.array_equal(k_r, k_s)
    for a, b in zip(v_r, v_s):  # payload parity == stability parity
        assert np.array_equal(a, b)


# -------------------------------------------------- segmented composite path
def test_radix_route_segmented_composite_parity():
    """Int-key fused batches ride the radix route: the segment-tag composite
    is a dense-int prefix, so the counted bucketing splits by segment runs.
    Output must be byte-identical to the sampling route's, with zero
    retries and the radix tier reported."""
    arrays = [
        datagen.dense_int(1, s, seed=10 + i, domain=32)[0]
        for i, s in enumerate((100, 37, 256, 9))
    ]
    r_radix = sort_segments(arrays, p=4, layout="striped", route="radix")
    r_sample = sort_segments(arrays, p=4, layout="striped")
    for a, kr, ks in zip(arrays, r_radix.keys, r_sample.keys):
        assert np.array_equal(kr, np.sort(a))
        assert np.array_equal(kr, ks)
    for or_, os_ in zip(r_radix.order, r_sample.order):
        assert np.array_equal(or_, os_)
    assert r_radix.stats.retries == 0
    assert r_radix.tier == "radix"
