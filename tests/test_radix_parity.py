"""Property test: the radix route is byte-identical to the sampling route.

Both routes end in the same fused Ph5 exchange and Ph6 merge; they differ
only in how the destination partition is chosen (counted range buckets vs
sampled splitters). Since both partitions respect the global order and
keep equal keys together, the *gathered* output — keys and every payload —
must match exactly on any input, not just statistically."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import SortConfig, TierStats, bsp_sort_safe, datagen, gathered_output

settings.register_profile("ci", deadline=None, max_examples=20)
settings.load_profile("ci")

pytestmark = pytest.mark.fast


@st.composite
def route_instances(draw):
    p = draw(st.sampled_from([2, 4, 8]))
    n_p = draw(st.integers(min_value=8, max_value=256))
    mix = draw(st.sampled_from(["U", "B", "DD", "zipf", "dense_int"]))
    kv = draw(st.booleans())
    seed = draw(st.integers(min_value=0, max_value=999))
    if mix == "dense_int":
        x = datagen.dense_int(p, n_p, seed=seed, domain=max(2, 2 * p))
    else:
        x = datagen.generate(mix, p, n_p, seed=seed)
    return x, kv


def _gather(x, route, kv):
    p, n_p = x.shape
    cfg = SortConfig(
        p=p, n_per_proc=n_p, routing="a2a_dense", route=route,
        pair_capacity="exact", algorithm="det",
    )
    vals = (
        [jnp.asarray(np.arange(x.size, dtype=np.int32).reshape(p, n_p))]
        if kv
        else []
    )
    stats = TierStats()
    res, vbufs, stats = bsp_sort_safe(
        jnp.asarray(x), cfg, values=vals, stats=stats
    )
    cnt = np.asarray(res.count)
    flat_vals = [
        np.concatenate([np.asarray(b)[k, : cnt[k]] for k in range(p)])
        for b in vbufs
    ]
    return gathered_output(res), flat_vals, stats


@given(route_instances())
def test_radix_route_byte_identical_to_sample_route(inst):
    x, kv = inst
    k_r, v_r, st_r = _gather(x, "radix", kv)
    k_s, v_s, _ = _gather(x, "sample", kv)
    assert st_r.retries == 0, st_r.as_row()  # zero retries by construction
    assert np.array_equal(k_r, np.sort(x.reshape(-1)))
    assert np.array_equal(k_r, k_s)
    for a, b in zip(v_r, v_s):  # payload parity == stability parity
        assert np.array_equal(a, b)
