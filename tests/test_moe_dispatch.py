"""MoE sort-based dispatch == dense-evaluation reference (the paper's
stability guarantee means the permutation must be exactly inverted)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import moe as moe_mod
import pytest

pytestmark = pytest.mark.fast


def _setup(E=4, k=2, T=64, D=32, F=16):
    cfg = dataclasses.replace(
        get_arch("granite-moe-1b-a400m").reduced(),
        moe_experts=E,
        moe_top_k=k,
        d_model=D,
        d_ff=F,
    )
    rng = jax.random.key(0)
    p = moe_mod.init_moe(rng, cfg, layers=1)
    lp = jax.tree.map(lambda a: a[0], p)
    x = jax.random.normal(jax.random.key(1), (2, T // 2, D)).astype(jnp.bfloat16)
    return cfg, lp, x


def _dense_reference(cfg, lp, x):
    """y = Σ_k prob_k · FFN_{e_k}(x) computed without any dispatch."""
    *lead, D = x.shape
    x2d = x.reshape(-1, D)
    probs, experts, _ = moe_mod._router(x2d, lp["router"], cfg.moe_top_k)
    y = jnp.zeros_like(x2d)
    for e in range(cfg.moe_experts):
        w = (probs * (experts == e)).sum(-1).astype(x.dtype)
        fe = moe_mod._expert_ffn(x2d, lp["w_gate"][e], lp["w_up"][e], lp["w_down"][e])
        y = y + w[:, None] * fe
    return y.reshape(*lead, D)


def test_tp_grouped_gemm_matches_dense():
    cfg, lp, x = _setup()
    ref = _dense_reference(cfg, lp, x)
    got, aux = moe_mod.moe_tp(lp, x, cfg, capacity_factor=4.0)  # ample capacity
    assert not bool(aux["overflow"])
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=3e-2, rtol=3e-2
    )


def test_tp_capacity_overflow_is_detected_not_silent():
    # n = T·k must exceed the small-batch full-capacity regime (n ≤ 512)
    cfg, lp, x = _setup(E=8, k=8, T=256)
    _, aux = moe_mod.moe_tp(lp, x, cfg, capacity_factor=0.01)
    assert bool(aux["overflow"])


def test_ep_single_device_path_matches_dense():
    cfg, lp, x = _setup()
    ref = _dense_reference(cfg, lp, x)
    got, aux = moe_mod.moe_ep(
        lp, x, cfg, moe_mod.MoEMeshInfo(), capacity_factor=4.0
    )
    assert not bool(aux["overflow"])
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=3e-2, rtol=3e-2
    )


def test_ep_safe_escalates_capacity_instead_of_dropping():
    """EP dispatch through the sort driver's tier ladder: an undersized
    capacity_factor is a retriable fault, not silent token drop — the ladder
    escalates to the full tier and the output still matches dense."""
    cfg, lp, x = _setup()
    ref = _dense_reference(cfg, lp, x)
    got, aux, stats = moe_mod.moe_ep_safe(
        lp, x, cfg, moe_mod.MoEMeshInfo(), capacity_factor=0.01
    )
    assert not bool(aux["overflow"])
    assert stats.retries >= 1 and stats.last_tier == "full", stats.as_row()
    assert stats.attempts.get("whp") == 1  # the guess was tried exactly once
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=3e-2, rtol=3e-2
    )


def test_ep_safe_benign_capacity_stays_on_whp_tier():
    """With ample capacity the ladder must not escalate, and TierStats rows
    stay driver-compatible (same counters the serve engine consumes)."""
    cfg, lp, x = _setup()
    got, aux, stats = moe_mod.moe_ep_safe(
        lp, x, cfg, moe_mod.MoEMeshInfo(), capacity_factor=4.0
    )
    assert stats.retries == 0 and stats.last_tier == "whp"
    row = stats.as_row()
    assert row["tier_whp"] == 1 and row["ok_whp"] == 1 and row["retries"] == 0
    ref = _dense_reference(cfg, lp, x)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=3e-2, rtol=3e-2
    )


def test_router_aux_losses_shapes():
    cfg, lp, x = _setup()
    _, aux = moe_mod.moe_tp(lp, x, cfg)
    assert aux["lb_loss"].shape == () and aux["z_loss"].shape == ()
    assert float(aux["lb_loss"]) >= 0.99  # ≥1 with equality at perfect balance


def test_ep_safe_planner_policy_stops_paying_doomed_whp():
    """Optional capacity-planner policy on the EP ladder: a config whose
    whp capacity guess keeps dropping tokens starts at the learned rung
    after enough evidence — later calls skip the doomed whp attempt while
    the output still matches dense."""
    from repro.planner import CapacityPlanner

    cfg, lp, x = _setup()
    ref = _dense_reference(cfg, lp, x)
    pl = CapacityPlanner(fault_target=0.05, min_attempts=2)
    for _ in range(4):  # undersized guess: whp faults every call
        got, aux, stats = moe_mod.moe_ep_safe(
            lp, x, cfg, moe_mod.MoEMeshInfo(), capacity_factor=0.01, planner=pl
        )
        assert not bool(aux["overflow"])
    (bucket,) = pl.history
    assert bucket.startswith("moe/") and pl.history[bucket]["rung"] >= 1
    got, aux, stats = moe_mod.moe_ep_safe(
        lp, x, cfg, moe_mod.MoEMeshInfo(), capacity_factor=0.01, planner=pl
    )
    assert "whp" not in stats.attempts, stats.as_row()  # learned start
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=3e-2, rtol=3e-2
    )


def test_ep_safe_radix_route_true_count_capacity_no_fallback():
    """Count-then-distribute EP dispatch: the router-only counting pass
    sizes the receive buffer from the true per-(src,dst) counts, so the
    single rung serves with zero retries and never touches the ladder's
    full (p·n) tier — even with a capacity_factor guess that would doom
    the whp rung."""
    cfg, lp, x = _setup()
    ref = _dense_reference(cfg, lp, x)
    got, aux, stats = moe_mod.moe_ep_safe(
        lp, x, cfg, moe_mod.MoEMeshInfo(), capacity_factor=0.01, route="radix"
    )
    assert not bool(aux["overflow"])
    assert stats.attempts == {"radix": 1}, stats.as_row()
    assert stats.retries == 0 and stats.last_tier == "radix"
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=3e-2, rtol=3e-2
    )


def test_ep_counts_match_dispatch_counts():
    """The counting pass and the dispatch body must route identically: the
    counted max bounds every per-destination count the dispatch computes
    (equality at p=1: all records to the one shard)."""
    cfg, lp, x = _setup()
    pair_true = int(moe_mod.moe_ep_counts(lp, x, cfg, moe_mod.MoEMeshInfo()))
    T = x.shape[0] * x.shape[1]
    assert pair_true == T * cfg.moe_top_k  # p=1: every record -> shard 0
