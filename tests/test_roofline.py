"""Roofline HLO parsers: unit tests on synthetic HLO text."""
import pytest

from repro.roofline.analysis import (
    _execution_multipliers,
    _split_computations,
    parse_collective_bytes,
    parse_dot_stats,
    scan_trip_factor,
)

pytestmark = pytest.mark.fast

HLO = """\
HloModule jit_step

%body.1 (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = f32[8,16]{1,0} parameter(0)
  %ag = f32[8,64]{1,0} all-gather(%p), replica_groups=[4,4]<=[16], dimensions={1}
  %d = f32[8,64]{1,0} dot(%ag, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,64]{1,0} all-reduce(%d), replica_groups={{0,1,2,3}}, to_apply=%add.1
}

%cond.1 (arg: (s32[], f32[8,16])) -> pred[] {
  %lt = pred[] compare(%i, %n), direction=LT
}

%add.1 (a: f32[], b: f32[]) -> f32[] {
  %r = f32[] add(%a, %b)
}

ENTRY %main.1 (x: f32[8,16]) -> f32[8,16] {
  %w = f32[64,64]{1,0} parameter(1)
  %wh = (s32[], f32[8,16]) while(%t), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
  %cp = f32[8,16]{1,0} collective-permute(%x), source_target_pairs={{0,1}}
}
"""


def test_split_and_multipliers():
    comps = _split_computations(HLO)
    assert "body.1" in comps and "main.1" in comps
    mult = _execution_multipliers(comps)
    assert mult["main.1"] == 1.0
    assert mult["body.1"] == 10.0


def test_trip_factor():
    assert scan_trip_factor(HLO) == 10.0


def test_collective_bytes_trip_scaled():
    out = parse_collective_bytes(HLO, default_group=4)
    # all-gather result 8·64·4 B = 2048 B, ring (g-1)/g with g=4 → 1536 ×10
    assert abs(out["all-gather"] - 1536 * 10) < 1
    # all-reduce: 2 · 2048 · 3/4 = 3072 ×10
    assert abs(out["all-reduce"] - 3072 * 10) < 1
    # permute in ENTRY: 8·16·4 = 512, ×1
    assert abs(out["collective-permute"] - 512) < 1


def test_dot_stats_trip_scaled():
    out = parse_dot_stats(HLO)
    # dot: result 8·64, K = lhs dim1 = 64 → 2·8·64·64 = 65536 flops ×10
    assert abs(out["dot_flops"] - 65536 * 10) < 1


def test_real_compile_end_to_end():
    """Tiny single-device compile: the analyzer runs and terms are finite."""
    import jax, jax.numpy as jnp
    from repro.roofline.analysis import analyze_compiled
    from repro.configs import get_arch
    from repro.configs.base import ShapeConfig

    class FakeMesh:
        shape = {"data": 1, "model": 1}

    def f(a, b):
        return jnp.einsum("ij,jk->ik", a, b).sum()

    comp = (
        jax.jit(f)
        .lower(
            jax.ShapeDtypeStruct((128, 128), jnp.float32),
            jax.ShapeDtypeStruct((128, 128), jnp.float32),
        )
        .compile()
    )
    info = analyze_compiled(
        comp,
        mesh=FakeMesh(),
        cfg=get_arch("tinyllama-1.1b").reduced(),
        shape=ShapeConfig("t", 16, 2, "train"),
    )
    assert info["dot_flops_per_dev"] >= 2 * 128 * 128 * 128
    assert info["dominant"] in ("compute", "memory", "collective")
