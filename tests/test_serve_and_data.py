"""Serving engine, sampling, and the data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.data import length_bucketed_order, synthetic_batch
from repro.models import Model
from repro.serve import ServeConfig, ServeEngine, sample
import pytest

pytestmark = pytest.mark.fast


def test_greedy_sampling_is_argmax():
    logits = jnp.asarray(np.random.default_rng(0).standard_normal((4, 100)).astype(np.float32))
    toks = sample(logits, jax.random.key(0), temperature=0.0)
    assert np.array_equal(toks, np.argmax(np.asarray(logits), -1))


def test_topk_sampling_stays_in_topk():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.standard_normal((8, 100)).astype(np.float32))
    k = 5
    topk = np.argsort(-np.asarray(logits), -1)[:, :k]
    for i in range(20):
        toks = np.asarray(sample(logits, jax.random.key(i), top_k=k))
        for b in range(8):
            assert toks[b] in topk[b]


def test_serve_engine_generates_and_respects_eos():
    cfg = get_arch("tinyllama-1.1b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, ServeConfig(max_new_tokens=6, top_k=4, eos_id=1))
    out = eng.generate(jnp.zeros((3, 8), jnp.int32))
    assert out.shape == (3, 6)
    out = np.asarray(out)
    for b in range(3):  # after first EOS everything stays EOS
        hits = np.where(out[b] == 1)[0]
        if hits.size:
            assert (out[b, hits[0] :] == 1).all()


def test_pipeline_is_stateless_seeded():
    cfg = get_arch("tinyllama-1.1b").reduced()
    shape = ShapeConfig("t", 16, 2, "train")
    b1 = synthetic_batch(cfg, shape, 7)
    b2 = synthetic_batch(cfg, shape, 7)
    assert np.array_equal(b1["tokens"], b2["tokens"])  # restart-exact
    b3 = synthetic_batch(cfg, shape, 8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_length_bucketing_via_bsp_sort():
    lens = np.random.default_rng(0).integers(1, 5000, 999).astype(np.int32)
    order = length_bucketed_order(lens, p=8)
    assert len(order) == 999
    assert (np.diff(lens[order]) >= 0).all()
    assert sorted(order.tolist()) == list(range(999))  # a permutation


def test_length_bucketing_survives_degenerate_lengths():
    """All-equal lengths are the adversarial one-bucket case: the safe driver
    must return every doc id exactly once (a scheduler that loses requests is
    not a scheduler)."""
    lens = np.full(777, 2048, np.int32)
    order = length_bucketed_order(lens, p=8, algorithm="iran")
    assert sorted(order.tolist()) == list(range(777))


def test_serve_engine_continuous_batching_refills_retired_slots():
    """A short sequence retires early and a queued request takes its slot
    mid-flight; every request's stream must equal the lockstep greedy
    reference (slot refill may not disturb the other lanes)."""
    cfg = get_arch("tinyllama-1.1b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(
        model, params, ServeConfig(max_new_tokens=6, temperature=0.0, eos_id=1)
    )
    rng = np.random.default_rng(0)
    prompts = [rng.integers(5, 50, 8).astype(np.int32) for _ in range(4)]
    # request 0 has a 2-token budget: it retires while the others are still
    # decoding, freeing its slot for the first queued request
    outs = eng.serve(prompts, slots=2, max_new=[2, 6, 6, 6])
    assert eng.refills >= 1  # the queue actually backfilled a retired slot
    # the backfilled prefill was launched AHEAD of the retirement (double-
    # buffered admission), not synchronously inside the refill
    assert eng.admission_prefetches >= eng.refills
    assert [len(o) for o in outs] == [2, 6, 6, 6]
    ref = np.asarray(eng.generate(jnp.asarray(np.stack(prompts))))
    for i, o in enumerate(outs):  # greedy ⇒ byte-comparable per request
        assert np.array_equal(o, ref[i][: len(o)]), (i, o, ref[i])
    # admission ordering ran through the sort driver at least once
    assert sum(eng.capacity_stats.attempts.values()) >= 1


def test_serve_engine_continuous_batching_edge_budgets():
    """Empty queue returns []; zero-budget requests retire with an empty
    stream without ever occupying a slot or emitting a prefill token."""
    cfg = get_arch("tinyllama-1.1b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(
        model, params, ServeConfig(max_new_tokens=4, temperature=0.0, eos_id=1)
    )
    assert eng.serve([]) == []
    rng = np.random.default_rng(2)
    prompts = [rng.integers(5, 50, 8).astype(np.int32) for _ in range(4)]
    outs = eng.serve(prompts, slots=2, max_new=[0, 3, 0, 3])
    assert [len(o) for o in outs] == [0, 3, 0, 3]
    outs0 = eng.serve(prompts, slots=2, max_new=[0, 0, 0, 0])
    assert [len(o) for o in outs0] == [0, 0, 0, 0]


def test_serve_engine_continuous_batching_eos_retirement():
    """EOS-based retirement also frees the slot; outputs are EOS-truncated."""
    cfg = get_arch("tinyllama-1.1b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(
        model, params, ServeConfig(max_new_tokens=4, temperature=0.0, eos_id=1)
    )
    rng = np.random.default_rng(1)
    prompts = [rng.integers(5, 50, 6).astype(np.int32) for _ in range(3)]
    outs = eng.serve(prompts, slots=1, max_new=[1, 1, 4])
    assert len(outs) == 3 and eng.refills == 2  # serial slot: 2 backfills
    for o in outs:
        assert 1 <= len(o) <= 4
        if 1 in o.tolist():
            assert o.tolist().index(1) == len(o) - 1  # truncated at EOS


def test_serve_engine_admission_order_tracks_capacity_stats():
    cfg = get_arch("tinyllama-1.1b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, ServeConfig(max_new_tokens=2))
    lens = np.random.default_rng(3).integers(1, 4096, 333).astype(np.int32)
    order = eng.admission_order(lens)
    assert sorted(order.tolist()) == list(range(333))
    assert (np.diff(lens[order]) >= 0).all()
    assert sum(eng.capacity_stats.attempts.values()) >= 1
    # adversarial burst: every request the same length — ids must survive
    order2 = eng.admission_order(np.full(333, 512, np.int32))
    assert sorted(order2.tolist()) == list(range(333))


def test_admission_sort_p_derives_from_mesh():
    """The admission sort's processor count comes from the engine's mesh
    (largest pow2 ≤ device count), not a hardcoded 8 — a sharded engine
    must bucket for its actual topology."""
    import types

    from repro.serve.engine import _mesh_sort_p

    assert _mesh_sort_p(None) == 8
    assert _mesh_sort_p(types.SimpleNamespace(devices=np.zeros((2, 4)))) == 8
    assert _mesh_sort_p(types.SimpleNamespace(devices=np.zeros((4, 4)))) == 16
    assert _mesh_sort_p(types.SimpleNamespace(devices=np.zeros((6,)))) == 4
    assert _mesh_sort_p(types.SimpleNamespace(devices=np.zeros((1,)))) == 1


def test_admission_order_explicit_p_override_and_service_telemetry():
    cfg = get_arch("tinyllama-1.1b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, ServeConfig(max_new_tokens=2))
    assert eng.sort_p == 8 and eng.sort_service.cfg.p == 8  # no mesh default
    lens = np.random.default_rng(5).integers(1, 2048, 100).astype(np.int32)
    order = eng.admission_order(lens, p=4)  # explicit override still works
    assert sorted(order.tolist()) == list(range(100))
    assert (np.diff(lens[order]) >= 0).all()
    # the default path goes through the engine's sort service and its
    # telemetry (latency per admission sort) accumulates
    before = len(eng.sort_service.latencies)
    eng.admission_order(lens)
    assert len(eng.sort_service.latencies) == before + 1
    assert sum(eng.capacity_stats.attempts.values()) >= 1
