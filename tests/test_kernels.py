"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.bitonic import ops as bops, ref as bref
from repro.kernels.merge_path import ops as mops, ref as mref
from repro.kernels.searchsorted import ops as sops, ref as sref

settings.register_profile("ci", deadline=None, max_examples=15)
settings.load_profile("ci")


@pytest.mark.parametrize("dtype", [np.int32, np.uint32, np.float32])
@pytest.mark.parametrize("shape", [(1, 17), (5, 100), (8, 1000), (3, 4096), (2, 16384)])
def test_bitonic_sort_sweep(dtype, shape):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 2**20, shape).astype(dtype))
    assert np.array_equal(bops.sort(x), bref.sort(x))


def test_bitonic_sort_bf16():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((4, 333)).astype(np.float32)).astype(jnp.bfloat16)
    assert np.array_equal(np.asarray(bops.sort(x)), np.asarray(bref.sort(x)))


def test_bitonic_multi_tile():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.integers(0, 2**31, (2, 40000)).astype(np.int32))
    assert np.array_equal(bops.sort(x), bref.sort(x))


def test_bitonic_kv_multiset_and_permutation():
    rng = np.random.default_rng(3)
    k = jnp.asarray(rng.integers(0, 50, (4, 500)).astype(np.int32))
    v = jnp.arange(4 * 500, dtype=jnp.int32).reshape(4, 500)
    ko, vo = bops.sort_kv(k, v)
    kr, _ = bref.sort_kv(k, v)
    assert np.array_equal(ko, kr)
    for r in range(4):  # values remain a permutation consistent with keys
        assert np.array_equal(np.asarray(k)[r][np.asarray(vo)[r] % 500], np.asarray(ko)[r])


@pytest.mark.parametrize("na,nb", [(100, 200), (1000, 1000), (17, 4096), (1, 1)])
def test_merge_sweep(na, nb):
    rng = np.random.default_rng(4)
    a = jnp.sort(jnp.asarray(rng.integers(0, 1000, (3, na)).astype(np.int32)), axis=-1)
    b = jnp.sort(jnp.asarray(rng.integers(0, 1000, (3, nb)).astype(np.int32)), axis=-1)
    assert np.array_equal(mops.merge(a, b), mref.merge(a, b))


@pytest.mark.parametrize("n,s", [(100, 3), (1000, 7), (5000, 31), (2048, 255)])
def test_searchsorted_sweep(n, s):
    rng = np.random.default_rng(5)
    x = jnp.sort(jnp.asarray(rng.integers(0, 50, n).astype(np.int32)))
    sk = jnp.asarray(rng.integers(0, 50, s).astype(np.int32))
    sp = jnp.asarray(rng.integers(0, 8, s).astype(np.int32))
    si = jnp.asarray(rng.integers(0, n, s).astype(np.int32))
    me = jnp.asarray(3, jnp.int32)
    got = sops.splitter_ranks(x, sk, sp, si, me)
    want = sref.splitter_ranks(x, sk, sp, si, me)
    assert np.array_equal(got, want)


@given(
    st.integers(min_value=1, max_value=8),
    st.integers(min_value=2, max_value=2048),
    st.integers(min_value=0, max_value=10**6),
)
def test_bitonic_hypothesis(rows, width, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-1000, 1000, (rows, width)).astype(np.int32))
    assert np.array_equal(bops.sort(x), bref.sort(x))


@given(st.integers(min_value=1, max_value=1024), st.integers(min_value=0, max_value=10**6))
def test_merge_hypothesis(width, seed):
    rng = np.random.default_rng(seed)
    a = jnp.sort(jnp.asarray(rng.standard_normal((2, width)).astype(np.float32)), axis=-1)
    b = jnp.sort(jnp.asarray(rng.standard_normal((2, width)).astype(np.float32)), axis=-1)
    assert np.array_equal(mops.merge(a, b), mref.merge(a, b))
