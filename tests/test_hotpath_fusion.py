"""Fused h-relation + payload-generic rank-merge tail (the hotpath PR).

Covers the three acceptance surfaces:

* the fused exchange is *byte-identical* to the per-array layout (packing is
  a bitcast, so this must hold bit-exactly) across mixes, key-only and
  key-value, on clean runs — and agrees on the overflow flag on faulted ones;
* the payload-generic ``merge="tree"`` tail is byte-identical to the
  ``merge_by_sort`` tail (keys, counts AND payloads), including the int64
  segmented composites and the ``merge_backend="pallas"`` substrate;
* HLO regression: the fused a2a path emits exactly ONE ``all_to_all`` per
  data superstep (+ the (p,)-word count bookkeeping superstep) regardless of
  payload count, counted on the real ``shard_map`` lowering in a subprocess
  with forced host devices (the vmap runner batches collectives away).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SortConfig,
    bsp_sort,
    bsp_sort_safe,
    datagen,
    gathered_output,
)
from repro.core import routing

P, NP = 8, 512
MIXES = ["U", "G", "B", "DD", "zipf"]


def _run_cfg(x, values, **kw):
    res, vb = bsp_sort(x, SortConfig(p=P, n_per_proc=NP, **kw), values=values)
    return (
        bool(res.overflow),
        np.asarray(res.buf),
        np.asarray(res.count),
        [np.asarray(v) for v in vb],
    )


def _assert_same(got, ref, where):
    assert got[0] == ref[0], (where, "overflow flag")
    if got[0]:  # faulted buffers are discarded by the driver: flag-only
        return
    assert np.array_equal(got[1], ref[1]), (where, "buf")
    assert np.array_equal(got[2], ref[2]), (where, "count")
    for a, b in zip(got[3], ref[3]):
        assert np.array_equal(a, b), (where, "values")


@pytest.mark.fast
@pytest.mark.parametrize("kv", [0, 1])
def test_fused_exchange_byte_identical_to_per_array(kv):
    ids = jnp.arange(P * NP, dtype=jnp.int32).reshape(P, NP)
    vals = (ids,) if kv else ()
    for mix in MIXES:
        x = jnp.asarray(datagen.generate(mix, P, NP, seed=7))
        for pc in ("exact", "whp"):
            for merge in ("sort", "tree"):
                ref = _run_cfg(
                    x, vals, algorithm="iran", pair_capacity=pc, merge=merge,
                    exchange="per_array",
                )
                got = _run_cfg(
                    x, vals, algorithm="iran", pair_capacity=pc, merge=merge,
                    exchange="fused",
                )
                _assert_same(got, ref, (mix, pc, merge, kv))


@pytest.mark.fast
@pytest.mark.parametrize("kv", [0, 1])
def test_tree_tail_byte_identical_to_sort_tail(kv):
    """Keys, counts AND payloads of merge="tree" == merge_by_sort, plus the
    safe driver delivering the complete sorted output through the tree tail
    on every mix (DD/zipf escalate past whp at this p)."""
    ids = jnp.arange(P * NP, dtype=jnp.int32).reshape(P, NP)
    vals = (ids,) if kv else ()
    for mix in MIXES:
        x = jnp.asarray(datagen.generate(mix, P, NP, seed=9))
        for pc in ("exact", "whp"):
            ref = _run_cfg(x, vals, algorithm="iran", pair_capacity=pc, merge="sort")
            got = _run_cfg(x, vals, algorithm="iran", pair_capacity=pc, merge="tree")
            _assert_same(got, ref, (mix, pc, kv))
        res, vb, _ = bsp_sort_safe(
            x,
            SortConfig(
                p=P, n_per_proc=NP, algorithm="iran", pair_capacity="whp",
                merge="tree",
            ),
            values=vals,
        )
        assert np.array_equal(
            gathered_output(res), np.sort(np.asarray(x).ravel())
        ), mix
        if kv:
            cnt = np.asarray(res.count)
            vout = np.concatenate(
                [np.asarray(vb[0])[k, : cnt[k]] for k in range(P)]
            )
            assert np.array_equal(
                np.asarray(x).ravel()[vout], gathered_output(res)
            ), mix


@pytest.mark.fast
@pytest.mark.parametrize("kv", [0, 1])
def test_tree_tail_pallas_backend_byte_identical(kv):
    """merge_backend="pallas" (interpret on CPU): same bytes as the XLA tail
    — key-only pairs take the merge-path partitioned network merge, key-value
    pairs the masked-count rank kernel."""
    ids = jnp.arange(P * NP, dtype=jnp.int32).reshape(P, NP)
    vals = (ids,) if kv else ()
    for mix in ("U", "DD"):
        x = jnp.asarray(datagen.generate(mix, P, NP, seed=11))
        ref = _run_cfg(x, vals, algorithm="det", merge="tree")
        got = _run_cfg(
            x, vals, algorithm="det", merge="tree", merge_backend="pallas"
        )
        _assert_same(got, ref, (mix, kv, "pallas"))


@pytest.mark.fast
def test_ring_fused_visitor_block_byte_identical():
    ids = jnp.arange(P * NP, dtype=jnp.int32).reshape(P, NP)
    x = jnp.asarray(datagen.generate("DD", P, NP, seed=13))
    for kv in (0, 1):
        vals = (ids,) if kv else ()
        ref = _run_cfg(
            x, vals, algorithm="det", routing="ring", exchange="per_array"
        )
        got = _run_cfg(x, vals, algorithm="det", routing="ring", exchange="fused")
        _assert_same(got, ref, ("ring", kv))


@pytest.mark.fast
def test_segmented_composites_ride_tree_tail():
    """The int64 (segment, key) composites + pos payload through merge="tree"
    — byte-identical per-segment outputs, at both the service knob and the
    sort_segments override level."""
    from repro.core import sort_segments
    from repro.core.api import SortExecutor
    from repro.service import ServiceConfig, SortService

    sizes = datagen.zipf_sizes(12, 4096, seed=3)
    arrays = [
        datagen.generate(MIXES[i % len(MIXES)], 1, int(s), seed=50 + i)[0]
        for i, s in enumerate(sizes)
    ]
    a = sort_segments(arrays, p=P, merge="sort", executor=SortExecutor())
    b = sort_segments(arrays, p=P, merge="tree", executor=SortExecutor())
    for ka, kb, oa, ob in zip(a.keys, b.keys, a.order, b.order):
        assert np.array_equal(ka, kb)
        assert np.array_equal(oa, ob)

    svc = SortService(ServiceConfig(p=P, merge="tree"), executor=SortExecutor())
    for arr, r in zip(arrays, svc.sort_many(arrays)):
        assert np.array_equal(r.keys, np.sort(arr))
        assert np.array_equal(arr[r.order], r.keys)
    assert svc.stats.retries == 0, svc.stats.as_row()


@pytest.mark.fast
def test_pack_bytes_roundtrip_mixed_dtypes():
    """The fused-exchange packing is a bitcast: bit-exact for every dtype and
    trailing shape the routing/MoE layers ship."""
    rng = np.random.default_rng(0)
    rows = [
        jnp.asarray(rng.integers(-(2**31), 2**31 - 1, (4, 16), dtype=np.int64).astype(np.int32)),
        jnp.asarray(rng.standard_normal((4, 16, 3)).astype(np.float32)),
        jnp.asarray(rng.standard_normal((4, 16)).astype(np.float32)).astype(jnp.bfloat16),
        jnp.asarray(rng.integers(0, 127, (4, 16), dtype=np.int64).astype(np.int8)),
    ]
    buf, metas = routing.pack_bytes(rows, lead=2)
    assert buf.dtype == jnp.uint8 and buf.shape[:2] == (4, 16)
    out = routing.unpack_bytes(buf, metas, lead=2)
    for o, r in zip(out, rows):
        assert o.dtype == r.dtype and o.shape == r.shape
        assert np.array_equal(np.asarray(o), np.asarray(r))

    flat_in = [rows[0], jnp.arange(9, dtype=jnp.int32)]  # mixed shapes
    vec, fmetas = routing.pack_bytes_flat(flat_in)
    for o, r in zip(routing.unpack_bytes_flat(vec, fmetas), flat_in):
        assert np.array_equal(np.asarray(o), np.asarray(r))


@pytest.mark.fast
def test_hlo_exactly_one_all_to_all_per_data_superstep():
    """HLO regression on the real shard_map lowering (8 forced host devices,
    subprocess — the shared benchmarks.common harness, so the ``hotpath``
    table's identity column counts the same way): the fused path lowers to
    exactly 2 all_to_all ops — the (p,)-word Ph4 count bookkeeping plus ONE
    data superstep — independent of payload count, while per-array pays
    2 + R. The allgather schedule gets the same fusion (boundary bookkeeping
    + one data gather). Lowering only; nothing is compiled or executed."""
    from benchmarks.common import sharded_collective_counts

    combos = {
        f"{routing}/{exchange}/{nv}": dict(
            algorithm="iran", pair_capacity="whp", routing=routing,
            exchange=exchange, nv=nv,
        )
        for routing in ("a2a_dense", "allgather")
        for exchange in ("per_array", "fused")
        for nv in (0, 1, 2)
    }
    counts = sharded_collective_counts(combos, p=8)
    for c in counts.values():  # rename for the assertions below
        c["a2a"], c["ag"] = c["all_to_all"], c["all_gather"]
    for nv in (0, 1, 2):
        # per-array: count superstep + one collective per array (key + R)
        assert counts[f"a2a_dense/per_array/{nv}"]["a2a"] == 2 + nv, counts
        # fused: count superstep + exactly ONE data superstep, any R
        assert counts[f"a2a_dense/fused/{nv}"]["a2a"] == 2, counts
        # sanity: the fused payload rows ride the a2a, not a hidden gather
        assert (
            counts[f"a2a_dense/fused/{nv}"]["ag"]
            == counts[f"a2a_dense/per_array/{nv}"]["ag"]
        ), counts
    # allgather routing: the sample-stage gathers + boundary bookkeeping +
    # data gathers. "all_gather" appears a fixed number of times per op in
    # the StableHLO text, so compare *deltas* against the nv=0 graph (where
    # fused == per-array by construction): per-array grows one gather per
    # payload, fused none.
    base = counts["allgather/fused/0"]["ag"]
    assert counts["allgather/per_array/0"]["ag"] == base, counts
    per_op = (counts["allgather/per_array/2"]["ag"] - base) // 2
    assert per_op > 0, counts
    for nv in (1, 2):
        assert (
            counts[f"allgather/per_array/{nv}"]["ag"] == base + per_op * nv
        ), counts
        assert counts[f"allgather/fused/{nv}"]["ag"] == base, counts
