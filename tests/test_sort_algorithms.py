"""End-to-end correctness of the BSP sorting algorithms (paper §5/§6)."""
import jax
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    SortConfig,
    SortExecutor,
    TierStats,
    bsp_sort,
    bsp_sort_safe,
    datagen,
    gathered_output,
)

P, NP = 8, 1024


def _adversarial(p=P, n_p=NP):
    """Constant-per-proc runs: every local run aims at ONE bucket, which
    overflows any w.h.p. pair capacity."""
    return np.repeat((np.arange(p, dtype=np.int32) * 1000)[:, None], n_p, axis=1)


def _check(x, algo, **kw):
    res, _ = bsp_sort(jnp.asarray(x), algorithm=algo, **kw)
    out = gathered_output(res)
    ref = np.sort(np.asarray(x).reshape(-1))
    return np.array_equal(out, ref), res


@pytest.mark.parametrize("algo", ["det", "iran", "ran", "bitonic"])
@pytest.mark.parametrize("dist", ["U", "G", "B", "2-G", "S", "DD", "WR"])
def test_all_algorithms_all_distributions(algo, dist):
    x = datagen.generate(dist, P, NP, seed=1)
    ok, res = _check(x, algo)
    if algo == "ran" and dist == "DD":
        # classic sample-sort without §5.1.1 duplicate handling collapses on
        # duplicate-heavy inputs — the fault must be *surfaced*, not silent.
        assert bool(res.overflow) or ok
        return
    assert not bool(res.overflow)
    assert ok


@pytest.mark.fast
@pytest.mark.parametrize("routing", ["a2a_dense", "allgather", "ring"])
@pytest.mark.parametrize("merge", ["sort", "tree"])
def test_routing_and_merge_schedules(routing, merge):
    if routing == "ring" and merge == "tree":
        pytest.skip("ring always compacts (merge=sort)")
    x = datagen.generate("U", P, NP, seed=3)
    ok, res = _check(x, "det", routing=routing, merge=merge)
    assert ok and not bool(res.overflow)


@pytest.mark.fast
@pytest.mark.parametrize("local_sort", ["lax", "radix", "bitonic"])
def test_local_sort_methods(local_sort):
    x = datagen.generate("U", P, NP, seed=4)
    ok, _ = _check(x, "det", local_sort=local_sort)
    assert ok


def test_whp_pair_capacity_production_mode():
    x = datagen.generate("U", P, 4096, seed=5)
    ok, res = _check(x, "iran", pair_capacity="whp")
    assert ok and not bool(res.overflow)


@pytest.mark.fast
def test_lemma_5_1_receive_bound():
    """Max keys per processor ≤ n_max = (1+1/⌈ω⌉)(n/p) + ⌈ω⌉p (+padding)."""
    for dist in ["U", "B", "S", "DD", "WR"]:
        x = datagen.generate(dist, P, NP, seed=7)
        cfg = SortConfig(p=P, n_per_proc=NP, algorithm="det")
        res, _ = bsp_sort(jnp.asarray(x), cfg)
        assert int(np.max(np.asarray(res.count))) <= cfg.n_max, dist


def test_duplicate_stability_key_value():
    """§5.1.1: with all-equal and heavy-duplicate keys the output is the
    *stable* sort — payload order within equal keys preserved."""
    for maker in (
        lambda: np.zeros((P, NP), np.int32),  # all keys equal
        lambda: datagen.generate("DD", P, NP, seed=1),
    ):
        x = maker()
        vals = np.arange(P * NP, dtype=np.int32).reshape(P, NP)
        res, vbufs = bsp_sort(
            jnp.asarray(x), algorithm="det", values=(jnp.asarray(vals),)
        )
        cnt = np.asarray(res.count)
        buf = np.asarray(vbufs[0])
        vout = np.concatenate([buf[k, : cnt[k]] for k in range(P)])
        kout = gathered_output(res)
        xflat = x.reshape(-1)
        assert np.array_equal(xflat[vout], kout)  # a permutation
        for v in np.unique(kout):
            sel = vout[kout == v]
            assert (np.diff(sel) > 0).all()  # stable within equal keys


@pytest.mark.fast
def test_safe_driver_escalates_on_adversarial_input():
    """Acceptance: an all-keys-to-one-bucket input (each proc's run constant)
    overflows the w.h.p. pair capacity; the escalation driver must retry at
    higher tiers and deliver the complete sorted output — no key dropped."""
    x = np.repeat((np.arange(P, dtype=np.int32) * 1000)[:, None], NP, axis=1)
    cfg = SortConfig(p=P, n_per_proc=NP, algorithm="iran", pair_capacity="whp")

    # the unsafe sort faults (and would silently truncate if trusted)
    res_unsafe, _ = bsp_sort(jnp.asarray(x), cfg)
    assert bool(res_unsafe.overflow)

    stats = TierStats()
    res, _, stats = bsp_sort_safe(jnp.asarray(x), cfg, stats=stats)
    assert not bool(res.overflow)
    assert np.array_equal(gathered_output(res), np.sort(x.reshape(-1)))
    assert stats.retries >= 1, stats.as_row()  # at least one tier escalation
    assert stats.attempts.get("whp", 0) == 1 and stats.last_tier != "whp"


@pytest.mark.fast
def test_safe_driver_benign_input_stays_on_whp_tier():
    """On well-behaved input the ladder must not escalate (no wasted work),
    and the terminal allgather tier must also sort standalone."""
    x = datagen.generate("U", P, NP, seed=13)
    cfg = SortConfig(p=P, n_per_proc=NP, algorithm="iran", pair_capacity="whp")
    res, _, stats = bsp_sort_safe(jnp.asarray(x), cfg)
    assert stats.retries == 0 and stats.last_tier == "whp"
    assert np.array_equal(gathered_output(res), np.sort(x.reshape(-1)))
    # terminal tier standalone: full-size receive buffer, overflow impossible
    _, terminal = cfg.tier_ladder()[-1]
    assert terminal.routing == "allgather" and terminal.n_max >= cfg.n
    res2, _ = bsp_sort(jnp.asarray(x), terminal)
    assert not bool(res2.overflow)
    assert np.array_equal(gathered_output(res2), np.sort(x.reshape(-1)))


@pytest.mark.fast
def test_safe_driver_key_value_payload_survives_escalation():
    """Payloads must ride through the retry ladder intact (MoE dispatch and
    data bucketing depend on the key-value form)."""
    x = np.repeat((np.arange(P, dtype=np.int32)[::-1] * 7)[:, None], NP, axis=1)
    ids = np.arange(P * NP, dtype=np.int32).reshape(P, NP)
    cfg = SortConfig(p=P, n_per_proc=NP, algorithm="iran", pair_capacity="whp")
    res, vbufs, stats = bsp_sort_safe(
        jnp.asarray(x), cfg, values=(jnp.asarray(ids),)
    )
    assert stats.retries >= 1
    cnt = np.asarray(res.count)
    vout = np.concatenate([np.asarray(vbufs[0])[k, : cnt[k]] for k in range(P)])
    kout = gathered_output(res)
    assert np.array_equal(x.reshape(-1)[vout], kout)  # a permutation
    assert np.array_equal(kout, np.sort(x.reshape(-1)))


@pytest.mark.fast
@pytest.mark.parametrize("algo", ["det", "iran", "ran"])
@pytest.mark.parametrize("maker", ["ADV", "DD", "WR"])
def test_resume_equivalence_every_ladder_rung(algo, maker):
    """For every rung, re-entering the route stage on the shared
    ``PreparedSort`` must be byte-identical to a fresh monolithic run at
    that tier with the same per-tier folded rng — keys, counts, overflow
    flag AND carried value arrays, on duplicate-heavy inputs too."""
    x = _adversarial() if maker == "ADV" else datagen.generate(maker, P, NP, seed=5)
    ids = np.arange(P * NP, dtype=np.int32).reshape(P, NP)
    cfg = SortConfig(p=P, n_per_proc=NP, algorithm=algo, pair_capacity="whp")
    ex = SortExecutor()
    xj, vj = jnp.asarray(x), (jnp.asarray(ids),)
    prep = ex.prepare_vmap(cfg, 1)(xj, *vj)
    base = jax.random.key(cfg.seed)
    for i, (tier, tcfg) in enumerate(cfg.tier_ladder()):
        rng_i = jax.random.fold_in(base, i)
        buf, vbufs, cnt, ovf = ex.route_vmap(tcfg, 1)(
            prep, jax.random.key_data(rng_i)
        )
        fres, fvb = bsp_sort(xj, tcfg, values=vj, rng=rng_i)
        assert np.array_equal(np.asarray(buf), np.asarray(fres.buf)), (tier, algo)
        assert np.array_equal(np.asarray(cnt), np.asarray(fres.count)), (tier, algo)
        assert bool(ovf.any()) == bool(fres.overflow), (tier, algo)
        assert np.array_equal(np.asarray(vbufs[0]), np.asarray(fvb[0])), (tier, algo)


@pytest.mark.fast
@pytest.mark.parametrize("algo", ["det", "iran"])
def test_escalation_runs_local_sort_exactly_once(algo, monkeypatch):
    """Acceptance: escalation forced past the whp tier must NOT redo the
    tier-invariant Ph2 work — local_sort executes exactly once. Counted by
    intercepting the algorithm module's local_sort under disable_jit (so
    every call is a real execution, not a cached trace), with the winning
    output still byte-identical to a fresh run at the winning tier."""
    import repro.core.sort_det as det_mod
    import repro.core.sort_iran as iran_mod

    mod = det_mod if algo == "det" else iran_mod
    calls = {"n": 0}
    orig = mod.local_sort

    def counting(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(mod, "local_sort", counting)
    x = jnp.asarray(_adversarial())
    cfg = SortConfig(p=P, n_per_proc=NP, algorithm=algo, pair_capacity="whp")
    stats = TierStats()
    with jax.disable_jit():
        res, _, stats = bsp_sort_safe(
            x, cfg, stats=stats, executor=SortExecutor()
        )
    assert stats.retries >= 1 and stats.attempts.get("whp") == 1  # escalated
    assert calls["n"] == 1, calls
    assert np.array_equal(gathered_output(res), np.sort(np.asarray(x).ravel()))
    # the winning output is exactly a fresh run at the winning tier
    ladder = cfg.tier_ladder()
    i = [t for t, _ in ladder].index(stats.last_tier)
    fres, _ = bsp_sort(
        x, ladder[i][1], rng=jax.random.fold_in(jax.random.key(cfg.seed), i)
    )
    assert np.array_equal(np.asarray(res.buf), np.asarray(fres.buf))


@pytest.mark.fast
def test_vmap_executor_reuses_compiled_callables():
    """Repeated safe sorts with one executor must not re-trace: one trace
    per (stage, tier) key across calls."""
    x = jnp.asarray(_adversarial())
    cfg = SortConfig(p=P, n_per_proc=NP, algorithm="iran", pair_capacity="whp")
    ex = SortExecutor()
    bsp_sort_safe(x, cfg, executor=ex)
    first = dict(ex.trace_counts)
    assert first and all(v == 1 for v in first.values())
    bsp_sort_safe(x, cfg, executor=ex)
    assert dict(ex.trace_counts) == first  # second call: zero new traces
    # ladder rungs share ONE prepare callable (keyed on prepare_key)
    n_prepare = sum(1 for k in first if k[0] == "prepare")
    assert n_prepare == 1


def test_iran_beats_det_imbalance_on_average():
    """Paper §6.4: random oversampling yields tighter balance than regular
    oversampling for comparable sample sizes."""
    x = datagen.generate("U", P, 8192, seed=9)
    imb = {}
    for algo in ("det", "iran"):
        cfg = SortConfig(p=P, n_per_proc=8192, algorithm=algo)
        res, _ = bsp_sort(jnp.asarray(x), cfg)
        imb[algo] = np.max(np.asarray(res.count)) / (8192)
    assert imb["iran"] <= imb["det"] * 1.05  # allow noise


def test_observed_imbalance_within_theory():
    """Paper §6.4: observed key imbalance stayed below the ~20% theoretical
    bound; check ours against theoretical_max_imbalance."""
    from repro.core import theoretical_max_imbalance

    x = datagen.generate("U", P, 8192, seed=11)
    for algo in ("det", "iran"):
        cfg = SortConfig(p=P, n_per_proc=8192, algorithm=algo)
        res, _ = bsp_sort(jnp.asarray(x), cfg)
        observed = np.max(np.asarray(res.count)) / 8192 - 1.0
        bound = theoretical_max_imbalance(cfg) + 0.05
        assert observed <= bound, (algo, observed, bound)
