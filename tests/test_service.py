"""Sort service: segmented fusion, batch forming, telemetry, bench JSON."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    SortConfig,
    SortExecutor,
    bsp_sort_safe,
    datagen,
    gathered_output,
    pack_segments,
    segmented_sort_safe,
    sort_segments,
)
from repro.service import BatchFormer, ServiceConfig, SortService

pytestmark = pytest.mark.fast


def _per_request_reference(keys: np.ndarray, p: int = 8) -> np.ndarray:
    """The pre-service dispatch: one whole overflow-safe BSP sort for this
    single request (sentinel-padded to its own pow2 layout)."""
    n = keys.shape[0]
    n_p = max(8, 1 << (max(1, -(-n // p)) - 1).bit_length())
    pad = p * n_p - n
    x = np.concatenate([keys, np.full(pad, np.iinfo(np.int32).max, np.int32)])
    res, _, _ = bsp_sort_safe(
        jnp.asarray(x.reshape(p, n_p)), algorithm="iran", pair_capacity="whp"
    )
    return gathered_output(res)[:n]


def test_segmented_matches_per_request_sort_byte_identical():
    """Acceptance: the fused segmented sort returns byte-identical output to
    per-request ``bsp_sort_safe`` on every packed segment — ragged sizes,
    duplicate-heavy and zipf mixes included."""
    sizes = datagen.zipf_sizes(24, 4096, seed=21)
    mixes = ["U", "DD", "zipf", "WR"]
    arrays = [
        datagen.generate(mixes[i % len(mixes)], 1, int(s), seed=50 + i)[0]
        for i, s in enumerate(sizes)
    ]
    res = sort_segments(arrays, p=8)
    for i, (a, got) in enumerate(zip(arrays, res.keys)):
        ref = _per_request_reference(a)
        assert got.dtype == ref.dtype == np.int32
        assert np.array_equal(got, ref), i


def test_segmented_ragged_and_empty_segments():
    rng = np.random.default_rng(0)
    arrays = [
        rng.integers(-(2**31), 2**31, s).astype(np.int32)
        for s in [0, 1, 7, 333, 0, 64]
    ]
    res = sort_segments(arrays, p=8)
    assert [len(k) for k in res.keys] == [0, 1, 7, 333, 0, 64]
    for a, k, o in zip(arrays, res.keys, res.order):
        assert np.array_equal(k, np.sort(a))
        assert np.array_equal(a[o], k)  # order is the argsort


def test_segmented_stable_order_on_duplicate_heavy_segments():
    """§5.1.1 carried to segments: within a segment, equal keys keep their
    original order (the ``order`` payload is the *stable* argsort)."""
    arrays = [
        np.zeros(257, np.int32),  # all keys equal
        datagen.generate("DD", 1, 500, seed=2)[0],
        datagen.generate("zipf", 1, 400, seed=3)[0],
    ]
    res = sort_segments(arrays, p=8)
    for a, k, o in zip(arrays, res.keys, res.order):
        assert np.array_equal(a[o], k)
        for v in np.unique(k):
            sel = o[k == v]
            assert (np.diff(sel) > 0).all()  # stable within equal keys


def test_segmented_adversarial_batch_escalates_not_truncates():
    """Eight constant-key requests aim every packed run at one bucket — on a
    whp-tier service the cheap tier faults and the per-batch ladder must
    escalate, returning every key (vs plain np.sort per request)."""
    arrays = [np.full(1024, r * 1000, np.int32) for r in range(8)]
    svc = SortService(
        ServiceConfig(p=8, pair_capacity="whp"), executor=SortExecutor()
    )
    results = svc.sort_many(arrays)
    assert svc.stats.retries >= 1  # escalated past the cheap tier
    for a, r in zip(arrays, results):
        assert np.array_equal(r.keys, np.sort(a))
        assert r.tier not in (None, "whp")


def test_default_service_serves_multi_segment_batches_first_tier():
    """Perf guard: the default config must serve a benign multi-segment
    batch at its FIRST ladder rung with zero retries. (Since PR 4 that
    rung is the planner's segment-aware ``planned`` capacity over the
    striped layout — a default that always faults would silently run
    every batch ~3×.)"""
    rng = np.random.default_rng(7)
    arrays = [rng.integers(0, 2**31, 512).astype(np.int32) for _ in range(16)]
    svc = SortService(ServiceConfig(p=8), executor=SortExecutor())
    results = svc.sort_many(arrays)
    assert svc.stats.retries == 0, svc.stats.as_row()
    assert all(r.tier == svc.stats.last_tier for r in results)
    for a, r in zip(arrays, results):
        assert np.array_equal(r.keys, np.sort(a))


def test_flush_keeps_piggybacked_results_claimable():
    """A request fused into another caller's flush must stay claimable:
    sort_one drains the queue but only claims its OWN result."""
    svc = SortService(ServiceConfig(p=8), executor=SortExecutor())
    a = np.arange(100, dtype=np.int32)[::-1].copy()
    fut_a = svc.submit(a)  # a SortFuture, not yet dispatched
    assert not fut_a.done() and svc.dispatcher.idle
    b = np.arange(50, dtype=np.int32)[::-1].copy()
    res_b = svc.sort_one(b)  # fuses a into the same flush
    assert np.array_equal(res_b.keys, np.sort(b))
    assert svc.pending == 0 and fut_a.done()  # piggybacked: already resolved
    later = svc.flush()  # nothing queued, but a's result is still unclaimed
    assert set(later) == {fut_a.rid}
    res_a = svc.take_result(fut_a.rid)  # claimable by rid alone
    assert np.array_equal(res_a.keys, np.sort(a))
    assert svc.flush() == {}  # claimed: the store is empty
    assert fut_a.result() is res_a  # the future's cached copy survives
    # take_result drives a still-pending request on demand (rid or future)
    fut_c = svc.submit(a)
    assert np.array_equal(svc.take_result(fut_c).keys, np.sort(a))
    fut_d = svc.submit(a)
    assert np.array_equal(svc.take_result(fut_d.rid).keys, np.sort(a))


def test_batch_former_pow2_buckets_and_key_cap():
    former = BatchFormer(p=8, max_batch_keys=1000, min_n_per_proc=8)
    reqs = [(i, np.zeros(s, np.int32)) for i, s in enumerate([600, 300, 200, 5000])]
    batches = former.form(reqs)
    # 600+300 fit; 200 opens a new batch; 5000 exceeds the cap alone but
    # still gets its own (bigger-bucket) batch
    assert [b.rids for b in batches] == [[0, 1], [2], [3]]
    assert [b.total_keys for b in batches] == [900, 200, 5000]
    for b in batches:
        n_p = b.n_per_proc
        assert n_p & (n_p - 1) == 0 and 8 * n_p >= b.total_keys
    assert batches[0].n_per_proc == 128  # ceil(900/8)=113 -> pow2 128
    assert former.form([]) == []


def test_batch_former_reuses_one_compiled_sort_per_bucket():
    """CI regression: two different same-bucket request mixes must reuse ONE
    compiled segmented sort (zero new executor traces on the second flush).
    det + exact capacity keeps the visited-tier set deterministic."""
    ex = SortExecutor()
    cfg = ServiceConfig(p=8, algorithm="det", pair_capacity="exact")
    rng = np.random.default_rng(4)

    def mix(sizes):
        return [rng.integers(0, 2**31, s).astype(np.int32) for s in sizes]

    SortService(cfg, executor=ex).sort_many(mix([900, 60, 40]))  # total 1000
    first = dict(ex.trace_counts)
    assert first and all(v == 1 for v in first.values())
    assert sum(1 for k in first if k[0] == "prepare") == 1
    SortService(cfg, executor=ex).sort_many(mix([500, 10, 400, 101]))  # 1011
    assert dict(ex.trace_counts) == first  # same pow2 bucket: no new traces
    # a different bucket compiles separately (and only once)
    SortService(cfg, executor=ex).sort_many(mix([5000]))
    grew = dict(ex.trace_counts)
    assert len(grew) > len(first) and all(v == 1 for v in grew.values())


def test_service_telemetry_latency_and_tier_stats():
    svc = SortService(ServiceConfig(p=8), executor=SortExecutor())
    arrays = [np.arange(s, dtype=np.int32)[::-1].copy() for s in [10, 200, 3000]]
    results = svc.sort_many(arrays)
    assert len(svc.latencies) == 3
    assert all(r.latency_s > 0 for r in results)
    assert all(r.n_per_proc == results[0].n_per_proc for r in results)
    assert svc.keys_sorted == 3210 and svc.batches_dispatched == 1
    tele = svc.telemetry()
    assert tele["requests"] == 3 and tele["batches"] == 1
    assert sum(svc.stats.attempts.values()) >= 1
    # flush with nothing pending is a no-op
    assert svc.flush() == {} and svc.pending == 0


def test_service_max_batch_splits_into_multiple_fused_sorts():
    svc = SortService(
        ServiceConfig(p=8, max_batch_keys=650), executor=SortExecutor()
    )
    arrays = [np.arange(300, dtype=np.int32)[::-1].copy() for _ in range(4)]
    results = svc.sort_many(arrays)
    assert svc.batches_dispatched == 2  # 300+300 fits under 650 -> 2+2
    for a, r in zip(arrays, results):
        assert np.array_equal(r.keys, np.sort(a))


def test_pack_segments_layout_and_bounds():
    packed = pack_segments(
        [np.arange(3, dtype=np.int32), np.arange(2, dtype=np.int32)],
        p=4,
        n_per_proc=8,
    )
    assert packed.comp.shape == (4, 8) and packed.comp.dtype == np.int64
    assert packed.pos.shape == (4, 8) and packed.n_keys == 5
    real_mask = packed.pos >= 0
    # pads carry the past-the-last segment id: strictly above real keys
    assert packed.comp[~real_mask].min() > packed.comp[real_mask].max()
    # real keys are spread evenly across lanes (no all-pad lane: a constant
    # run aimed at one bucket would structurally fault the whp pair tier),
    # and each lane's real share is a prefix (stability reads submit order)
    per_lane = real_mask.sum(axis=1)
    assert per_lane.max() - per_lane.min() <= 1
    for k in range(4):
        assert real_mask[k, : per_lane[k]].all()
    # single-segment hot path: no composite lift, raw int32 keys
    one = pack_segments([np.arange(5, dtype=np.int32)], p=4, n_per_proc=8)
    assert one.comp.dtype == np.int32
    assert (one.comp[one.pos < 0] == np.iinfo(np.int32).max).all()
    with pytest.raises(ValueError):
        pack_segments([np.zeros(100, np.int32)], p=2, n_per_proc=8)


def test_single_segment_int32_path_handles_max_key_collisions():
    """Single-segment pads equal int32 max, which legal keys may also hold:
    the unpack must keep every real key (filtering by payload, not value)
    and stay stable among the collided maxima."""
    imax = np.iinfo(np.int32).max
    keys = np.concatenate(
        [np.full(7, imax, np.int32), np.arange(50, dtype=np.int32)]
    )
    res = sort_segments([keys], p=8)
    assert np.array_equal(res.keys[0], np.sort(keys))
    assert np.array_equal(keys[res.order[0]], res.keys[0])
    sel = res.order[0][res.keys[0] == imax]
    assert (np.diff(sel) > 0).all()  # stable within the collided maxima


def test_single_segment_batch_serves_on_cheap_sub_exact_tier():
    """The auto tier keeps the cheap regime for single-segment sorts (serve
    admission / data bucketing): a benign corpus must be served in one
    attempt with zero retries and without exact's p×-larger routing
    buffers. Since the radix PR a balanced integer corpus takes the
    count-then-distribute route (one exact-capacity rung, no splitter
    superstep); a range-skewed corpus still rides the planner's sampled
    ``planned`` capacity (at most the classic whp bound, pad-aware)."""
    lens = np.random.default_rng(11).integers(1, 5000, 999).astype(np.int32)
    svc = SortService(ServiceConfig(p=8), executor=SortExecutor())
    res = svc.sort_one(lens)
    assert np.array_equal(res.keys, np.sort(lens))
    assert res.tier == "radix" and svc.stats.retries == 0, svc.stats.as_row()
    # range-skewed keys (zipf mass at small values) stay on the sampling
    # route and serve at the planner's sub-exact capacity
    skew = datagen.generate("zipf", 1, 999, seed=11)[0]
    svc = SortService(ServiceConfig(p=8), executor=SortExecutor())
    res = svc.sort_one(skew)
    assert np.array_equal(res.keys, np.sort(skew))
    assert res.tier == "planned" and svc.stats.retries == 0, svc.stats.as_row()
    # an explicit pin still forces the classic whp regime
    svc = SortService(
        ServiceConfig(p=8, pair_capacity="whp"), executor=SortExecutor()
    )
    res = svc.sort_one(lens)
    assert res.tier == "whp" and np.array_equal(res.keys, np.sort(lens))


def test_flush_failsink_retries_failed_batch_without_losing_requests(
    monkeypatch,
):
    """An admitted request may never be dropped: a batch whose sort raises
    is failsink-retried (a solo batch gets one re-dispatch) inside the same
    flush — no exception escapes to innocent submitters, and the retried
    result carries the failsink telemetry mark."""
    import repro.service.dispatch as disp_mod

    svc = SortService(
        ServiceConfig(p=8, max_batch_keys=100), executor=SortExecutor()
    )
    fut_a = svc.submit(np.arange(80, dtype=np.int32)[::-1].copy())
    fut_b = svc.submit(np.arange(90, dtype=np.int32)[::-1].copy())
    calls = {"n": 0}
    orig = disp_mod.segmented_sort_launch

    def failing(*args, **kw):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("boom")
        return orig(*args, **kw)

    monkeypatch.setattr(disp_mod, "segmented_sort_launch", failing)
    out = svc.flush()  # batch 2 (fut_b) raises once, retries solo, lands
    assert set(out) == {fut_a.rid, fut_b.rid}  # nobody lost, nobody raised
    assert svc.dispatcher.failsink_solo_retries == 1
    assert svc.dispatcher.failsink_errors == 0
    res_b = svc.take_result(fut_b)
    assert res_b.failsink and fut_b.failsink  # rode the failsink re-dispatch
    assert np.array_equal(res_b.keys, np.arange(90, dtype=np.int32))
    res_a = svc.take_result(fut_a)
    assert not res_a.failsink  # the innocent batch never saw the failsink


def test_length_bucketed_order_rejects_mismatched_service_p():
    from repro.data import length_bucketed_order
    from repro.service import ServiceConfig as SC, SortService as SS

    svc = SS(SC(p=8), executor=SortExecutor())
    lens = np.arange(100, dtype=np.int32)
    with pytest.raises(ValueError):
        length_bucketed_order(lens, p=16, service=svc)
    order = length_bucketed_order(lens, p=8, service=svc)
    assert np.array_equal(order, np.arange(100))


def test_datagen_zipf_keys_and_sizes():
    z = datagen.generate("zipf", 4, 500, seed=3)
    assert z.shape == (4, 500) and z.dtype == np.int32 and z.min() >= 1
    _, counts = np.unique(z, return_counts=True)
    assert counts.max() / z.size > 0.2  # duplicate-heavy head
    assert np.array_equal(z, datagen.generate("zipf", 4, 500, seed=3))
    s = datagen.zipf_sizes(32, 4096, seed=21)
    assert s.sum() == 4096 and s.min() >= 1 and len(s) == 32
    assert np.array_equal(s, datagen.zipf_sizes(32, 4096, seed=21))
    assert s.max() / s.min() > 8  # genuinely skewed mix
    # degenerate totals must still satisfy the contract (sum, min >= 1)
    for total in (64, 65, 80):
        t = datagen.zipf_sizes(64, total, seed=0)
        assert t.sum() == total and t.min() >= 1


def test_bench_json_writer(tmp_path):
    import json

    from benchmarks import common

    saved = list(common.ROWS)
    del common.ROWS[:]
    try:
        common.emit("service", {"mix": "U", "speedup": 2.5})
        common.emit("service", {"mix": "DD", "speedup": 2.8})
        common.emit("capacity", {"variant": "RSQ", "complete": True})
        paths = common.write_json(str(tmp_path))
        assert [p.split("/")[-1] for p in paths] == [
            "BENCH_capacity.json",
            "BENCH_service.json",
        ]
        data = json.load(open(paths[1]))
        assert data["table"] == "service"
        assert data["rows"] == [
            {"mix": "U", "speedup": 2.5},
            {"mix": "DD", "speedup": 2.8},
        ]
    finally:
        common.ROWS[:] = saved
