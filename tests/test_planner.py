"""Capacity planner: fingerprints, segment-aware bound, striped packing,
traffic-learned tier selection, persistence, auto-flush, bench_diff gate."""
import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core import SortConfig, SortExecutor, TierStats, datagen
from repro.core.segmented import (
    pack_segments,
    segmented_sort_safe,
    sort_segments,
    striped_chunk_sizes,
)
from repro.planner import (
    CapacityPlanner,
    bucket_key,
    fingerprint_arrays,
    lane_spread,
    planned_cap_for,
    segment_aware_pair_cap,
    solve_omega,
)
from repro.service import ServiceConfig, SortService

pytestmark = pytest.mark.fast

SCRIPTS = os.path.join(os.path.dirname(__file__), "..", "scripts")


def _zipf_mix(mix, n_req, total, seed):
    sizes = datagen.zipf_sizes(n_req, total, seed=seed)
    return [
        datagen.generate(mix, 1, int(s), seed=seed * 100 + i)[0]
        for i, s in enumerate(sizes)
    ]


# ------------------------------------------------------------- fingerprint
def test_fingerprint_fields_and_bucketing():
    arrays = [np.arange(100, dtype=np.int32), np.zeros(50, np.int32)]
    fp = fingerprint_arrays(arrays, p=4)
    assert fp.n_keys == 150 and fp.p == 4 and fp.n_segments == 2
    assert fp.sizes == (100, 50)
    assert fp.n_per_proc == 64  # pow2 cover of ceil(150/4)
    # dup sampling: distinct-key segment near 1/sample, constant segment 1.0
    assert fp.dup_fractions[0] < 0.05 and fp.dup_fractions[1] == 1.0
    assert 0.0 < fp.dup_fraction < 1.0  # size-weighted mean
    assert fp.pad_keys == 4 * 64 - 150
    key = bucket_key(fp)
    assert key.startswith("p4/npp64/segs2/dup")
    # quantization: nearby workloads share a bucket, regimes split it
    fp2 = fingerprint_arrays(
        [np.arange(100, dtype=np.int32) * 2, np.ones(50, np.int32)], p=4
    )
    assert bucket_key(fp2) == key
    fp3 = fingerprint_arrays([np.arange(150, dtype=np.int32)], p=4)
    assert "segs1" in bucket_key(fp3) and bucket_key(fp3) != key


def test_lane_spread_contiguous_geometry():
    # 8 equal segments over 4 lanes: each contiguous lane spans exactly 2
    smax, smean = lane_spread([100] * 8, 4)
    assert (smax, smean) == (2, 2.0)
    # one giant segment: every lane sits inside it
    assert lane_spread([10_000], 4) == (1, 1.0)
    # many tiny segments: each lane spans ~R/p of them
    smax, _ = lane_spread([10] * 64, 4)
    assert smax >= 16
    assert lane_spread([], 4) == (0, 0.0)


# ---------------------------------------------------------- striped layout
def test_striped_chunk_sizes_invariants():
    rng = np.random.default_rng(0)
    for _ in range(100):
        p = int(2 ** rng.integers(0, 5))
        sizes = rng.integers(0, 97, rng.integers(1, 30))
        ch = striped_chunk_sizes(sizes, p)
        assert (ch.sum(axis=1) == sizes).all()  # every key placed
        tot = ch.sum(axis=0)
        assert tot.max() - tot.min() <= 1  # lanes stay balanced


def test_striped_packing_pads_distinct_and_interleaved():
    packed = pack_segments(
        [np.arange(10, dtype=np.int32), np.arange(5, dtype=np.int32)],
        p=4, n_per_proc=8, layout="striped",
    )
    assert packed.layout == "striped"
    pads = packed.comp[packed.pos < 0]
    assert len(np.unique(pads)) == pads.size  # distinct: no constant run
    assert pads.min() > packed.comp[packed.pos >= 0].max()  # sort to tail
    # interleaved: consecutive sorted pads come from different lanes
    lane_of = np.repeat(np.arange(4), 8).reshape(4, 8)[packed.pos < 0]
    by_value = lane_of[np.argsort(pads)]
    assert (by_value[1:] != by_value[:-1]).any()
    # lane real-key loads stay balanced
    per_lane = (packed.pos >= 0).sum(axis=1)
    assert per_lane.max() - per_lane.min() <= 1


def test_striped_results_byte_identical_to_contiguous():
    """Acceptance: the planner's striped path returns byte-identical keys
    AND stable argsort vs the PR 3 contiguous path, dup-heavy included."""
    rng = np.random.default_rng(3)
    arrays = [
        rng.integers(-(2**31), 2**31, s).astype(np.int32)
        for s in [0, 1, 333, 64]
    ] + [np.zeros(257, np.int32), datagen.generate("zipf", 1, 400, seed=3)[0]]
    a = sort_segments(arrays, p=8, layout="striped")
    b = sort_segments(arrays, p=8, layout="contiguous")
    for ka, kb in zip(a.keys, b.keys):
        assert ka.dtype == kb.dtype and np.array_equal(ka, kb)
    for oa, ob in zip(a.order, b.order):
        assert np.array_equal(oa, ob)
    for arr, k, o in zip(arrays, a.keys, a.order):
        assert np.array_equal(arr[o], k)  # stable argsort survives striping
        for v in np.unique(k):
            sel = o[k == v]
            assert (np.diff(sel) > 0).all()


def test_pack_segments_rejects_unknown_layout():
    with pytest.raises(ValueError):
        pack_segments([np.zeros(8, np.int32)], p=2, layout="diagonal")


# ------------------------------------------------------ segment-aware bound
def test_planned_config_tier_ladder_and_prepare_sharing():
    cfg = SortConfig(
        p=8, n_per_proc=256, algorithm="iran",
        pair_capacity="planned", pair_cap_override=64,
    )
    assert cfg.pair_cap == 64
    names = [t for t, _ in cfg.tier_ladder()]
    assert names == ["planned", "planned2", "exact", "allgather"]
    tiers = dict(cfg.tier_ladder())
    assert tiers["planned2"].pair_cap == 128  # capacity_factor ×2
    # exact/allgather rungs normalise the override away: ladders that
    # differ only in their planned bound share those compiled rungs
    other = SortConfig(
        p=8, n_per_proc=256, algorithm="iran",
        pair_capacity="planned", pair_cap_override=96,
    )
    assert tiers["exact"] == dict(other.tier_ladder())["exact"]
    # every rung shares one prepare (omega normalised for non-det too)
    keys = {t.prepare_key() for t in tiers.values()} | {
        SortConfig(
            p=8, n_per_proc=256, algorithm="iran", omega=2.0,
            pair_capacity="planned", pair_cap_override=64,
        ).prepare_key()
    }
    assert len(keys) == 1
    with pytest.raises(ValueError):
        SortConfig(p=8, n_per_proc=16, pair_capacity="planned").validate()


def test_segment_aware_bound_shrinks_and_inflates_as_designed():
    # benign many-segment mix: far below exact
    sizes = [512] * 16
    cap = segment_aware_pair_cap(sizes, p=8, n_per_proc=1024)
    assert cap < 1024 // 2
    # duplicate-heavy segments inflate the bound
    cap_dup = segment_aware_pair_cap(
        sizes, p=8, n_per_proc=1024, dup_fractions=[0.5] * 16
    )
    assert cap < cap_dup
    # all-constant MULTI-segment batches stay sub-exact under striping —
    # each lane holds only m/p copies of each constant, so a lane's worst
    # bucket carries ~2·m/p (measured 128 at this shape); the bound must
    # not charge a segment's duplicate mass to windows it doesn't overlap
    cap_const = segment_aware_pair_cap(
        [1024] * 8, p=8, n_per_proc=1024, dup_fractions=[1.0] * 8
    )
    assert 2 * 1024 // 8 <= cap_const < 1024
    # ...but ONE all-constant segment is the true degenerate case: a lane's
    # n_p copies all sort to one bucket — no sub-exact tier exists
    cap_one = segment_aware_pair_cap(
        [8192], p=8, n_per_proc=1024, dup_fractions=[1.0]
    )
    assert cap_one >= 1024
    # constant sentinel pads (single-segment int32 path) are priced in
    cap_pad = segment_aware_pair_cap([4104], p=8, n_per_proc=1024, pad_dup=1.0)
    assert cap_pad >= (8 * 1024 - 4104) // 8  # ≥ the concentrated pad share
    om, cap_o = solve_omega(sizes, p=8, n_per_proc=1024)
    assert om >= 1.0 and cap_o > 0


def test_window_load_max_covers_duplicate_clip_kinks():
    """Regression: the sliding-window scan must evaluate the interior
    breakpoints where ``overlap/m + δ`` saturates at 1 — a starts/ends-only
    candidate set undersized the bound ~14% on this dup-heavy case."""
    from repro.planner.capacity import _window_load_max

    def brute(sizes, dups, p, width, steps=4000):
        m = sizes.astype(np.float64)
        ends, m_hat = np.cumsum(m), np.ceil(m / p)
        starts, total = ends - m, float(m.sum())
        width = min(width, total)
        best = 0.0
        for t in np.linspace(0, total - width, steps):
            ov = np.clip(
                np.minimum(ends, t + width) - np.maximum(starts, t), 0, None
            )
            term = m_hat * np.minimum(1.0, ov / m + dups)
            best = max(best, float(np.where(ov > 0, term, 0.0).sum()))
        return best

    s, d = np.array([197, 146, 147]), np.array([0.64, 0.49, 0.71])
    assert _window_load_max(s, d, 2, 137) >= brute(s, d, 2, 137) - 1e-6
    rng = np.random.default_rng(1)
    for _ in range(50):
        s = rng.integers(1, 400, rng.integers(1, 10)).astype(np.int64)
        d = rng.random(len(s)) * rng.choice([0.0, 0.5, 1.0])
        w = int(rng.integers(1, s.sum() + 1))
        assert _window_load_max(s, d, 4, w) >= brute(s, d, 4, w) - 1e-6


def test_segment_aware_bound_monte_carlo_fault_rate():
    """Satellite acceptance: across U/G/B/DD/zipf adversarial fused mixes
    (zipf-skewed sizes, contiguous-packing-hostile multi-segment batches),
    the planned tier chosen by the segment-aware bound must hold — observed
    starting-tier fault rate within the planner's whp target — and every
    result must stay byte-correct."""
    ex = SortExecutor()
    attempts = faults = 0
    sub_exact = 0
    for mix in ["U", "G", "B", "DD", "zipf"]:
        for seed in range(3):
            arrays = _zipf_mix(mix, 16, 2048, seed)
            fp = fingerprint_arrays(arrays, 8)
            omega, cap = planned_cap_for(fp)
            if cap >= fp.n_per_proc:
                continue  # bound says no cheap tier exists: not a trial
            packed = pack_segments(
                arrays, 8, n_per_proc=fp.n_per_proc, layout="striped"
            )
            stats = TierStats()
            res = segmented_sort_safe(
                packed,
                pair_capacity="planned",
                pair_cap_override=cap,
                omega=omega,
                stats=stats,
                executor=ex,
                seed=seed,
            )
            attempts += 1
            sub_exact += 1
            faults += int(stats.retries > 0)
            for a, k, o in zip(arrays, res.keys, res.order):
                assert np.array_equal(k, np.sort(a))
                assert np.array_equal(a[o], k)
    assert attempts >= 10  # the bound must offer a sub-exact tier broadly
    # whp target with slack for the small trial count (0 faults expected)
    assert faults / attempts <= 0.1, (faults, attempts)


# ------------------------------------------------------- learning/feedback
def test_planner_promotes_on_faults_and_probes_down():
    pl = CapacityPlanner(fault_target=0.05, min_attempts=4, probe_after=6)
    b = "p8/npp256/segs16/dup0"
    assert pl.rung_for(b) == 0
    for _ in range(4):
        pl.observe(b, faulted=True)
    assert pl.rung_for(b) == 1 and pl.promotions == 1
    # counters reset: the new rung is judged on its own evidence
    assert pl.history[b]["attempts"] == 0
    for _ in range(6):
        pl.observe(b, faulted=False)
    assert pl.rung_for(b) == 0 and pl.probes == 1
    # rung clamps at the ladder top
    for _ in range(3):
        for _ in range(4):
            pl.observe(b, faulted=True)
    assert pl.rung_for(b) == 2
    for _ in range(4):
        pl.observe(b, faulted=True)
    assert pl.rung_for(b) == 2  # clamped


def test_planner_rungs_map_to_start_tiers():
    # balanced equal-size int segments route radix since the radix PR:
    # single exact-capacity rung, no ω, no rung ladder to learn
    balanced = [np.arange(512, dtype=np.int32) for _ in range(8)]
    pl = CapacityPlanner()
    dr = pl.plan(balanced, 8)
    assert dr.route == "radix" and dr.start_tier == "radix"
    assert dr.pair_cap_override is None and dr.omega is None

    # skewed sizes put the busiest range bucket over RADIX_SKEW/p — these
    # stay on the sampling route and exercise the rung→tier mapping
    arrays = [np.arange(2048, dtype=np.int32)] + [
        np.arange(64, dtype=np.int32) for _ in range(7)
    ]
    d0 = pl.plan(arrays, 8)
    assert d0.route == "sample"
    assert d0.pair_capacity == "planned" and d0.layout == "striped"
    assert d0.pair_cap_override < 512 and d0.omega >= 1.0
    pl.history[d0.bucket]["rung"] = 1
    d1 = pl.plan(arrays, 8)
    assert d1.rung == 1
    # rung 1 doubles the RAW bound before quantization: strictly bigger cap
    assert d1.pair_capacity == "exact" or (
        d1.pair_cap_override > d0.pair_cap_override
    )
    pl.history[d0.bucket]["rung"] = 2
    d2 = pl.plan(arrays, 8)
    assert d2.pair_capacity == "exact" and d2.pair_cap_override is None
    # single-segment plan keeps the contiguous raw-int32 hot path
    ds = pl.plan([np.arange(999, dtype=np.int32)], 8)
    assert ds.layout == "contiguous" and "segs1" in ds.bucket


def test_planner_history_persists_and_changes_start_tier(
    tmp_path, monkeypatch
):
    """Tentpole acceptance: observed faults promote a bucket, the history
    survives as JSON, and a later run (fresh planner, same path) starts
    that bucket at the learned rung instead of re-paying the faults."""
    import repro.planner.planner as planner_mod

    path = str(tmp_path / "history.json")
    # skewed sizes keep the batch on the sampling route (a balanced
    # equal-size int batch would plan route="radix" and never consult
    # the capacity bound this test sabotages)
    arrays = [
        np.random.default_rng(i)
        .integers(0, 2**31, 2048 if i == 0 else 64)
        .astype(np.int32)
        for i in range(8)
    ]
    # an underestimating bound makes the planned tier genuinely overflow
    monkeypatch.setattr(
        planner_mod, "planned_cap_for", lambda fp, **kw: (2.0, 8)
    )
    pl = CapacityPlanner(path=path, fault_target=0.05, min_attempts=2)
    svc = SortService(
        ServiceConfig(p=8, planner_path=path),
        executor=SortExecutor(),
        planner=pl,
    )
    for _ in range(6):  # every batch faults its tiny planned cap
        results = svc.sort_many(arrays)
        for a, r in zip(arrays, results):
            assert np.array_equal(r.keys, np.sort(a))  # escalation, not loss
    assert svc.stats.retries >= 2
    bucket = pl.plan(arrays, 8).bucket
    assert pl.history[bucket]["rung"] >= 1  # promoted away from the bad cap
    learned_rung = pl.history[bucket]["rung"]
    monkeypatch.undo()

    # fresh process, same path: starts at the learned rung — with the real
    # bound restored, a promoted bucket plans a bigger cap (or exact)
    reloaded = CapacityPlanner(path=path)
    assert reloaded.history[bucket]["rung"] == learned_rung
    d_learned = reloaded.plan(arrays, 8)
    d_fresh = CapacityPlanner().plan(arrays, 8)
    assert d_learned.rung == learned_rung and d_fresh.rung == 0
    assert d_learned.pair_capacity == "exact" or (
        d_learned.pair_cap_override > d_fresh.pair_cap_override
    )
    # on-disk format is the documented JSON
    data = json.loads(open(path).read())
    assert data["version"] == 1 and bucket in data["buckets"]


def test_bsp_sort_safe_planner_policy_learns_ladder_start():
    """The optional raw-sort policy: a shape whose whp rung keeps faulting
    starts higher next time; the ladder above the learned start still runs."""
    from repro.core import bsp_sort_safe, gathered_output
    import jax.numpy as jnp

    p, n_p = 8, 64
    adv = np.repeat(
        (np.arange(p, dtype=np.int32) * (2**20))[:, None], n_p, axis=1
    )
    cfg = SortConfig(p=p, n_per_proc=n_p, algorithm="iran", pair_capacity="whp")
    pl = CapacityPlanner(fault_target=0.05, min_attempts=2)
    ex = SortExecutor()
    for _ in range(8):
        res, _, stats = bsp_sort_safe(
            jnp.asarray(adv), cfg, planner=pl, executor=ex, stats=TierStats()
        )
        assert np.array_equal(
            gathered_output(res), np.sort(adv.reshape(-1))
        )
    bucket = f"sort/iran/p{p}/npp{n_p}/whp"
    assert pl.history[bucket]["rung"] >= 1  # stopped paying the doomed whp
    stats = TierStats()
    bsp_sort_safe(jnp.asarray(adv), cfg, planner=pl, executor=ex, stats=stats)
    assert "whp" not in stats.attempts  # sliced off the learned prefix


# ------------------------------------------------- executor registry bound
def test_executor_registry_growth_bounded_under_mixed_soak():
    """Satellite: planner-chosen configs must not grow the compiled-callable
    cache without bound. Quantized planned caps (eighths of n_per_proc) ×
    the tier ladder give O(levels × tiers) route entries per bucket shape;
    replaying the whole mixed soak must add ZERO new executor keys."""
    ex = SortExecutor()
    svc = SortService(ServiceConfig(p=8), executor=ex)

    def soak(seed0):
        for seed in range(seed0, seed0 + 6):
            mix = ["U", "DD", "zipf"][seed % 3]
            n_req = [1, 4, 16][seed % 3]
            svc.sort_many(_zipf_mix(mix, n_req, 1024 + 128 * (seed % 5), seed))

    soak(0)
    keys_after_first = set(ex.trace_counts)
    shapes = {k[2].n_per_proc for k in keys_after_first}
    route_keys = [k for k in keys_after_first if k[0] == "route"]
    prepare_keys = [k for k in keys_after_first if k[0] == "prepare"]
    # per pow2 bucket shape: ≤8 planned levels × ladder rungs (planned,
    # planned2, exact, allgather) plus the whp pair — a fixed constant —
    # plus the radix route's octave-quantized counted capacities
    assert len(route_keys) <= len(shapes) * 12, len(route_keys)
    # one sampling-route prepare + one radix-route prepare per shape
    assert len(prepare_keys) <= len(shapes) * 2, len(prepare_keys)
    counts_after_first = dict(ex.trace_counts)
    soak(0)  # replay: identical traffic must reuse every compiled callable
    # (equality of COUNTS, not just keys: a silent per-call retrace would
    # bump a count without adding a key)
    assert dict(ex.trace_counts) == counts_after_first


def test_corrupt_history_warns_and_starts_fresh(tmp_path):
    """Load mirrors the warn-only save: a corrupt/stale-format history file
    must not keep a service from coming up."""
    path = tmp_path / "history.json"
    path.write_text("{ not json")
    with pytest.warns(UserWarning, match="unusable"):
        pl = CapacityPlanner(path=str(path))
    assert pl.history == {}
    # stale format (missing counter field) is tolerated the same way
    path.write_text(json.dumps({"version": 1, "buckets": {"b": {"rung": 1}}}))
    with pytest.warns(UserWarning, match="unusable"):
        assert CapacityPlanner(path=str(path)).history == {}
    # and an unknown version likewise
    path.write_text(json.dumps({"version": 99, "buckets": {}}))
    with pytest.warns(UserWarning, match="unusable"):
        CapacityPlanner(path=str(path))


def test_planner_merge_on_save_pools_concurrent_histories(tmp_path):
    """Two planners sharing one history path must not last-write-wins
    clobber each other: a save folds in buckets the other process wrote,
    keeps the higher (capacity-safe) rung on conflict, and accumulates the
    other side's counter deltas without double-counting what was loaded."""
    path = str(tmp_path / "history.json")
    a = CapacityPlanner(path=path, min_attempts=4, fault_target=0.05)
    b = CapacityPlanner(path=path)  # loaded before A wrote anything

    for _ in range(5):
        a.observe("hot", True)  # promotes hot to rung 1
    assert a.history["hot"]["rung"] == 1
    a.save()

    b.observe("cold", False)
    b.save()  # must NOT erase A's promoted "hot" bucket
    merged = CapacityPlanner(path=path)
    assert merged.history["hot"]["rung"] == 1, merged.history
    assert merged.history["cold"]["attempts"] == 1, merged.history

    # same-rung counter pooling without double-counting: two fresh planners
    # each observe the shared bucket twice more and save in turn
    c = CapacityPlanner(path=path)
    d = CapacityPlanner(path=path)
    for _ in range(2):
        c.observe("cold", False)
        d.observe("cold", False)
    c.save()
    d.save()  # folds C's delta (2) onto its own view (1 loaded + 2 new)
    assert CapacityPlanner(path=path).history["cold"]["attempts"] == 5

    # rung conflict: the higher rung wins even if the lower saves last
    e = CapacityPlanner(path=path, min_attempts=2, fault_target=0.05)
    f = CapacityPlanner(path=path)
    for _ in range(3):
        e.observe("cold", True)
    promoted = e.history["cold"]["rung"]
    assert promoted >= 1
    e.save()
    f.observe("cold", False)  # f still thinks cold is rung 0
    f.save()
    assert CapacityPlanner(path=path).history["cold"]["rung"] == promoted


def test_service_rejects_unsupported_tier_pin():
    """A 'planned' pin has no per-batch bound to run with — it must be
    rejected at construction, not raise inside flush where the crash-safe
    re-queue would wedge the request forever."""
    with pytest.raises(ValueError, match="pair_capacity"):
        SortService(
            ServiceConfig(p=8, pair_capacity="planned"), executor=SortExecutor()
        )


def test_unwritable_planner_path_warns_but_serves(tmp_path):
    """Persistence is telemetry: an unwritable history path must not fail
    completed sorts (warn, keep serving)."""
    blocker = tmp_path / "not_a_dir"
    blocker.write_text("file where a directory is needed")
    path = str(blocker / "history.json")  # os.makedirs will fail
    svc = SortService(
        ServiceConfig(p=8, planner_path=path), executor=SortExecutor()
    )
    a = np.arange(100, dtype=np.int32)[::-1].copy()
    with pytest.warns(UserWarning, match="not persisted"):
        res = svc.sort_one(a)
    assert np.array_equal(res.keys, np.sort(a))


# ----------------------------------------------------------- auto-flush
def test_auto_flush_size_trigger():
    svc = SortService(
        ServiceConfig(p=8, max_pending=3), executor=SortExecutor()
    )
    rids = [svc.submit(np.arange(50, dtype=np.int32)[::-1].copy()) for _ in range(3)]
    assert svc.pending == 0  # third submit tripped the size trigger
    assert svc.flush_triggers.get("size") == 1
    for rid in rids:
        assert np.array_equal(
            svc.take_result(rid).keys, np.arange(50, dtype=np.int32)
        )
    svc.submit(np.arange(10, dtype=np.int32))
    assert svc.pending == 1  # below threshold: stays queued
    svc.flush()
    assert svc.flush_triggers.get("manual") == 1


def test_auto_flush_deadline_trigger():
    svc = SortService(
        ServiceConfig(p=8, flush_after_s=0.02), executor=SortExecutor()
    )
    rid = svc.submit(np.arange(64, dtype=np.int32)[::-1].copy())
    assert svc.pending == 1 and not svc.maybe_flush()  # not due yet
    time.sleep(0.03)
    # a later submit finds the oldest request overdue and flushes BOTH
    rid2 = svc.submit(np.arange(32, dtype=np.int32)[::-1].copy())
    assert svc.pending == 0
    assert svc.flush_triggers.get("deadline") == 1
    assert np.array_equal(
        svc.take_result(rid).keys, np.arange(64, dtype=np.int32)
    )
    assert np.array_equal(
        svc.take_result(rid2).keys, np.arange(32, dtype=np.int32)
    )
    # maybe_flush is a no-op on an empty queue, and telemetry reports it all
    assert not svc.maybe_flush()
    tele = svc.telemetry()
    assert tele["flush_triggers"] == {"deadline": 1}
    assert "planner" in tele and tele["planner"]["plans"] >= 1


# ------------------------------------------------------------- bench_diff
def _write_bench(path, rows):
    with open(path, "w") as f:
        json.dump({"table": "planner", "rows": rows}, f)
    return str(path)


def test_bench_diff_gate(tmp_path):
    rows = [
        {"mix": "U", "p": 8, "wall_planner_s": 0.05, "speedup": 1.6,
         "lane_spread_max": 9},
        {"mix": "DD", "p": 8, "wall_planner_s": 0.07, "speedup": 1.1,
         "lane_spread_max": 9},
    ]
    base = _write_bench(tmp_path / "base.json", rows)
    script = os.path.join(SCRIPTS, "bench_diff.py")

    def run(fresh_rows, *extra):
        fresh = _write_bench(tmp_path / "fresh.json", fresh_rows)
        return subprocess.run(
            [sys.executable, script, base, fresh, *extra],
            capture_output=True, text=True,
        )

    # within tolerance (and a big improvement is a note, not a failure)
    ok = run([dict(rows[0], wall_planner_s=0.055), dict(rows[1], speedup=2.0)])
    assert ok.returncode == 0, ok.stdout + ok.stderr
    # wall-time regression beyond tolerance fails
    slow = run([dict(rows[0], wall_planner_s=0.09), rows[1]])
    assert slow.returncode == 1 and "wall_planner_s" in slow.stdout
    # speedup collapse fails too (higher-is-better direction)
    worse = run([rows[0], dict(rows[1], speedup=0.5)])
    assert worse.returncode == 1 and "speedup" in worse.stdout
    # identity drift (different mix) is structural: exit 2
    drift = run([dict(rows[0], mix="G"), rows[1]])
    assert drift.returncode == 2
    # numeric identity fields merely CONTAINING "_s" are identity too — a
    # substring direction match would wave this through as an improvement
    spread = run([dict(rows[0], lane_spread_max=6), rows[1]])
    assert spread.returncode == 2 and "lane_spread_max" in spread.stdout
    # row-count drift is structural
    short = run([rows[0]])
    assert short.returncode == 2


def test_bench_diff_percentile_tolerance(tmp_path):
    """Latency percentiles gate at the looser --tol-pctile (default 2x
    --tol): a p99 wobble a mean would fail on passes, a real p99 collapse
    still fails, and an explicit --tol-pctile overrides the default."""
    rows = [{"mix": "U", "lat_mean_ms": 5.0, "lat_p99_ms": 10.0}]
    base = _write_bench(tmp_path / "base.json", rows)
    script = os.path.join(SCRIPTS, "bench_diff.py")

    def run(fresh_rows, *extra):
        fresh = _write_bench(tmp_path / "fresh.json", fresh_rows)
        return subprocess.run(
            [sys.executable, script, base, fresh, *extra],
            capture_output=True, text=True,
        )

    # +50% on p99 is inside the default percentile gate (2 x 30%)...
    ok = run([dict(rows[0], lat_p99_ms=15.0)])
    assert ok.returncode == 0, ok.stdout + ok.stderr
    # ...but the same +50% on the mean is a plain-latency regression
    mean = run([dict(rows[0], lat_mean_ms=7.5)])
    assert mean.returncode == 1 and "lat_mean_ms" in mean.stdout
    # a genuine p99 collapse beyond the loose gate still fails
    tail = run([dict(rows[0], lat_p99_ms=25.0)])
    assert tail.returncode == 1 and "lat_p99_ms" in tail.stdout
    # an explicit --tol-pctile overrides the 2x default
    tight = run([dict(rows[0], lat_p99_ms=15.0)], "--tol-pctile", "0.2")
    assert tight.returncode == 1 and "lat_p99_ms" in tight.stdout
