"""Training substrate: optimizer, accumulation, checkpoint, elasticity."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.data import synthetic_batch
from repro.models import Model
from repro.optim import OptConfig, apply_updates, global_norm, init_state
from repro.optim import compress
from repro.train import checkpoint, elastic, init_all, make_train_step

SHAPE = ShapeConfig("tiny", 32, 4, "train")


def test_loss_decreases_on_memorizable_data():
    cfg = get_arch("tinyllama-1.1b").reduced()
    model = Model(cfg)
    oc = OptConfig(lr=1e-3, total_steps=30, warmup_steps=1)
    params, opt = init_all(model, oc, jax.random.key(0))
    step = make_train_step(model, oc, None)
    tokens = jnp.tile(jnp.arange(32, dtype=jnp.int32)[None], (4, 1))  # fixed
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
    losses = []
    for _ in range(15):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses


@pytest.mark.fast
def test_adamw_clip_and_schedule():
    oc = OptConfig(lr=1.0, clip_norm=0.5, warmup_steps=0, total_steps=100)
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 100.0)}
    st = init_state(oc, params)
    _, st2, metrics = apply_updates(oc, params, grads, st)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)
    assert int(st2["step"]) == 1


def test_microbatch_grads_match_full_batch():
    cfg = get_arch("tinyllama-1.1b").reduced()
    oc = OptConfig()
    m1 = Model(dataclasses.replace(cfg, microbatches=1, remat=False))
    m2 = Model(dataclasses.replace(cfg, microbatches=2, remat=False))
    params, opt = init_all(m1, oc, jax.random.key(0))
    batch = synthetic_batch(cfg, SHAPE, 0)
    s1 = make_train_step(m1, oc, None)
    s2 = make_train_step(m2, oc, None)
    p1, _, met1 = s1(params, opt, batch)
    params, opt = init_all(m2, oc, jax.random.key(0))
    p2, _, met2 = s2(params, opt, batch)
    # same data, same seed: the accumulated update must match closely
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=5e-2
        )


def test_checkpoint_restart_is_exact():
    cfg = get_arch("granite-moe-1b-a400m").reduced()
    model = Model(cfg)
    oc = OptConfig(total_steps=10)
    params, opt = init_all(model, oc, jax.random.key(0))
    step = make_train_step(model, oc, None)
    with tempfile.TemporaryDirectory() as d:
        for s in range(3):
            params, opt, _ = step(params, opt, synthetic_batch(cfg, SHAPE, s))
        checkpoint.save(d, 3, {"params": params, "opt": opt})
        # continue 2 more steps
        pa, oa = params, opt
        for s in range(3, 5):
            pa, oa, ma = step(pa, oa, synthetic_batch(cfg, SHAPE, s))
        # crash + restart from step 3: stateless-seeded pipeline replays
        assert checkpoint.latest_step(d) == 3
        rest = checkpoint.restore(d, 3, {"params": params, "opt": opt})
        pb, ob = rest["params"], rest["opt"]
        for s in range(3, 5):
            pb, ob, mb = step(pb, ob, synthetic_batch(cfg, SHAPE, s))
        for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
            assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.fast
def test_checkpoint_integrity_detection():
    with tempfile.TemporaryDirectory() as d:
        path = checkpoint.save(d, 1, {"x": jnp.arange(10)})
        with open(path, "r+b") as f:
            f.seek(100)
            f.write(b"\xde\xad")
        with pytest.raises(IOError):
            checkpoint.restore(d, 1, {"x": jnp.arange(10)})


@pytest.mark.fast
def test_checkpoint_gc_keeps_window():
    with tempfile.TemporaryDirectory() as d:
        for s in range(6):
            checkpoint.save(d, s, {"x": jnp.arange(4)}, keep=3)
        assert checkpoint.all_steps(d) == [3, 4, 5]


@pytest.mark.fast
def test_straggler_monitor():
    mon = elastic.StragglerMonitor(threshold=2.0, patience=2)
    for _ in range(6):
        assert not mon.record(1.0)
    assert not mon.record(5.0)  # first slow step
    assert mon.record(5.0)  # patience reached → remesh advised


@pytest.mark.fast
def test_plan_remesh_preserves_model_axis_and_batch():
    (d, m), accum = elastic.plan_remesh(
        n_devices=192, model_axis=16, old_data_axis=16, global_batch=256
    )
    assert m == 16 and d == 8 and accum == 2  # half the DP → 2× accumulation
    with pytest.raises(ValueError):
        elastic.plan_remesh(n_devices=8, model_axis=16, old_data_axis=16, global_batch=256)


@pytest.mark.fast
def test_capacity_retry_ladder():
    calls = []

    def run(cf):
        calls.append(cf)
        return ("ok", cf), cf < 1.5  # overflow until cf ≥ 1.5

    out = elastic.retry_capacity(run)
    assert out[1] >= 1.5 and len(calls) >= 2


@pytest.mark.fast
def test_gradient_compression_error_feedback():
    rng = jax.random.key(0)
    g = {"w": jax.random.normal(jax.random.key(1), (1000,))}
    errs = compress.init_errors(g)
    q, errs = compress.compress_tree(g, errs, rng)
    deq = compress.decompress_tree(q, g)
    rel = float(global_norm(jax.tree.map(lambda a, b: a - b, g, deq)) / global_norm(g))
    assert rel < 0.01  # int8 block quantization ≈ <1% error
    # error feedback: residual carried, not lost
    assert float(global_norm(errs)) > 0
