"""BSP (p, L, g) cost model — reproduces the paper's §6 analytics."""
import math

import pytest

from repro.core import BSPMachine, CRAY_T3D, SortConfig, predict

pytestmark = pytest.mark.fast


def _machine(p):
    L, g = CRAY_T3D[p]
    return BSPMachine(p=p, L=L, g=g)


@pytest.mark.parametrize("p", [16, 32, 64, 128])
def test_predictions_are_sane(p):
    cfg = SortConfig(p=p, n_per_proc=(8 << 20) // p, algorithm="det")
    pred = predict(cfg, _machine(p))
    assert 0 < pred.efficiency <= 1.0
    assert pred.pi >= 1.0  # can't beat the sequential comparison count
    assert pred.speedup <= p


def test_paper_efficiency_claim_8m_128():
    """Paper §6.4: for n=8M, p=128 the theoretical efficiency bound is ≈66%
    for [DSQ] and observed 63-67%; the randomized observed 78-83%."""
    n = 8 << 20
    det = predict(SortConfig(p=128, n_per_proc=n // 128, algorithm="det"), _machine(128))
    assert 0.55 <= det.efficiency <= 0.85, det.efficiency
    ran = predict(SortConfig(p=128, n_per_proc=n // 128, algorithm="iran"), _machine(128))
    assert ran.efficiency >= det.efficiency * 0.9


def test_communication_efficiency_ordering():
    """One-round sample sort must beat Θ(lg²p)-round bitonic in μ terms:
    routed words per proc ~ n_max for det vs ~ lg²p·n/p for [BSI]."""
    p, n_p = 64, 1 << 17
    det = predict(SortConfig(p=p, n_per_proc=n_p, algorithm="det"), _machine(p))
    # bitonic communication: lg p (lg p + 1)/2 rounds of n_p words
    lgp = math.log2(p)
    bitonic_words = lgp * (lgp + 1) / 2 * n_p
    det_words = SortConfig(p=p, n_per_proc=n_p, algorithm="det").n_max
    assert det_words < bitonic_words / 3


def test_seq_fraction_matches_paper():
    """Paper §6.4: sequential phases (sort+merge) account for 85-90%+ of
    runtime on the T3D — the cost model must reproduce that balance."""
    p = 64
    cfg = SortConfig(p=p, n_per_proc=(32 << 20) // p, algorithm="iran")
    pred = predict(cfg, _machine(p))
    seq = pred.per_phase["SeqSort"] + pred.per_phase["Merging"]
    assert seq / pred.seconds_total >= 0.80


def test_nmax_formula_matches_lemma():
    cfg = SortConfig(p=8, n_per_proc=1024, algorithm="det", pad_align=1, capacity_factor=1.0)
    r = cfg.r
    x = cfg.segment_len
    assert cfg.n_max == (cfg.s + cfg.p - 1) * x  # exact proof-side bound
    loose = (1 + 1 / r) * cfg.n_per_proc + r * cfg.p
    assert cfg.n_max <= loose * 1.3
