"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward/train step on CPU, output shapes + finiteness asserted.

Full configs are exercised only via the dry-run (ShapeDtypeStruct — no
allocation); see launch/dryrun.py and EXPERIMENTS.md §Dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs
from repro.models import Model

ARCHS = sorted(all_archs().keys())
B, S = 2, 64


def _batch(cfg):
    # random tokens: all-identical tokens legitimately overflow MoE capacity
    # (every token picks the same top-k experts — the fault IS the contract)
    toks = jax.random.randint(jax.random.key(9), (B, S), 0, cfg.vocab, dtype=jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.ones((B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jnp.ones((B, cfg.enc_positions, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = all_archs()[arch].reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    loss, aux = model.train_loss(params, _batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    if cfg.moe_experts:
        # the capacity fault flag must be *reported* (at random init a tiny
        # reduced-E router legitimately concentrates past cf=1.25 — the
        # driver's retry ladder handles it; ample-capacity equivalence is
        # asserted in test_moe_dispatch.py)
        assert aux["overflow"].shape == ()


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = all_archs()[arch].reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = {k: v for k, v in _batch(cfg).items() if k != "labels"}
    cache, logits = model.prefill(params, batch, cache_len=S + 8)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    lg, cache2 = model.decode_step(params, cache, jnp.zeros((B,), jnp.int32))
    assert lg.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(lg.astype(jnp.float32))))
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_grad_step_no_nans(arch):
    cfg = all_archs()[arch].reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(1))

    def loss_fn(p):
        return model.train_loss(p, _batch(cfg))[0]

    grads = jax.grad(loss_fn)(params)
    gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, f"{arch}: bad grad norm {gn}"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill_logits(arch):
    """Teacher-forcing consistency: decoding token t through the cache must
    reproduce the prefill logits at position t (same computation, one new
    token at a time)."""
    cfg = all_archs()[arch].reduced()
    # flash (chunked, bf16) prefill vs reference decode attention: ~0.04
    # absolute noise on random-init logits of O(1) magnitude
    tol = 6e-2
    model = Model(cfg)
    params = model.init(jax.random.key(2))
    # VLM prompts must cover the vision-token prefix (its first
    # `vision_tokens` positions are patch embeddings, not text)
    sp = max(8, cfg.vision_tokens + 8)
    toks = jax.random.randint(jax.random.key(3), (B, sp), 0, cfg.vocab, dtype=jnp.int32)
    batch = {k: v for k, v in _batch(cfg).items() if k not in ("labels", "tokens")}
    full_batch = {"tokens": toks, **batch}
    # prefill on the first sp-1 tokens, then decode token sp-1
    pre_batch = {"tokens": toks[:, : sp - 1], **batch}
    cache, _ = model.prefill(params, pre_batch, cache_len=sp + 8)
    lg_dec, _ = model.decode_step(params, cache, toks[:, sp - 1])
    cache8, lg_pre = model.prefill(params, full_batch, cache_len=sp + 8)
    np.testing.assert_allclose(
        np.asarray(lg_dec, np.float32),
        np.asarray(lg_pre, np.float32),
        rtol=tol,
        atol=tol,
        err_msg=arch,
    )


def test_param_counts_match_published_sizes():
    expect = {
        "deepseek-7b": 6.9e9,
        "internlm2-20b": 19.9e9,
        "phi3-mini-3.8b": 3.8e9,
        "tinyllama-1.1b": 1.1e9,
        "jamba-1.5-large-398b": 397e9,
        "xlstm-350m": 0.30e9,
        "internvl2-76b": 70e9,
        "granite-moe-1b-a400m": 1.4e9,
        "mixtral-8x22b": 141e9,
        "whisper-tiny": 0.06e9,
    }
    for name, want in expect.items():
        got = all_archs()[name].param_count()
        assert abs(got - want) / want < 0.12, (name, got, want)
