"""Observability subsystem: the registry, the tracer, and the guarantee
that tracing never changes what the machine computes — traced runs are
byte-identical to untraced runs, configs stay executor-cache-equal, and
the compiled programs (trace counts, lowered HLO) are untouched."""
import json
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import obs
from repro.core import (
    SortConfig,
    TierStats,
    bsp_sort,
    bsp_sort_safe,
    datagen,
    gathered_output,
    pack_segments,
    segmented_sort_safe,
    theoretical_max_imbalance,
)
from repro.core.api import SortExecutor

pytestmark = pytest.mark.fast

P, N_P = 8, 512


# ----------------------------------------------------------- registry
def test_registry_counter_gauge_histogram_snapshot_reset():
    reg = obs.MetricsRegistry()
    c = reg.counter("sort.retries")
    c.inc()
    c.inc(2)
    assert c.value == 3
    g = reg.gauge("dispatch.in_flight_peak", svc="svc9")
    g.set(2)
    g.set_max(5)
    g.set_max(1)  # set_max never lowers
    assert g.value == 5
    h = reg.histogram("service.request_latency_s")
    for v in (0.1, 0.2, 0.3):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["sort.retries"] == 3
    assert snap["dispatch.in_flight_peak{svc=svc9}"] == 5
    assert snap["service.request_latency_s"]["count"] == 3
    reg.reset()
    assert c.value == 0 and g.value == 0 and h.count == 0
    # registrations survive reset: same handle, fresh value
    assert reg.counter("sort.retries") is c


def test_registry_labels_collect_and_kind_clash():
    reg = obs.MetricsRegistry()
    reg.counter("sort.tier_attempts", tier="whp").inc()
    reg.counter("sort.tier_attempts", tier="exact").inc(4)
    got = {
        labels["tier"]: m.value
        for labels, m in reg.collect("sort.tier_attempts")
    }
    assert got == {"whp": 1, "exact": 4}
    assert obs.metric_key("a.b", {"z": 1, "a": 2}) == "a.b{a=2,z=1}"
    with pytest.raises(TypeError):
        reg.gauge("sort.tier_attempts", tier="whp")  # kind clash


def test_histogram_percentiles_match_numpy():
    h = obs.Histogram()
    rng = np.random.default_rng(7)
    xs = rng.exponential(0.01, 500)
    for v in xs:
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 500
    assert s["mean"] == pytest.approx(xs.mean(), rel=1e-9)
    assert s["p50"] == pytest.approx(float(np.quantile(xs, 0.5)), rel=1e-9)
    assert s["p99"] == pytest.approx(float(np.quantile(xs, 0.99)), rel=1e-9)


def test_tierstats_mirrors_into_registry():
    reg = obs.metrics()
    before_att = reg.counter("sort.tier_attempts", tier="whp").value
    before_ok = reg.counter("sort.tier_ok", tier="whp").value
    before_rt = reg.counter("sort.retries").value
    st = TierStats()
    st.record("whp", ok=False)
    st.record("whp", ok=True)
    assert reg.counter("sort.tier_attempts", tier="whp").value == before_att + 2
    assert reg.counter("sort.tier_ok", tier="whp").value == before_ok + 1
    assert reg.counter("sort.retries").value == before_rt + 1
    # merge_from must NOT re-mirror (each attempt already counted once)
    st2 = TierStats()
    st2.merge_from(st)
    assert reg.counter("sort.tier_attempts", tier="whp").value == before_att + 2


# ------------------------------------------- tracing changes nothing
def test_obs_field_is_cache_invisible():
    t = obs.Tracer()
    a = SortConfig(p=P, n_per_proc=N_P)
    b = SortConfig(p=P, n_per_proc=N_P, obs=t)
    assert a == b
    assert hash(a) == hash(b)
    assert a.prepare_key() == b.prepare_key()
    assert "obs" not in repr(b)


def test_traced_rerun_does_not_retrace_executor():
    ex = SortExecutor()
    x = jnp.asarray(datagen.generate("U", P, N_P, seed=3))
    cfg = SortConfig(p=P, n_per_proc=N_P, routing="a2a_dense")
    bsp_sort_safe(x, cfg, executor=ex)
    counts = dict(ex.trace_counts)
    assert counts  # the untraced run compiled something
    res, _, _ = bsp_sort_safe(
        x, SortConfig(p=P, n_per_proc=N_P, routing="a2a_dense",
                      obs=obs.Tracer()),
        executor=ex,
    )
    assert dict(ex.trace_counts) == counts  # zero new traces
    assert np.array_equal(
        gathered_output(res), np.sort(np.asarray(x).ravel())
    )


def test_hlo_identical_with_and_without_obs():
    x = jnp.asarray(datagen.generate("U", P, N_P, seed=3))

    def lowered(cfg):
        return (
            jax.jit(lambda a: bsp_sort(a, cfg)[0].buf).lower(x).as_text()
        )

    plain = SortConfig(p=P, n_per_proc=N_P, routing="a2a_dense")
    traced = SortConfig(
        p=P, n_per_proc=N_P, routing="a2a_dense", obs=obs.Tracer()
    )
    assert lowered(plain) == lowered(traced)


@pytest.mark.parametrize(
    "kw",
    [
        dict(pair_capacity="whp"),
        dict(route="radix", pair_capacity="exact"),
    ],
    ids=["sample", "radix"],
)
def test_traced_output_byte_identical(kw):
    x = jnp.asarray(datagen.generate("U", P, N_P, seed=5))
    base = dict(p=P, n_per_proc=N_P, routing="a2a_dense", **kw)
    r0, _, _ = bsp_sort_safe(x, SortConfig(**base))
    t = obs.Tracer()
    r1, _, _ = bsp_sort_safe(x, SortConfig(obs=t, **base))
    assert np.array_equal(np.asarray(r0.buf), np.asarray(r1.buf))
    assert np.array_equal(np.asarray(r0.count), np.asarray(r1.count))
    assert t.route_spans()  # and the run actually got traced


def test_traced_segmented_byte_identical():
    rng = np.random.default_rng(11)
    segs = [
        rng.integers(-1000, 1000, s).astype(np.int32) for s in (7, 300, 41)
    ]
    packed = pack_segments(segs, p=4)
    r0 = segmented_sort_safe(packed)
    t = obs.Tracer()
    r1 = segmented_sort_safe(packed, obs=t)
    for a, b in zip(r0.keys, r1.keys):
        assert np.array_equal(a, b)
    assert [s for s in t.points if s["name"] == "segments"]


# ------------------------------------------------- span/trace schema
def _traced_run(seed=5):
    t = obs.Tracer()
    x = jnp.asarray(datagen.generate("U", P, N_P, seed=seed))
    cfg = SortConfig(
        p=P, n_per_proc=N_P, routing="a2a_dense", pair_capacity="whp",
        obs=t,
    )
    bsp_sort_safe(x, cfg)
    return t, cfg


def test_span_schema_and_chrome_trace_validate():
    t, _ = _traced_run()
    assert obs.validate_spans(t) == []
    names = {s["name"] for s in t.spans}
    assert {"prepare", "route"} <= names
    route = t.route_spans()[0]
    for key in ("tier", "rung", "ok", "h_words", "supersteps",
                "recv_max", "recv_mean", "imbalance", "sync_s"):
        assert key in route["args"], key
    with tempfile.TemporaryDirectory() as d:
        path = t.save(os.path.join(d, "trace.json"))
        with open(path) as f:
            data = json.load(f)
    assert obs.validate_chrome_trace(data) == []
    phases = {e["ph"] for e in data["traceEvents"]}
    assert {"X", "M"} <= phases  # spans + thread-name metadata


def test_imbalance_within_whp_bound_on_balanced_mix():
    t, cfg = _traced_run()
    rep = t.cost_report()
    assert rep["max_imbalance"] <= 1.0 + theoretical_max_imbalance(cfg)
    assert all(r["h_words"] >= N_P for r in rep["supersteps"])


# --------------------------------------------------------- (g, L) fit
def test_fit_gl_recovers_synthetic_machine():
    g, L = 2e-9, 5e-4
    spans = [
        {"name": "route", "args": {"h_words": h, "supersteps": s},
         "dur": g * h + L * s}
        for h, s in [(1_000, 2), (10_000, 2), (100_000, 2), (50_000, 3)]
    ]
    fit = obs.fit_gl(spans)
    assert fit.ok and fit.n_samples == 4
    assert fit.g_s_per_word == pytest.approx(g, rel=1e-6)
    assert fit.l_s == pytest.approx(L, rel=1e-6)
    assert fit.r2 == pytest.approx(1.0, abs=1e-9)
    assert fit.predict_s(1_000, 2) == pytest.approx(g * 1_000 + L * 2)


def test_fit_gl_degenerate_inputs():
    assert not obs.fit_gl([]).ok
    one = [{"name": "route", "args": {"h_words": 5, "supersteps": 2},
            "dur": 0.1}]
    assert not obs.fit_gl(one).ok
    const_h = one * 3
    assert not obs.fit_gl(const_h).ok  # constant h: g unidentifiable
