"""Distributed (shard_map) paths on 8 host devices — run in a subprocess so
the main pytest process keeps seeing exactly 1 CPU device (per the brief)."""
import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(src: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)],
        capture_output=True,
        text=True,
        env=env,
        timeout=560,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_sharded_sort_equals_simulated():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core import bsp_sort, bsp_sort_sharded, gathered_output, datagen
        p, n_p = 8, 2048
        mesh = Mesh(np.array(jax.devices()), ("procs",))
        for algo in ["det", "iran", "bitonic"]:
            for dist in ["U", "DD", "WR"]:
                x = jnp.asarray(datagen.generate(dist, p, n_p, seed=7))
                r_sim, _ = bsp_sort(x, algorithm=algo)
                r_shd, _ = bsp_sort_sharded(x, mesh, "procs", algorithm=algo)
                assert np.array_equal(gathered_output(r_sim), gathered_output(r_shd)), (algo, dist)
        print("OK")
    """)
    assert "OK" in out


def test_sharded_safe_driver_resumes_and_caches_shard_map():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core import SortConfig, SortExecutor, bsp_sort_sharded_safe, gathered_output
        p, n_p = 8, 2048
        mesh = Mesh(np.array(jax.devices()), ("procs",))
        xadv = jnp.asarray(np.repeat((np.arange(p, dtype=np.int32) * 1000)[:, None], n_p, axis=1))
        cfg = SortConfig(p=p, n_per_proc=n_p, algorithm="iran", pair_capacity="whp")
        ex = SortExecutor()
        res, _, st = bsp_sort_sharded_safe(xadv, mesh, "procs", cfg, executor=ex)
        assert st.retries >= 1, st.as_row()  # escalated past whp
        assert np.array_equal(gathered_output(res), np.sort(np.asarray(xadv).ravel()))
        # regression: repeated calls with the same mesh/cfg must NOT rebuild
        # shard_map — the executor's counting wrapper sees zero new traces
        first = dict(ex.trace_counts)
        assert all(v == 1 for v in first.values()), first
        res2, _, st2 = bsp_sort_sharded_safe(xadv, mesh, "procs", cfg, executor=ex)
        assert dict(ex.trace_counts) == first, (ex.trace_counts, first)
        # one shared prepare callable across all rungs of the ladder
        assert sum(1 for k in first if k[0] == "prepare") == 1
        print("OK")
    """)
    assert "OK" in out


def test_executor_mesh_keyed_cache_no_cross_mesh_reuse():
    """Two different forced 8-device mesh layouts must get distinct sharded
    cache entries (mesh is part of the key) and re-entry with either mesh
    must hit its own entry — no cross-mesh reuse, no retrace. Scoped-down
    single-host version of the ROADMAP multi-host registry validation."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core import SortConfig, SortExecutor, bsp_sort_sharded, gathered_output, datagen
        p, n_p = 8, 512
        devs = np.array(jax.devices())
        mesh_a = Mesh(devs, ("procs",))
        mesh_b = Mesh(devs[::-1].copy(), ("procs",))  # same devices, other layout
        assert mesh_a != mesh_b
        cfg = SortConfig(p=p, n_per_proc=n_p, algorithm="det")
        ex = SortExecutor()
        x = jnp.asarray(datagen.generate("U", p, n_p, seed=3))
        ra, _ = bsp_sort_sharded(x, mesh_a, "procs", cfg, executor=ex)
        rb, _ = bsp_sort_sharded(x, mesh_b, "procs", cfg, executor=ex)
        keys = list(ex.trace_counts)
        # one ("sort","sharded",cfg,nv,mesh,axis) entry per mesh, each traced once
        assert len(keys) == 2 and all(v == 1 for v in ex.trace_counts.values()), ex.trace_counts
        assert {k[4] for k in keys} == {mesh_a, mesh_b}
        bsp_sort_sharded(x, mesh_a, "procs", cfg, executor=ex)
        bsp_sort_sharded(x, mesh_b, "procs", cfg, executor=ex)
        assert all(v == 1 for v in ex.trace_counts.values())  # cache hits only
        assert np.array_equal(gathered_output(ra), gathered_output(rb))
        print("OK")
    """)
    assert "OK" in out


def test_moe_ep_sharded_matches_dense_reference():
    out = _run("""
        import dataclasses, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.configs import get_arch
        from repro.models import moe as moe_mod
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        cfg = dataclasses.replace(get_arch("granite-moe-1b-a400m").reduced(),
                                  moe_experts=8, moe_top_k=2, d_model=32, d_ff=16)
        lp = jax.tree.map(lambda a: a[0], moe_mod.init_moe(jax.random.key(0), cfg, 1))
        x = jax.random.normal(jax.random.key(1), (2, 8, 32)).astype(jnp.bfloat16)
        # dense reference (no dispatch)
        x2d = x.reshape(-1, 32)
        probs, experts, _ = moe_mod._router(x2d, lp["router"], 2)
        ref = jnp.zeros_like(x2d)
        for e in range(8):
            w = (probs * (experts == e)).sum(-1).astype(x.dtype)
            ref += w[:, None] * moe_mod._expert_ffn(x2d, lp["w_gate"][e], lp["w_up"][e], lp["w_down"][e])
        ref = ref.reshape(x.shape)
        mi = moe_mod.MoEMeshInfo(mesh=mesh, model_axis="model", data_axes=("data",))
        got, aux = jax.jit(lambda lp, x: moe_mod.moe_ep(lp, x, cfg, mi, capacity_factor=4.0))(lp, x)
        assert not bool(aux["overflow"])
        np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(ref, np.float32),
                                   atol=3e-2, rtol=3e-2)
        # decode path (psum dense-eval)
        got2, aux2 = jax.jit(lambda lp, x: moe_mod.moe_ep_decode(lp, x, cfg, mi))(lp, x)
        np.testing.assert_allclose(np.asarray(got2, np.float32), np.asarray(ref, np.float32),
                                   atol=3e-2, rtol=3e-2)
        print("OK")
    """)
    assert "OK" in out


def test_small_mesh_train_step_compiles_and_runs():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.configs import get_arch
        from repro.configs.base import ShapeConfig
        from repro.data import synthetic_batch
        from repro.models import Model
        from repro.optim import OptConfig
        from repro.train import init_all, make_train_step
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        cfg = get_arch("tinyllama-1.1b").reduced()
        model = Model(cfg)
        oc = OptConfig(total_steps=5)
        params, opt = init_all(model, oc, jax.random.key(0))
        step = make_train_step(model, oc, mesh)
        batch = synthetic_batch(cfg, ShapeConfig("t", 32, 4, "train"), 0)
        params, opt, m = step(params, opt, batch)
        assert np.isfinite(float(m["loss"]))
        print("OK", float(m["loss"]))
    """)
    assert "OK" in out
