"""Delta subsystem: fold ≡ cold-resort byte-identity, the composite
position lift, rank-merge degenerate spans, tombstones, the planner's
sortedness probe, and the service/serve wiring."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TierStats, datagen
from repro.core.merge import _rank_merge_two
from repro.core.segmented import sort_segments
from repro.delta import (
    SortedView,
    drop_positions,
    lift_positions,
    merge_sorted_runs,
    near_sorted_sort,
    split_sorted_run,
)
from repro.planner import CapacityPlanner, sampled_sortedness

pytestmark = pytest.mark.fast

P = 8


def _stream(dist: str, n: int, seed: int = 0) -> np.ndarray:
    return datagen.generate(dist, 1, n, seed=seed)[0]


# ---------------------------------------------------- near_sorted generator
def test_near_sorted_generator_properties():
    for pattern in datagen.NEAR_SORTED_PATTERNS:
        x = datagen.near_sorted(4096, 0.05, pattern, seed=3)
        assert x.shape == (4096,) and x.dtype == np.int32
        x0 = datagen.near_sorted(4096, 0.0, pattern, seed=3)
        assert np.all(np.diff(x0.astype(np.int64)) >= 0), pattern
    # appended: the base prefix stays sorted, only the tail is fresh
    d = round(4096 * 0.05)
    xa = datagen.near_sorted(4096, 0.05, "appended", seed=3)
    assert np.all(np.diff(xa[: 4096 - d].astype(np.int64)) >= 0)
    with pytest.raises(ValueError):
        datagen.near_sorted(64, 0.1, "zigzag")


def test_near_sorted_deterministic_in_seed():
    a = datagen.near_sorted(1024, 0.02, "scattered", seed=7)
    b = datagen.near_sorted(1024, 0.02, "scattered", seed=7)
    c = datagen.near_sorted(1024, 0.02, "scattered", seed=8)
    assert np.array_equal(a, b) and not np.array_equal(a, c)


# ------------------------------------------------------------ host-side split
def test_split_sorted_run_partitions_and_kept_sorted():
    for pattern in datagen.NEAR_SORTED_PATTERNS:
        x = datagen.near_sorted(4096, 0.02, pattern, seed=5)
        kept, delta = split_sorted_run(x)
        # exact partition of the index range, kept run non-decreasing
        assert np.array_equal(
            np.sort(np.concatenate([kept, delta])), np.arange(4096)
        )
        assert np.all(np.diff(x[kept].astype(np.int64)) >= 0), pattern


def test_split_sorted_run_planted_extreme_lands_in_delta():
    """A single planted record-high early in the run must be classified as
    Δ (local-violation pass), not poison the running max and drop the
    entire sorted suffix."""
    x = np.sort(
        np.random.default_rng(0).integers(0, 2**20, 2048, dtype=np.int64)
    ).astype(np.int32)
    x[10] = np.iinfo(np.int32).max  # local violator: x[10] > x[11]
    kept, delta = split_sorted_run(x)
    assert 10 in delta
    assert kept.size >= 2048 - 4  # at most the plant + its neighbours drop


def test_split_sorted_run_edges():
    kept, delta = split_sorted_run(np.array([], np.int32))
    assert kept.size == 0 and delta.size == 0
    kept, delta = split_sorted_run(np.array([7], np.int32))
    assert kept.size == 1 and delta.size == 0


# --------------------------------------------------------- composite lift
def test_lift_drop_roundtrip_and_stable_order():
    rng = np.random.default_rng(1)
    keys = rng.integers(
        np.iinfo(np.int32).min, np.iinfo(np.int32).max, 512, dtype=np.int64
    ).astype(np.int32)
    keys[::7] = keys[0]  # force duplicates
    pos = np.arange(512, dtype=np.int64)
    comp = lift_positions(keys, pos)
    k2, p2 = drop_positions(np.sort(comp))
    assert np.array_equal(k2, np.sort(keys))
    assert np.array_equal(p2, np.argsort(keys, kind="stable"))


# ----------------------------------------- _rank_merge_two degenerate spans
def _merged(ka, ca, kb, cb, va=(), vb=(), w_out=None):
    sent = jnp.asarray(np.iinfo(np.int32).max, jnp.int32)
    out, vout, cnt = _rank_merge_two(
        jnp.asarray(ka, jnp.int32), jnp.asarray(ca),
        jnp.asarray(kb, jnp.int32), jnp.asarray(cb),
        sent,
        tuple(jnp.asarray(v) for v in va),
        tuple(jnp.asarray(v) for v in vb),
        w_out=w_out,
    )
    return np.asarray(out), [np.asarray(v) for v in vout], int(cnt)


def test_rank_merge_two_empty_a_side():
    out, vout, cnt = _merged(
        np.zeros(0, np.int32), 0, [3, 5, 9, 2**31 - 1], 3,
        va=(np.zeros(0, np.int32),), vb=(np.array([30, 50, 90, 0], np.int32),),
    )
    assert cnt == 3 and np.array_equal(out[:3], [3, 5, 9])
    assert out[3] == np.iinfo(np.int32).max
    assert np.array_equal(vout[0][:3], [30, 50, 90]) and vout[0][3] == 0


def test_rank_merge_two_empty_b_side_truncated():
    # w_out truncation on the pass-through side must re-mask pads so the
    # shortened run is still valid-prefix + sentinel
    out, _, cnt = _merged(
        [4, 8, 2**31 - 1, 2**31 - 1], 2, np.zeros(0, np.int32), 0, w_out=3
    )
    assert cnt == 2 and np.array_equal(out[:2], [4, 8])
    assert out[2] == np.iinfo(np.int32).max


def test_rank_merge_two_both_empty():
    out, _, cnt = _merged(np.zeros(0, np.int32), 0, np.zeros(0, np.int32), 0)
    assert cnt == 0 and out.size == 0


def test_rank_merge_two_zero_count_with_width():
    # width > 0 but count 0 (an all-pad lane): general path, must emit pads
    out, _, cnt = _merged(
        [2**31 - 1, 2**31 - 1], 0, [1, 6, 2**31 - 1, 2**31 - 1], 2
    )
    assert cnt == 2 and np.array_equal(out[:2], [1, 6])
    assert np.all(out[2:] == np.iinfo(np.int32).max)


# ------------------------------------------------------- merge_sorted_runs
def test_merge_sorted_runs_matches_stable_reference():
    rng = np.random.default_rng(2)
    a = np.sort(rng.integers(0, 1000, 300, dtype=np.int64)).astype(np.int32)
    b = np.sort(rng.integers(0, 1000, 170, dtype=np.int64)).astype(np.int32)
    av = (np.arange(300, dtype=np.int64),)
    bv = (np.arange(300, 470, dtype=np.int64),)
    keys, (vals,) = merge_sorted_runs(a, b, av, bv)
    cat = np.concatenate([a, b])
    order = np.argsort(cat, kind="stable")  # a-first on ties = stable concat
    assert np.array_equal(keys, cat[order])
    assert np.array_equal(vals, np.concatenate([av[0], bv[0]])[order])


def test_merge_sorted_runs_empty_sides():
    a = np.sort(np.array([5, 1, 9], np.int32))
    empty = np.array([], np.int32)
    k1, (v1,) = merge_sorted_runs(a, empty, (a.copy(),), (empty.copy(),))
    assert np.array_equal(k1, a) and np.array_equal(v1, a)
    k2, (v2,) = merge_sorted_runs(empty, a, (empty.copy(),), (a.copy(),))
    assert np.array_equal(k2, a) and np.array_equal(v2, a)
    k3, _ = merge_sorted_runs(empty, empty)
    assert k3.size == 0


# ----------------------------------------------- fold ≡ resort ≡ cold sort
@pytest.mark.parametrize("dist", ["U", "G", "B", "DD", "zipf"])
def test_fold_byte_identity_key_only(dist):
    base = np.sort(_stream(dist, 2048, seed=4))
    delta = _stream(dist, 128, seed=9)
    cat = np.concatenate([base, delta])
    ref_k = np.sort(cat)
    ref_o = np.argsort(cat, kind="stable")

    fold_view = SortedView(p=P)
    assert fold_view.fold(base) == "resort"  # install
    assert fold_view.fold(delta) == "fold"
    resort_view = SortedView(p=P)
    resort_view.fold(base)
    assert resort_view.fold(delta, route="resort") == "resort"

    for v in (fold_view, resort_view):
        assert np.array_equal(v.keys, ref_k)
    # cold fused sort of the same concat as the third witness
    cold = sort_segments([cat], P, stats=TierStats(), pair_capacity="exact")
    assert np.array_equal(cold.keys[0], ref_k)
    assert np.array_equal(cold.order[0], ref_o)


@pytest.mark.parametrize("dist", ["U", "DD", "zipf"])
def test_fold_byte_identity_with_payloads(dist):
    base = _stream(dist, 1024, seed=6)
    delta = _stream(dist, 200, seed=7)
    cat = np.concatenate([base, delta])
    pos = np.arange(cat.size, dtype=np.int64)
    ref_o = np.argsort(cat, kind="stable")

    view = SortedView(p=P)
    view.fold(base, (pos[:1024],))
    route = view.fold(delta, (pos[1024:],))
    assert route == "fold"
    assert np.array_equal(view.keys, cat[ref_o])
    # the positional payload IS the stable argsort of the concatenation
    assert np.array_equal(view.payloads[0], ref_o)


def test_fold_empty_delta_and_empty_view():
    base = np.sort(_stream("U", 512, seed=1))
    view = SortedView(p=P)
    view.fold(base)
    n0 = view.n
    view.fold(np.array([], np.int32))
    assert view.n == n0 and np.array_equal(view.keys, base)
    fresh = SortedView(p=P)
    fresh.fold(np.array([], np.int32))
    assert fresh.n == 0


def test_fold_share_routes_to_resort():
    view = SortedView(p=P, fold_max_share=0.25)
    view.fold(np.sort(_stream("U", 512, seed=2)))
    big = _stream("U", 400, seed=3)  # 400/912 > 25% of the merged view
    assert view.fold(big) == "resort"
    cat = np.concatenate([np.sort(_stream("U", 512, seed=2)), big])
    assert np.array_equal(view.keys, np.sort(cat))


# ------------------------------------------------ planner-routed delta sort
@pytest.mark.parametrize("pattern", datagen.NEAR_SORTED_PATTERNS)
def test_near_sorted_sort_matches_cold(pattern):
    x = datagen.near_sorted(4096, 0.02, pattern, seed=11)
    st = TierStats()
    res = near_sorted_sort(x, P, stats=st)
    assert res.tier == "delta"
    assert st.retries == 0  # Δ rung is exact-capacity by construction
    assert np.array_equal(res.keys[0], np.sort(x))
    assert np.array_equal(res.order[0], np.argsort(x, kind="stable"))


# ------------------------------------------------------------- tombstones
def test_tombstone_delete_parity():
    keys = np.array([1, 3, 3, 3, 7, 9, 9], np.int32)
    view = SortedView(p=P)
    view.install(keys, (np.arange(7, dtype=np.int64),))
    removed = view.delete(np.array([3, 3, 5, 9], np.int32))
    assert removed == 3  # two 3s + one 9; the 5 is a miss
    assert np.array_equal(view.keys, [1, 3, 7, 9])
    assert np.array_equal(view.payloads[0], [0, 3, 4, 6])  # first-occurrence


def test_tombstone_update_in_place_preserves_order():
    keys = np.array([2, 2, 5, 8], np.int32)
    view = SortedView(p=P)
    view.install(keys, (np.array([10, 11, 12, 13], np.int64),))
    hits = view.update(
        np.array([2, 8, 4], np.int32), (np.array([99, 88, 77], np.int64),)
    )
    assert hits == 2
    assert np.array_equal(view.keys, keys)  # keys untouched
    assert np.array_equal(view.payloads[0], [99, 11, 12, 88])


def test_pop_min_drains_in_order():
    view = SortedView(p=P)
    view.install(
        np.array([4, 6, 6], np.int32), (np.array([1, 2, 3], np.int64),)
    )
    assert view.pop_min() == (4, (1,))
    assert view.pop_min() == (6, (2,))  # equal keys keep first-seen order
    assert view.pop_min() == (6, (3,))
    with pytest.raises(IndexError):
        view.pop_min()


# ------------------------------------------------------ planner probe/route
def test_sampled_sortedness_values():
    assert sampled_sortedness(np.arange(4096, dtype=np.int32)) == 1.0
    shuffled = _stream("U", 4096, seed=12)
    frac = sampled_sortedness(shuffled)
    assert 0.3 <= frac <= 0.7  # random stream ≈ half its pairs in order
    assert frac == round(frac * 16) / 16  # quantized to the 1/16 grid
    assert sampled_sortedness(np.array([5], np.int32)) == 1.0


def test_planner_routes_near_sorted_to_delta():
    planner = CapacityPlanner()
    x = datagen.near_sorted(2048, 0.02, "scattered", seed=13)
    assert planner.plan([x], P).route == "delta"
    assert planner.plan([x], P).start_tier == "delta"
    assert planner.delta_plans >= 1
    # shuffled stream: not near-sorted, must NOT take the fold
    assert planner.plan([_stream("U", 2048, seed=14)], P).route != "delta"
    # too small: below DELTA_MIN_KEYS the fold's fixed costs dominate
    tiny = datagen.near_sorted(256, 0.02, "scattered", seed=15)
    assert planner.plan([tiny], P).route != "delta"
    # multi-segment batches keep the segmented path
    two = [np.sort(_stream("U", 1024, seed=16)) for _ in range(2)]
    assert planner.plan(two, P).route != "delta"


# ----------------------------------------------------------- service wiring
def test_service_routes_near_sorted_request():
    from repro.core.api import SortExecutor
    from repro.service import ServiceConfig, SortService

    svc = SortService(ServiceConfig(p=P), executor=SortExecutor())
    x = datagen.near_sorted(2048, 0.01, "appended", seed=17)
    res = svc.sort_one(x)
    assert res.tier == "delta"
    assert np.array_equal(res.keys, np.sort(x))
    assert np.array_equal(res.order, np.argsort(x, kind="stable"))
    assert svc.dispatcher.start_tiers.get("delta", 0) >= 1


def test_service_stream_submits_fold():
    from repro.core.api import SortExecutor
    from repro.service import ServiceConfig, SortService

    svc = SortService(ServiceConfig(p=P), executor=SortExecutor())
    stream = object()
    a = _stream("U", 1024, seed=18)
    b = _stream("U", 256, seed=19)
    r1 = svc.submit(a, stream=stream).result()
    assert np.array_equal(r1.keys, np.sort(a))
    r2 = svc.submit(b, stream=stream).result()
    cat = np.concatenate([a, b])
    # the stream view covers the WHOLE history; order indexes into it
    assert np.array_equal(r2.keys, np.sort(cat))
    assert np.array_equal(r2.order, np.argsort(cat, kind="stable"))
    assert r2.tier == "delta"
    assert svc.dispatcher.telemetry()["stream_views"] == 1


# ------------------------------------------------------------ serve wiring
def test_serve_admission_view_and_arrivals():
    import jax

    from repro.configs import get_arch
    from repro.models import Model
    from repro.serve import ServeConfig, ServeEngine

    cfg = get_arch("tinyllama-1.1b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(
        model, params, ServeConfig(max_new_tokens=4, temperature=0.0, eos_id=1)
    )
    rng = np.random.default_rng(20)
    prompts = [rng.integers(5, 50, 8).astype(np.int32) for _ in range(3)]
    late = rng.integers(5, 50, 6).astype(np.int32)

    def arrivals(step):
        return [late] if step == 1 else []

    outs = eng.serve(prompts, slots=2, arrivals=arrivals)
    assert len(outs) == 4  # the arrival joined the batch and completed
    assert all(len(o) == 4 for o in outs)
    ref = np.asarray(eng.generate(jnp.asarray(np.stack(prompts))))
    for i in range(3):  # greedy ⇒ original requests byte-match lockstep
        assert np.array_equal(outs[i], ref[i][: len(outs[i])])
    ref_late = np.asarray(eng.generate(jnp.asarray(late[None, :])))[0]
    assert np.array_equal(outs[3], ref_late[: len(outs[3])])
