"""Property-based tests (hypothesis) for the sorting system's invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import SortConfig, bsp_sort, bsp_sort_safe, gathered_output

settings.register_profile("ci", deadline=None, max_examples=20)
settings.load_profile("ci")


@st.composite
def sort_instances(draw):
    p = draw(st.sampled_from([2, 4, 8]))
    n_p = draw(st.integers(min_value=8, max_value=512))
    algo = draw(st.sampled_from(["det", "iran", "bitonic"]))
    kind = draw(st.sampled_from(["uniform", "dups", "sorted", "reverse", "const"]))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    if kind == "uniform":
        x = rng.integers(-(2**31), 2**31, (p, n_p), dtype=np.int64).astype(np.int32)
    elif kind == "dups":
        x = rng.integers(0, 5, (p, n_p)).astype(np.int32)
    elif kind == "sorted":
        x = np.sort(rng.integers(0, 1000, (p, n_p)).astype(np.int32), axis=None).reshape(p, n_p)
    elif kind == "reverse":
        x = np.sort(rng.integers(0, 1000, (p, n_p)).astype(np.int32), axis=None)[::-1].reshape(p, n_p).copy()
    else:
        x = np.full((p, n_p), 7, np.int32)
    return x, algo


@given(sort_instances())
def test_output_is_sorted_permutation(inst):
    x, algo = inst
    res, _ = bsp_sort(jnp.asarray(x), algorithm=algo)
    assert not bool(res.overflow)
    out = gathered_output(res)
    assert np.array_equal(out, np.sort(x.reshape(-1)))


@given(
    st.sampled_from([2, 4, 8]),
    st.integers(min_value=64, max_value=1024),
    st.floats(min_value=1.0, max_value=8.0),
    st.integers(min_value=0, max_value=10**6),
)
def test_capacity_bound_holds_for_any_omega(p, n_p, omega, seed):
    """Lemma 5.1 is an *a priori* bound: for any ω and any input, the routed
    receive count never exceeds cfg.n_max for the deterministic algorithm."""
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 50, (p, n_p)).astype(np.int32)  # heavy duplicates
    cfg = SortConfig(p=p, n_per_proc=n_p, algorithm="det", omega=omega)
    res, _ = bsp_sort(jnp.asarray(x), cfg)
    assert int(np.max(np.asarray(res.count))) <= cfg.n_max
    assert not bool(res.overflow)


@given(st.integers(min_value=0, max_value=10**6))
def test_float_keys(seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((4, 256)).astype(np.float32)
    res, _ = bsp_sort(jnp.asarray(x), algorithm="det")
    out = gathered_output(res)
    assert np.array_equal(out, np.sort(x.reshape(-1)))


@given(
    st.sampled_from([4, 8]),
    # fixed sizes: every distinct (p, n_p, algo) jit-compiles the whole tier
    # ladder, so a free-ranging n_p would compile ~per example
    st.sampled_from([64, 256, 512]),
    st.sampled_from(["det", "iran", "ran"]),
    st.integers(min_value=0, max_value=10**6),
)
def test_safe_driver_never_truncates(p, n_p, algo, seed):
    """Adversarial skew (each proc's run aimed at ONE bucket) must sort
    correctly through tier escalation — full output, zero dropped keys."""
    rng = np.random.default_rng(seed)
    # constant-per-proc runs in a random proc order: every local run lands in
    # a single destination bucket, overwhelming any w.h.p. pair capacity.
    vals = rng.choice(10**6, size=p, replace=False).astype(np.int32)
    x = np.repeat(vals[:, None], n_p, axis=1)
    cfg = SortConfig(p=p, n_per_proc=n_p, algorithm=algo, pair_capacity="whp")
    res, _, stats = bsp_sort_safe(jnp.asarray(x), cfg)
    assert not bool(res.overflow)
    assert np.array_equal(gathered_output(res), np.sort(x.reshape(-1)))


@given(st.integers(min_value=0, max_value=10**6))
def test_distribution_independence_det(seed):
    """The deterministic algorithm's receive counts depend only on key
    *ranks*: applying a strictly monotone transform leaves counts equal."""
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 10**6, (4, 256)).astype(np.int32)
    res1, _ = bsp_sort(jnp.asarray(x), algorithm="det")
    res2, _ = bsp_sort(jnp.asarray(x * 2 + 1), algorithm="det")
    assert np.array_equal(np.asarray(res1.count), np.asarray(res2.count))
