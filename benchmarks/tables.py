"""One benchmark per paper table (Tables 1-11) + the BSP-model validation.

Distribution/variant naming follows the paper: [DSR]/[DSQ] = deterministic
with radix/comparison local sort, [RSR]/[RSQ] = randomized (IRAN) likewise,
[BSI] = bitonic. Input sets §6.3: [U],[G],[B],[2-G],[S],[DD],[WR].

Paper reference values (Cray T3D seconds) are printed alongside ours where
the paper's table gives them — labeled ``paper_t3d`` — so the shape of the
comparison (ratios between variants/distributions, phase percentages) can be
validated even though absolute CPU numbers differ by hardware.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SortConfig,
    TierStats,
    bsp_sort,
    bsp_sort_safe,
    datagen,
    gathered_output,
    phase_fns,
    predict,
)
from benchmarks.common import emit, predicted_t3d, seq_sort_time, t_comp_per_cmp, timeit

VARIANTS = {
    "RSR": dict(algorithm="iran", local_sort="radix"),
    "RSQ": dict(algorithm="iran", local_sort="lax"),
    "DSR": dict(algorithm="det", local_sort="radix"),
    "DSQ": dict(algorithm="det", local_sort="lax"),
    "BSI": dict(algorithm="bitonic", local_sort="lax"),
}
DISTS = ["U", "G", "2-G", "B", "S", "DD", "WR"]


def _sort_fn(p, n_p, **kw):
    cfg = SortConfig(p=p, n_per_proc=n_p, routing="a2a_dense", pair_capacity="exact", **kw)

    def run(x):
        res, _ = bsp_sort(x, cfg)
        return res.buf

    return jax.jit(run), cfg


def _run_variant(variant: str, dist: str, p: int, n: int) -> Dict:
    n_p = n // p
    fn, cfg = _sort_fn(p, n_p, **VARIANTS[variant])
    x = jnp.asarray(datagen.generate(dist, p, n_p, seed=21))
    t = timeit(fn, x)
    return {"t": t, "cfg": cfg}


def table_1_2_runtime_by_distribution(sizes, p=64, variants=("RSR", "RSQ", "DSR", "DSQ")):
    """Tables 1 & 2: execution time per input set, p=64."""
    for n in sizes:
        for v in variants:
            table = "table1" if v.startswith("R") else "table2"
            row = {"variant": v, "n": n, "p": p}
            for dist in DISTS:
                r = _run_variant(v, dist, p, n)
                row[dist] = round(r["t"], 4)
            seq = seq_sort_time(n)
            row["work_eff_U"] = round(seq / row["U"], 3)
            emit(table, row)


def table_3_scalability(n, ps=(8, 16, 32, 64)):
    """Table 3: scalability on [U] and [WR] + efficiencies."""
    for v in ("RSR", "RSQ", "DSR", "DSQ"):
        for dist in ("U", "WR"):
            row = {"variant": v, "dist": dist, "n": n}
            for p in ps:
                t = _run_variant(v, dist, p, n)["t"]
                row[f"p{p}"] = round(t, 4)
            cfg = SortConfig(p=ps[-1], n_per_proc=n // ps[-1], **{k: vv for k, vv in VARIANTS[v].items()})
            row["pred_t3d_eff"] = round(predicted_t3d(cfg).efficiency, 3)
            row["work_eff"] = round(seq_sort_time(n) / row[f"p{ps[-1]}"], 3)
            emit("table3", row)


def tables_4_7_phase_breakdown(n, ps=(8, 32, 64)):
    """Tables 4-7: per-phase times and percentages ([RSR],[RSQ],[DSR],[DSQ] on [U])."""
    tables = {"RSR": "table4", "RSQ": "table5", "DSR": "table6", "DSQ": "table7"}
    for v, table in tables.items():
        for p in ps:
            n_p = n // p
            cfg = SortConfig(
                p=p, n_per_proc=n_p, routing="a2a_dense", pair_capacity="exact",
                **VARIANTS[v],
            )
            if cfg.algorithm == "bitonic":
                continue
            fns = phase_fns(cfg)
            x = jnp.asarray(datagen.generate("U", p, n_p, seed=21))
            times = {}
            xs = fns["SeqSort"](x)
            times["Ph2_SeqSort"] = timeit(fns["SeqSort"], x)
            splits = fns["Sampling"](xs)
            times["Ph3_Sampling"] = timeit(fns["Sampling"], xs)
            bounds = fns["Prefix"](xs, splits)
            times["Ph4_Prefix"] = timeit(fns["Prefix"], xs, splits)
            buf, cnt, ovf = fns["Routing"](xs, bounds)
            times["Ph5_Routing"] = timeit(fns["Routing"], xs, bounds)
            times["Ph6_Merging"] = timeit(fns["Merging"], buf)
            total = sum(times.values())
            row = {"variant": v, "n": n, "p": p, "total": round(total, 4)}
            for k, t in times.items():
                row[k] = round(t, 4)
                row[f"{k}_pct"] = round(100 * t / total, 1)
            row["seq_pct"] = round(
                100 * (times["Ph2_SeqSort"] + times["Ph6_Merging"]) / total, 1
            )
            emit(table, row)


def table_8_9_comparisons(n, ps=(8, 16, 32, 64)):
    """Tables 8/9: our variants vs the paper's published T3D numbers."""
    paper_t9 = {  # (algorithm, input) -> {p: seconds} — paper Table 9, n=8M
        ("RSR", "U"): {8: 3.16, 16: 1.74, 32: 0.956, 64: 0.526, 128: 0.300},
        ("DSR", "WR"): {8: 3.18, 16: 1.73, 32: 0.945, 64: 0.530, 128: 0.372},
        ("RSQ", "WR"): {8: 3.64, 16: 1.82, 32: 0.938, 64: 0.486, 128: 0.272},
        ("DSQ", "WR"): {8: 3.65, 16: 1.82, 32: 0.930, 64: 0.489, 128: 0.337},
    }
    for (v, dist), ref in paper_t9.items():
        row = {"variant": v, "dist": dist, "n": n}
        for p in ps:
            row[f"p{p}"] = round(_run_variant(v, dist, p, n)["t"], 4)
            if p in ref:
                row[f"paper_t3d_p{p}"] = ref[p]
        # scaling-shape check: our p_min/p_max ratio vs the paper's
        lo, hi = ps[0], ps[-1]
        row[f"our_p{lo}_over_p{hi}"] = round(row[f"p{lo}"] / row[f"p{hi}"], 2)
        row[f"paper_p{lo}_over_p{hi}"] = round(ref[lo] / ref[hi], 2)
        emit("table9", row)


def table_10_scalability_four_variants(sizes, ps=(8, 16, 32, 64)):
    for v in ("DSR", "DSQ", "RSR", "RSQ"):
        for n in sizes:
            row = {"variant": v, "n": n}
            for p in ps:
                row[f"p{p}"] = round(_run_variant(v, "U", p, n)["t"], 4)
            emit("table10", row)


def table_11_dsq_vs_44(n, ps=(8, 16, 32, 64)):
    paper_44 = {8: 0.462, 16: 0.240, 32: 0.137, 64: 0.117}  # [44] on 1e6 keys
    paper_dsq = {8: 0.413, 16: 0.222, 32: 0.127, 64: 0.075}
    row = {"variant": "DSQ", "dist": "U", "n": n}
    for p in ps:
        row[f"p{p}"] = round(_run_variant("DSQ", "U", p, n)["t"], 4)
        row[f"paper_dsq_p{p}"] = paper_dsq[p]
        row[f"paper44_p{p}"] = paper_44[p]
    emit("table11", row)


def table_bsi_baseline(n, p=16):
    """[BSI] vs sample-sort (paper §6.2: bitonic loses beyond small sizes)."""
    for v in ("BSI", "DSQ"):
        t = _run_variant(v, "U", p, n)["t"]
        emit("bsi", {"variant": v, "n": n, "p": p, "t": round(t, 4)})


def table_bsp_model_validation(n, ps=(16, 32, 64, 128)):
    """The paper's §6 predicted-vs-observed methodology.

    (a) Predicted π/μ/efficiency under the paper's T3D constants —
        reproduces the paper's ≈66% (det) / ≥66% (ran) claims at n=8M,p=128.
    (b) Observed max key imbalance vs the ~20% theoretical bound (§6.4).
    """
    from repro.core import theoretical_max_imbalance

    for p in ps:
        for algo in ("det", "iran"):
            cfg = SortConfig(p=p, n_per_proc=n // p, algorithm=algo)
            pred = predicted_t3d(cfg)
            res, _ = bsp_sort(
                jnp.asarray(datagen.generate("U", p, n // p, seed=21)), cfg
            )
            imb = float(np.max(np.asarray(res.count)) / (n / p) - 1.0)
            emit(
                "bsp_model",
                {
                    "algo": algo,
                    "n": n,
                    "p": p,
                    "pred_pi": round(pred.pi, 3),
                    "pred_mu": round(pred.mu, 3),
                    "pred_eff_t3d": round(pred.efficiency, 3),
                    "observed_imbalance": round(imb, 4),
                    "theory_imbalance_bound": round(theoretical_max_imbalance(cfg), 3),
                },
            )


def table_capacity_retry(n, p=16, variants=("RSQ", "RSR", "DSQ")):
    """Capacity-tier retry profile: how often w.h.p. capacity suffices.

    Production setting (pair_capacity="whp") through the overflow-safe
    driver, per §6.3 input set plus [ADV] — the adversarial
    all-keys-to-one-bucket input (each proc's run constant) that no w.h.p.
    bound survives. Row = per-tier attempt counters + the tier that finally
    served the sort + wall time including retries.

    ``wall_s`` is the resumable pipeline (prepare once, re-enter route per
    rung); ``wall_full_s`` re-runs the whole sort per rung (the
    pre-pipeline driver, ``resume=False``); ``retry_cost`` is their ratio —
    the measured full-rerun escalation overhead, only meaningful on rows
    that actually escalate (ADV, and the skewed sets). The win tracks the
    Ph2 share of a tier attempt: ~2× for the radix variants ([RSR], where
    the counting-split local sort dominates), near 1× for [·SQ] on CPU
    where XLA's fused comparison sort is cheap relative to the escalated
    tiers' dense routing buffers.
    """
    n_p = n // p
    adv = np.repeat((np.arange(p, dtype=np.int32) * (2**20))[:, None], n_p, axis=1)
    for v in variants:
        for dist in DISTS + ["ADV"]:
            cfg = SortConfig(
                p=p, n_per_proc=n_p, routing="a2a_dense", pair_capacity="whp",
                **VARIANTS[v],
            )
            x = jnp.asarray(adv) if dist == "ADV" else jnp.asarray(
                datagen.generate(dist, p, n_p, seed=21)
            )
            # warm: compile every tier this input visits, both drivers
            bsp_sort_safe(x, cfg)
            bsp_sort_safe(x, cfg, resume=False)
            stats = TierStats()
            t0 = time.time()
            res, _, stats = bsp_sort_safe(x, cfg, stats=stats)
            wall = time.time() - t0  # sort + retries, compiles amortized
            t0 = time.time()
            bsp_sort_safe(x, cfg, resume=False)
            wall_full = time.time() - t0
            ok = np.array_equal(
                gathered_output(res), np.sort(np.asarray(x).reshape(-1))
            )
            emit(
                "capacity",
                {"variant": v, "dist": dist, "n": n, "p": p,
                 "served_by": stats.last_tier, "complete": ok,
                 "wall_s": round(wall, 4),
                 "wall_full_s": round(wall_full, 4),
                 "retry_cost": round(wall_full / max(wall, 1e-9), 2),
                 **stats.as_row()},
            )


def _timed_service(svc_cfg, ex, arrays, repeats):
    """Warm (compile) one service, then time fresh services over the burst.

    Shared by the ``service`` and ``planner`` tables so both measure under
    the identical warm-then-measure protocol. Returns (mean wall seconds,
    the last timed service — for its telemetry counters).
    """
    from repro.service import SortService

    SortService(svc_cfg, executor=ex).sort_many(arrays)  # warm/compile
    ts, svc = [], None
    for _ in range(repeats):
        svc = SortService(svc_cfg, executor=ex)
        t0 = time.time()
        svc.sort_many(arrays)
        ts.append(time.time() - t0)
    return float(np.mean(ts)), svc


def table_service(n_requests=64, total=1 << 16, p=8, mixes=("U", "G", "B", "DD", "zipf")):
    """Sort-service dispatch: fused segmented sort vs per-request sorts.

    A mixed-size batch of ``n_requests`` concurrent sort requests (sizes
    Zipf-skewed — a few big, a long tail of tiny) per key mix. ``fused``
    packs the whole batch into one tagged segmented BSP sort through the
    service's batch former; ``per_req`` dispatches each request as its own
    batch (``max_batch_keys=1``) — the pre-service regime where every small
    request pays a full p-lane sort plus its own escalation walk.

    ``*_buckets`` counts the distinct compiled (n_per_proc) shapes each
    path touched: the fused path compiles the segmented sort once per pow2
    bucket while per-request dispatch compiles one ladder per request-size
    bucket. Warmed before timing, so ``speedup`` is dispatch + sort work,
    not compile amortization.
    """
    from repro.core.api import SortExecutor
    from repro.service import ServiceConfig
    from benchmarks.common import REPEATS

    sizes = datagen.zipf_sizes(n_requests, total, seed=21)
    for mix in mixes:
        arrays = [
            datagen.generate(mix, 1, int(s), seed=100 + i)[0]
            for i, s in enumerate(sizes)
        ]
        ex_f = SortExecutor()
        t_fused, svc_f = _timed_service(
            ServiceConfig(p=p, max_batch_keys=2 * total), ex_f, arrays, REPEATS
        )
        ex_r = SortExecutor()
        t_per, svc_r = _timed_service(
            ServiceConfig(p=p, max_batch_keys=1), ex_r, arrays, REPEATS
        )
        buckets = lambda ex: len({k[2].n_per_proc for k in ex.trace_counts})
        lat = np.fromiter(svc_f.latencies, np.float64)[-n_requests:]
        emit(
            "service",
            {
                "mix": mix, "n_req": n_requests, "keys": total, "p": p,
                "wall_fused_s": round(t_fused, 4),
                "wall_per_req_s": round(t_per, 4),
                "speedup": round(t_per / max(t_fused, 1e-9), 2),
                "fused_keys_per_s": int(total / max(t_fused, 1e-9)),
                "per_req_keys_per_s": int(total / max(t_per, 1e-9)),
                "fused_buckets": buckets(ex_f),
                "per_req_buckets": buckets(ex_r),
                "fused_batches": svc_f.batches_dispatched,
                "served_by": svc_f.stats.last_tier,
                "lat_p99_ms": round(float(np.quantile(lat, 0.99)) * 1e3, 2),
                "retries_fused": svc_f.stats.retries,
                "retries_per_req": svc_r.stats.retries,
            },
        )


def table_planner(n_requests=64, total=1 << 16, p=8, mixes=("U", "G", "B", "DD", "zipf")):
    """Capacity planner vs the PR 3 tier rule on fused multi-segment batches.

    One Zipf-size mix of ``n_requests`` concurrent requests per key mix,
    fused into a single batch. ``rule`` is the PR 3 dispatch (contiguous
    packing, every multi-segment batch pinned to the ``exact`` pair
    capacity); ``planner`` is the adaptive path (striped packing, the
    segment-aware whp bound picking a sub-exact ``planned`` starting tier,
    traffic-learned rungs). Both warmed, so ``speedup`` is routing-volume
    work, not compile amortization. ``planned_cap``/``exact_cap`` show the
    per-(src,dst) capacity each path routed with; ``start_tier`` must be
    sub-exact with zero retries for the planner to be a win (a plan that
    faults pays the wasted attempt — visible in ``retries_planner``).
    """
    from repro.core.api import SortExecutor
    from repro.service import ServiceConfig
    from repro.planner import fingerprint_arrays, planned_cap_for
    from benchmarks.common import REPEATS

    sizes = datagen.zipf_sizes(n_requests, total, seed=21)
    for mix in mixes:
        arrays = [
            datagen.generate(mix, 1, int(s), seed=100 + i)[0]
            for i, s in enumerate(sizes)
        ]
        cap_keys = 2 * total  # one fused batch per flush
        ex_r = SortExecutor()
        t_rule, svc_r = _timed_service(
            ServiceConfig(p=p, pair_capacity="exact", max_batch_keys=cap_keys),
            ex_r, arrays, REPEATS,
        )
        ex_p = SortExecutor()
        t_plan, svc_p = _timed_service(
            ServiceConfig(p=p, max_batch_keys=cap_keys), ex_p, arrays, REPEATS
        )
        fp = fingerprint_arrays(arrays, p)
        omega, cap = planned_cap_for(fp)
        emit(
            "planner",
            {
                "mix": mix, "n_req": n_requests, "keys": total, "p": p,
                "wall_rule_s": round(t_rule, 4),
                "wall_planner_s": round(t_plan, 4),
                "speedup": round(t_rule / max(t_plan, 1e-9), 2),
                "start_tier": max(svc_p.start_tiers, key=svc_p.start_tiers.get),
                "planned_cap": cap,
                "exact_cap": fp.n_per_proc,
                "omega": round(omega, 2),
                "dup_frac": round(fp.dup_fraction, 3),
                "lane_spread_max": fp.lane_spread_max,
                "retries_planner": svc_p.stats.retries,
                "retries_rule": svc_r.stats.retries,
            },
        )


def table_service_soak(
    n_requests=48, total=1 << 15, p=8, arrival_hz=400.0, mix="zipf"
):
    """Open-loop soak: Poisson arrivals against the async dispatch pipeline.

    ``n_requests`` Zipf-sized requests arrive on a seeded Poisson clock
    (open loop — the arrival schedule never waits for the service, so
    queueing delay is measured, not hidden), pumped through the
    admission-aware ``flush_ready`` former as they accumulate. A final
    burst worth two full batches lands before the closing flush, so the
    drain structurally holds ``max_in_flight`` batches launched at once —
    the ``overlapped`` column asserts that later batches' host
    plan/pack/launch happened while earlier flights' device work was
    outstanding, and ``in_flight_peak`` is an identity column (the
    pipeline must saturate its depth deterministically).

    The headline metric is ``lat_p99_ms`` — submit→result wall latency
    under load, tail quantile — gated by scripts/bench_diff.py under its
    looser percentile tolerance. ``complete``/``failsink_errors`` are
    identity columns: a soak that drops or fails a request is a structural
    failure, not a slow run.
    """
    from repro.core.api import SortExecutor
    from repro.service import ServiceConfig, SortService

    rng = np.random.default_rng(21)
    sizes = datagen.zipf_sizes(n_requests, total, seed=21)
    arrays = [
        datagen.generate(mix, 1, int(s), seed=300 + i)[0]
        for i, s in enumerate(sizes)
    ]
    cap = 1 << 14
    # burst tail: two full batches' worth of keys submitted at once, so the
    # closing flush always has >= 2 batches to keep in flight
    burst = [
        datagen.generate(mix, 1, cap // 8, seed=600 + i)[0] for i in range(16)
    ]
    gaps = rng.exponential(1.0 / arrival_hz, n_requests)
    deadlines = np.cumsum(gaps)
    cfg = ServiceConfig(p=p, max_batch_keys=cap, max_in_flight=2)
    ex = SortExecutor()
    SortService(cfg, executor=ex).sort_many(arrays + burst)  # warm/compile

    svc = SortService(cfg, executor=ex)
    futs = []
    t0 = time.time()
    for i, a in enumerate(arrays):  # open loop: schedule, don't backpressure
        lag = deadlines[i] - (time.time() - t0)
        if lag > 0:
            time.sleep(lag)
        futs.append(svc.submit(a))
        svc.flush_ready()  # full batches launch mid-stream, tail held
    futs += [svc.submit(a) for a in burst]  # no trigger: queued unlaunched
    svc.flush()  # drain: >= 2 batches in flight before the first wait
    wall = time.time() - t0

    complete = all(
        np.array_equal(f.result().keys, np.sort(a))
        for f, a in zip(futs, arrays + burst)
    )
    tele = svc.telemetry()
    n_keys = int(sum(s.shape[0] for s in arrays + burst))
    emit(
        "soak",
        {
            "mix": mix, "n_req": len(futs), "keys": n_keys, "p": p,
            "arrival_hz": arrival_hz,
            "max_in_flight": cfg.max_in_flight,
            "in_flight_peak": tele["dispatch"]["in_flight_peak"],
            "overlapped": tele["dispatch"]["overlapped_launches"] >= 1,
            "complete": complete,
            "failsink_errors": tele["dispatch"]["failsink_errors"],
            "wall_s": round(wall, 4),
            "keys_per_s": int(n_keys / max(wall, 1e-9)),
            "lat_p50_ms": tele["lat_p50_ms"],
            "lat_p99_ms": tele["lat_p99_ms"],
            "lat_mean_ms": tele["lat_mean_ms"],
            "retries": svc.stats.retries,
        },
    )


def table_chaos(n_requests=64, total=1 << 15, p=8):
    """Chaos soak: a seeded FaultPlan against the hardened dispatch pipeline.

    The same Zipf request mix runs twice through services sharing one
    executor: once clean (the reference), once under a
    :class:`repro.chaos.FaultPlan` injecting capacity faults (forced
    ladder escalations), transient launch faults (failsink bisection +
    recovery), two poison rids (terminal, must fail *naming the rid*) and
    explicit straggler delays (feeding the EWMA monitor). The gate is the
    recovery contract, not speed:

    * ``innocents_failed`` — identity 0: every non-poison request's future
      resolves successfully despite the faults around it;
    * ``byte_identical`` — identity True: each innocent's sorted keys and
      stable order match the un-faulted reference run exactly (injected
      escalations and re-dispatches may change *which tier* serves a
      request, never its bytes);
    * ``poison_failed`` — identity 2: both poison futures carry a
      ``SortServiceError`` naming their rid;
    * ``recovered_batches`` — identity: failsink re-dispatches that
      completed; the count is deterministic because every fault decision
      is a pure hash of (seed, kind, key) and dispatch is FIFO;
    * ``lat_p99_ms`` — the cost of recovery on the tail, gated under the
      percentile tolerance.
    """
    from repro.chaos import FaultPlan
    from repro.core.api import SortExecutor
    from repro.service import ServiceConfig, SortService, SortServiceError

    sizes = datagen.zipf_sizes(n_requests, total, seed=23)
    arrays = [
        datagen.generate("zipf", 1, int(s), seed=900 + i)[0]
        for i, s in enumerate(sizes)
    ]
    poison = (11, 42)  # rids = submit order on a fresh service
    cap = 1 << 14
    cfg = dict(p=p, max_batch_keys=cap, max_in_flight=2)
    ex = SortExecutor()
    SortService(ServiceConfig(**cfg), executor=ex).sort_many(arrays)  # warm

    # reference: clean service, same arrays — per-rid expected bytes
    ref_svc = SortService(ServiceConfig(**cfg), executor=ex)
    ref_futs = [ref_svc.submit(a) for a in arrays]
    ref_svc.flush()
    ref = {f.rid: f.result() for f in ref_futs}

    plan = FaultPlan(
        seed=23,
        capacity_fault_rate=0.25,
        capacity_fault_rungs=(0,),
        poison_rids=poison,
        transient_error_rate=0.35,
        straggle_flights=(1, 5),
        straggle_s=0.002,
    )
    svc = SortService(ServiceConfig(**cfg, chaos=plan), executor=ex)
    t0 = time.time()
    futs = [svc.submit(a) for a in arrays]
    svc.flush()
    wall = time.time() - t0

    innocents_failed = 0
    byte_identical = True
    poison_failed = 0
    for f in futs:
        exc = f.exception()
        if f.rid in poison:
            if isinstance(exc, SortServiceError) and f"rid={f.rid}" in str(exc):
                poison_failed += 1
            continue
        if exc is not None:
            innocents_failed += 1
            continue
        r = f.result()
        if not (
            np.array_equal(r.keys, ref[f.rid].keys)
            and np.array_equal(r.order, ref[f.rid].order)
        ):
            byte_identical = False
    tele = svc.telemetry()
    n_keys = int(sum(a.shape[0] for a in arrays))
    emit(
        "chaos",
        {
            "n_req": n_requests, "keys": n_keys, "p": p,
            "poison": len(poison),
            "injected_total": plan.injected_total,
            "capacity_faults": plan.injected.get("capacity_fault", 0),
            "launch_faults": plan.injected.get("launch_error", 0)
            + plan.injected.get("poison", 0),
            "straggles": plan.injected.get("straggle", 0),
            "innocents_failed": innocents_failed,
            "poison_failed": poison_failed,
            "byte_identical": byte_identical,
            "recovered_batches": tele["dispatch"]["recovered_batches"],
            "failsink_splits": tele["dispatch"]["failsink_splits"],
            "wall_s": round(wall, 4),
            "keys_per_s": int(n_keys / max(wall, 1e-9)),
            "lat_p50_ms": tele["lat_p50_ms"],
            "lat_p99_ms": tele["lat_p99_ms"],
            "retries": svc.stats.retries,
        },
    )


def _hotpath_a2a_counts(p: int) -> Dict[str, int]:
    """HLO ``all_to_all`` op counts per (exchange, kv) combo (one subprocess,
    shared harness: benchmarks.common.sharded_collective_counts)."""
    from benchmarks.common import sharded_collective_counts

    combos = {
        f"{exchange}/kv{nv}": dict(
            algorithm="iran", pair_capacity="whp", exchange=exchange, nv=nv
        )
        for exchange in ("per_array", "fused")
        for nv in (0, 1)
    }
    counts = sharded_collective_counts(combos, p=p)
    return {name: c["all_to_all"] for name, c in counts.items()}


def table_hotpath(n, p=8, mixes=("U", "G", "B", "DD", "zipf")):
    """Route→merge hot path: {sort, tree} tail × {per-array, fused} exchange.

    The fused exchange packs key + payload rows into one byte buffer so the
    Ph5 data superstep issues exactly ONE ``all_to_all`` (``a2a_ops`` counts
    the HLO ops of the whole sort: 1 count-bookkeeping + 1 data superstep
    fused, vs 1 + (1+R) per-array). The tree tail rank-merges the received
    sorted runs — payload-generic since this PR, so the key-value rows
    exercise it end-to-end. Wall-clock is the vmap runner at the *exact*
    pair capacity (deterministically clean on every mix — escalation
    behaviour is the ``capacity`` table's job); ``speedup`` is each row
    against the per-array sort-tail baseline of the same (mix, kv).
    ``a2a_ops`` is an identity column for bench_diff: a collective-count
    regression fails structurally, not within a timing tolerance.

    Key-only rows compile to the identical program under both exchange
    modes (fusing engages only with more than one array), so each
    (mix, tail) key-only wall is measured once and reported on both rows —
    re-timing the same callable would only add shared-core noise to the
    gated baseline.
    """
    n_p = n // p
    counts = _hotpath_a2a_counts(p)  # shape-independent op counts
    for mix in mixes:
        x = jnp.asarray(datagen.generate(mix, p, n_p, seed=21))
        ids = jnp.arange(p * n_p, dtype=jnp.int32).reshape(p, n_p)
        for kv in (0, 1):
            vals = [ids] if kv else []
            base = None
            for tail in ("sort", "tree"):
                measured = None  # (wall, complete) reused across kv=0 rows
                for exchange in ("per_array", "fused"):
                    cfg = SortConfig(
                        p=p, n_per_proc=n_p, algorithm="iran",
                        pair_capacity="exact", merge=tail, exchange=exchange,
                    )

                    def run(xa, va, cfg=cfg):
                        res, vbufs = bsp_sort(xa, cfg, values=va)
                        return res.buf, res.count, vbufs

                    if measured is None or kv:
                        fn = jax.jit(run)
                        # tree-vs-sort deltas are ~20% at this size: take the
                        # best of more repeats than the global default so the
                        # speedup column is trajectory-stable, not timer noise
                        t = timeit(fn, x, vals, repeats=6)
                        buf, cnt, _ = fn(x, vals)
                        flat = np.concatenate(
                            [np.asarray(buf)[k, : np.asarray(cnt)[k]]
                             for k in range(p)]
                        )
                        ok = np.array_equal(
                            flat, np.sort(np.asarray(x).ravel())
                        )
                        measured = (t, ok)
                    t, ok = measured
                    if base is None:
                        base = t  # per-array sort tail == the seed layout
                    emit(
                        "hotpath",
                        {
                            "mix": mix, "n": n, "p": p, "kv": kv,
                            "tail": tail, "exchange": exchange,
                            "a2a_ops": counts[f"{exchange}/kv{kv}"],
                            "wall_s": round(t, 4),
                            "speedup": round(base / max(t, 1e-9), 2),
                            "complete": ok,
                        },
                    )


def table_radix(n, p=16, repeats=4):
    """Count-then-distribute radix route vs the sampling route, per key mix.

    Both sides run through the overflow-safe driver so the walls include
    the real production cost of each route: the sample side pays the
    splitter superstep plus any w.h.p. capacity retries; the radix side
    pays one counting pass and a small host read of the exact boundary
    matrix, then routes through a single exact-capacity rung.

    Mixes pick the regimes the route selector cares about: ``dense_int``
    (domain = 4·p — few distinct values per splitter bucket, so sampled
    splitters quantize badly and the w.h.p. capacity faults) and
    ``expert_id`` (domain = p — MoE dispatch keys) are the radix home
    turf; ``U``/``U64`` are balanced wide-range keys where both routes
    run clean and the sides break even (the skipped splitter superstep
    is small on the simulated-processor substrate) — break-even at wide
    domains is the ``U`` row's documentation, not a regression; and
    ``zipf_skew`` is adversarial for range bucketing
    (heavy mass at small keys lands in one radix bucket, so the exact
    capacity approaches the full buffer — the planner routes such batches
    to sample; the row documents why). ``retries_radix`` is an identity
    column: the radix route cannot overflow, so any nonzero value is a
    structural failure, not a slow run. ``complete`` likewise.
    """
    n_p = n // p
    rng = np.random.default_rng(21)
    mixes = {
        "dense_int": datagen.dense_int(p, n_p, seed=21, domain=4 * p),
        "expert_id": datagen.dense_int(p, n_p, seed=22, domain=p),
        "U": datagen.generate("U", p, n_p, seed=21),
        "U64": rng.integers(-(2**62), 2**62, (p, n_p), dtype=np.int64),
        "zipf_skew": datagen.generate("zipf", p, n_p, seed=21),
    }
    from jax.experimental import enable_x64

    for mix, xs in mixes.items():
        scope = enable_x64 if xs.dtype == np.int64 else _null_scope
        with scope():
            x = jnp.asarray(xs)

            def timed(cfg):
                bsp_sort_safe(x, cfg)  # warm: compile every rung visited
                ts, st = [], None
                for _ in range(repeats):
                    st = TierStats()
                    t0 = time.time()
                    res, _, st = bsp_sort_safe(x, cfg, stats=st)
                    ts.append(time.time() - t0)
                return float(np.min(ts)), res, st

            t_r, res_r, st_r = timed(
                SortConfig(p=p, n_per_proc=n_p, routing="a2a_dense",
                           route="radix", pair_capacity="exact")
            )
            t_s, res_s, st_s = timed(
                SortConfig(p=p, n_per_proc=n_p, routing="a2a_dense",
                           pair_capacity="whp")
            )
            ref = np.sort(np.asarray(xs).reshape(-1))
            ok = np.array_equal(gathered_output(res_r), ref) and np.array_equal(
                gathered_output(res_s), ref
            )
            emit(
                "radix",
                {"mix": mix, "n": n, "p": p,
                 "wall_radix_s": round(t_r, 4),
                 "wall_sample_s": round(t_s, 4),
                 "speedup": round(t_s / max(t_r, 1e-9), 2),
                 "retries_radix": st_r.retries,
                 "retries_sample": st_s.retries,
                 "served_by_sample": st_s.last_tier,
                 "complete": ok},
            )


class _null_scope:
    """No-op stand-in for ``enable_x64`` on 32-bit mixes."""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


def table_duplicate_handling_overhead(n, p=64):
    """§6.1: duplicate handling costs 3-6%; compare [U] vs all-duplicates."""
    fn, cfg = _sort_fn(p, n // p, algorithm="det", local_sort="lax")
    xu = jnp.asarray(datagen.generate("U", p, n // p, seed=21))
    xd = jnp.zeros((p, n // p), jnp.int32)  # every key identical
    tu, td = timeit(fn, xu), timeit(fn, xd)
    emit(
        "duplicates",
        {"n": n, "p": p, "t_U": round(tu, 4), "t_allsame": round(td, 4),
         "ratio": round(td / tu, 3)},
    )


def table_obs(n, p=8):
    """Traced-run observability: per-route h volume, imbalance, (g, L) fit.

    Every data row is a *traced* overflow-safe sort (``SortConfig`` with
    ``obs=tracer``); one tracer is shared across the whole table so the
    final ``fit`` row can regress the measured route-span walls against
    their traced h volumes and superstep counts (BSP cost w + g·h + L →
    per-word gap ``fit_g_s``, sync latency ``fit_l_s``). Two sizes per
    route give the regression its h spread.

    ``h_words`` is the traced max-per-processor relation size in 32-bit
    words — a pure function of the seeded input and the route, so it is an
    identity column: drift means the routing changed, not that it got
    slower. ``imbalance`` (max/mean received keys) is likewise seeded-
    deterministic but diffed as a metric (lower is better); ``imb_ok``
    checks it against the paper's §6.4 bound (1 + eps) and must hold on
    the balanced [U] mix for the direct routes. The ``segmented`` rows run
    the fused multi-request path, whose pad composites sort to the global
    tail — their ``imb_ok`` documents how far lane padding pushes the
    received skew rather than asserting the w.h.p. theory.
    """
    from repro import obs
    from repro.core import (
        pack_segments,
        segmented_sort_safe,
        theoretical_max_imbalance,
    )

    tracer = obs.Tracer()

    def report(route, nn, bound_cfg, run):
        mark = len(tracer.spans)
        t0 = time.time()
        ok = bool(run())
        wall = time.time() - t0
        spans = [s for s in tracer.spans[mark:] if s["name"] == "route"]
        h = max((s["args"]["h_words"] for s in spans), default=0)
        imb = max((s["args"]["imbalance"] for s in spans), default=0.0)
        bound = 1.0 + theoretical_max_imbalance(bound_cfg)
        emit(
            "obs",
            {"mix": "U", "route": route, "p": p, "n": nn,
             "h_words": h,
             "imb_ok": bool(imb <= bound),
             "imbalance": round(float(imb), 4),
             "wall_s": round(wall, 4),
             "complete": ok},
        )

    for nn in (n // 2, n):
        n_p = nn // p
        xs = datagen.generate("U", p, n_p, seed=21)
        x = jnp.asarray(xs)
        ref = np.sort(np.asarray(xs).ravel())
        for route, kw in (
            ("sample", dict(pair_capacity="whp")),
            ("radix", dict(route="radix", pair_capacity="exact")),
        ):
            base = dict(p=p, n_per_proc=n_p, routing="a2a_dense", **kw)
            cfg = SortConfig(**base)
            bsp_sort_safe(x, cfg)  # warm: compile outside the timed run
            tcfg = SortConfig(obs=tracer, **base)

            def run(x=x, tcfg=tcfg, ref=ref):
                res, _, _ = bsp_sort_safe(x, tcfg)
                return np.array_equal(gathered_output(res), ref)

            report(route, nn, cfg, run)

        segs = [np.asarray(a, np.int32) for a in np.array_split(xs.ravel(), 7)]
        packed = pack_segments(segs, p=p)
        seg_ref = [np.sort(s) for s in segs]
        segmented_sort_safe(packed)  # warm (configs are obs-blind equal)

        def run_seg(packed=packed, seg_ref=seg_ref):
            out = segmented_sort_safe(packed, obs=tracer)
            return all(
                np.array_equal(k, r) for k, r in zip(out.keys, seg_ref)
            )

        report(
            "segmented", nn,
            SortConfig(p=packed.p, n_per_proc=packed.n_per_proc), run_seg,
        )

    f = tracer.fit()
    emit(
        "obs",
        {"mix": "U", "route": "fit", "p": p, "n": n,
         "fit_ok": f.ok,
         "n_samples": f.n_samples,
         "fit_g_s": round(f.g_s_per_word, 9),
         "fit_l_s": round(f.l_s, 6),
         "r2": round(f.r2, 4)},
    )


def table_delta(n, p=8, fracs=(0.001, 0.01, 0.05, 0.2), repeats=2):
    """Delta fold vs full resort across Δ/n, per near-sorted pattern.

    Each row times the planner-routed delta path (``repro.delta``: host
    split → Δ-sized fused sort of the out-of-place composites → one rank
    merge) against a cold full sort of the same stream through the
    segmented machinery at the exact capacity (the strongest retry-free
    baseline — a w.h.p. start could only add retries to the full side).
    Patterns are the ``datagen.near_sorted`` families; Δ/n spans the
    ISSUE grid 0.1%–20%.

    Identity columns: ``delta_n`` (the split is deterministic on the
    seeded stream), ``retries_delta`` (the Δ sort runs ONE exact-capacity
    Δ-sized rung — any nonzero value is structural, not slow),
    ``folds``/``resorts`` (the SortedView leg's route counts: the install
    is a resort, the Δ batch must fold — a fold that became a resort is a
    routing regression), and ``complete`` (byte-identity of keys AND
    stable argsort vs numpy for both timed paths and the view leg).
    ``speedup`` = wall_full / wall_delta, higher is better.
    """
    from repro.core.segmented import sort_segments
    from repro.delta import SortedView, near_sorted_sort, split_sorted_run

    rng = np.random.default_rng(35)
    for pattern in ("appended", "scattered", "rotated"):
        for frac in fracs:
            x = datagen.near_sorted(n, frac, pattern, seed=21)
            _, delta_idx = split_sorted_run(x)
            ref_keys = np.sort(x)
            ref_order = np.argsort(x, kind="stable")

            def run_delta():
                st = TierStats()
                res = near_sorted_sort(x, p, stats=st)
                return res, st

            def run_full():
                st = TierStats()
                res = sort_segments([x], p, stats=st, pair_capacity="exact")
                return res, st

            run_delta(), run_full()  # warm: compile both paths untimed
            t_d = t_f = float("inf")
            for _ in range(repeats):
                t0 = time.time()
                res_d, st_d = run_delta()
                t_d = min(t_d, time.time() - t0)
                t0 = time.time()
                res_f, st_f = run_full()
                t_f = min(t_f, time.time() - t0)
            ok = (
                np.array_equal(res_d.keys[0], ref_keys)
                and np.array_equal(res_d.order[0], ref_order)
                and np.array_equal(res_f.keys[0], ref_keys)
                and np.array_equal(res_f.order[0], ref_order)
            )

            # SortedView leg (untimed): install = resort, Δ batch = fold
            view = SortedView(p=p)
            routes = [view.fold(x)]
            d2 = rng.integers(0, 2**31, max(1, len(delta_idx)), dtype=np.int64)
            d2 = d2.astype(np.int32)
            routes.append(view.fold(d2))
            cat = np.concatenate([x, d2])
            ok = ok and np.array_equal(view.keys, np.sort(cat))

            emit(
                "delta",
                {"pattern": pattern, "n": n, "p": p, "frac": frac,
                 "delta_n": int(delta_idx.size),
                 "wall_delta_s": round(t_d, 4),
                 "wall_full_s": round(t_f, 4),
                 "speedup": round(t_f / max(t_d, 1e-9), 2),
                 "retries_delta": st_d.retries,
                 "retries_full": st_f.retries,
                 "folds": routes.count("fold"),
                 "resorts": routes.count("resort"),
                 "complete": ok},
            )
