"""Benchmark harness — one function per paper table. CSV to stdout.

    PYTHONPATH=src python -m benchmarks.run [--full] [--tables table1,table3]
                                            [--json OUT]

Default (quick) sizes keep a single-CPU-core run to a few minutes; --full
uses the paper's 1M/4M/8M sizes. ``--json OUT`` additionally writes every
emitted row as ``OUT/BENCH_<table>.json`` (inputs are seeded, so the files
form a diffable perf trajectory across commits). The simulated-processor
methodology and the predicted-vs-observed framing are described in
benchmarks/common.py and EXPERIMENTS.md §Paper-validation.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import tables
from benchmarks.common import emit, t_comp_per_cmp, write_json

M = 1 << 20


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-size inputs (1M/4M/8M)")
    ap.add_argument("--tables", type=str, default="all")
    ap.add_argument(
        "--json", type=str, default=None, metavar="OUT",
        help="also write BENCH_<table>.json files into the OUT directory",
    )
    ap.add_argument(
        "--trace", type=str, default=None, metavar="OUT",
        help="run a traced profile sort and write a Chrome trace_event JSON "
        "to OUT (open in chrome://tracing or Perfetto), then print the "
        "fitted (g, L) cost report",
    )
    args = ap.parse_args()

    if args.full:
        from benchmarks import common

        common.REPEATS = 4
        sizes_12 = [M, 4 * M]
        n_3 = 8 * M
        n_phase = 4 * M
        sizes_10 = [M, 4 * M]
        n_9 = 8 * M
        ps = (8, 16, 32, 64)
    else:
        sizes_12 = [M // 4]
        n_3 = M // 4
        n_phase = M // 4
        sizes_10 = [M // 16, M // 4]
        n_9 = M // 4
        ps = (8, 16, 32)

    want = None if args.tables == "all" else set(args.tables.split(","))

    def go(name, fn, *a, **kw):
        if want is not None and name not in want:
            return
        t0 = time.time()
        fn(*a, **kw)
        emit("meta", {"table": name, "wall_s": round(time.time() - t0, 1)})

    emit("meta", {"t_comp_per_cmp_ns": round(t_comp_per_cmp() * 1e9, 3)})
    go("table1", tables.table_1_2_runtime_by_distribution, sizes_12, p=32)
    go("table3", tables.table_3_scalability, n_3, ps=ps)
    go("table4_7", tables.tables_4_7_phase_breakdown, n_phase, ps=ps)
    go("table9", tables.table_8_9_comparisons, n_9, ps=ps)
    go("table10", tables.table_10_scalability_four_variants, sizes_10, ps=ps)
    go("table11", tables.table_11_dsq_vs_44, M // 4, ps=ps)
    go("bsi", tables.table_bsi_baseline, M // 4)
    go("bsp_model", tables.table_bsp_model_validation, n_3 if not args.full else 8 * M)
    go("duplicates", tables.table_duplicate_handling_overhead, M // 4)
    go("capacity", tables.table_capacity_retry, M // 4 if not args.full else 4 * M,
       p=16 if not args.full else 64)
    go("hotpath", tables.table_hotpath, M // 16 if not args.full else M, p=8)
    go("radix", tables.table_radix, M // 16 if not args.full else M,
       p=8 if not args.full else 16)
    go("obs", tables.table_obs, M // 16 if not args.full else M // 4, p=8)
    go("delta", tables.table_delta, M // 16 if not args.full else M, p=8)
    go("service", tables.table_service, n_requests=64,
       total=M // 16 if not args.full else M, p=8 if not args.full else 16)
    go("planner", tables.table_planner, n_requests=64,
       total=M // 16 if not args.full else M, p=8 if not args.full else 16)
    go("soak", tables.table_service_soak,
       n_requests=48 if not args.full else 128,
       total=M // 32 if not args.full else M // 4,
       arrival_hz=400.0 if not args.full else 800.0)
    go("chaos", tables.table_chaos, n_requests=64,
       total=M // 32 if not args.full else M // 4)

    if args.json:
        for path in write_json(args.json):
            emit("meta", {"json": path})

    if args.trace:
        traced_profile(args.trace, full=args.full)


def traced_profile(out: str, full: bool) -> None:
    """One traced run per route; Chrome trace to ``out`` + cost report.

    The profile sorts the balanced [U] mix through the sampling and the
    radix routes at two sizes each (the (g, L) regression needs h to
    vary), saves the merged timeline as Chrome ``trace_event`` JSON and
    prints the fitted-machine cost report: effective g (s/word), L
    (s/superstep), and per-superstep predicted-vs-measured rows.
    """
    import json

    import jax.numpy as jnp

    from repro import obs
    from repro.core import SortConfig, bsp_sort_safe, datagen

    p, n_p = (16, M // 64) if full else (8, M // 128)
    tracer = obs.Tracer()
    for route, kw in (
        ("sample", dict(pair_capacity="whp")),
        ("radix", dict(route="radix", pair_capacity="exact")),
    ):
        for scale in (1, 2):
            base = dict(
                p=p, n_per_proc=n_p * scale, routing="a2a_dense", **kw
            )
            x = jnp.asarray(datagen.generate("U", p, n_p * scale, seed=21))
            bsp_sort_safe(x, SortConfig(**base))  # warm: compile untimed
            bsp_sort_safe(x, SortConfig(obs=tracer, **base))
    path = tracer.save(out)
    with open(path) as f:
        problems = obs.validate_chrome_trace(json.load(f))
    problems += obs.validate_spans(tracer)
    rep = tracer.cost_report()
    fit = rep["fit"]
    emit(
        "trace",
        {"path": path, "valid": not problems, "spans": len(tracer.spans),
         "fit_ok": fit["ok"], "n_samples": fit["n_samples"],
         "g_s_per_word": round(fit["g_s_per_word"], 9),
         "l_s": round(fit["l_s"], 6), "r2": round(fit["r2"], 4),
         "max_imbalance": round(rep["max_imbalance"], 4)},
    )
    for row in rep["supersteps"]:
        emit("trace", row)
    for msg in problems:
        print(f"trace: INVALID: {msg}", file=sys.stderr)


if __name__ == "__main__":
    main()
