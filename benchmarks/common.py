"""Benchmark utilities: timing, calibration, and table rendering.

Methodology mirrors the paper's §6: wall-clock timing (bsp_time analogue =
perf_counter around block_until_ready), averages over ≥4 runs after one
warmup, and the paper's calibration of the comparison rate (its T3D
quicksort did 1M keys in ~3 s ⇒ 7 cmp/µs; we measure the same constant for
this CPU + XLA's sort).

The Cray T3D is simulated: p processors = a vmapped axis on one CPU core,
so measured "parallel" time is total-work time. We therefore report
    work_eff = T_seq(jnp.sort of n keys) / T_sim
(the simulated-processor analogue of the paper's efficiency — both count
total comparisons), alongside the BSP-model PREDICTED efficiency under the
paper's own T3D constants, which reproduces the paper's §6 numbers.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BSPMachine, CRAY_T3D, SortConfig, predict

#: paper §6 averages ≥4 experiments; default 2 keeps the harness's default
#: single-core run short — raise via benchmarks.run --full for paper fidelity.
REPEATS = 2


def timeit(fn: Callable, *args, repeats: int = REPEATS) -> float:
    out = fn(*args)
    jax.block_until_ready(out)  # warmup / compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.mean(ts))


_seq_cache: Dict[int, float] = {}


def seq_sort_time(n: int, seed: int = 0) -> float:
    """Best sequential comparison sort on this substrate (jit jnp.sort)."""
    if n not in _seq_cache:
        x = jnp.asarray(
            np.random.default_rng(seed).integers(0, 2**31, n, dtype=np.int64).astype(np.int32)
        )
        f = jax.jit(jnp.sort)
        _seq_cache[n] = timeit(f, x)
    return _seq_cache[n]


def t_comp_per_cmp() -> float:
    """Calibrated seconds/comparison (paper: 1/7e6 on the T3D)."""
    n = 1 << 20
    return seq_sort_time(n) / (n * np.log2(n))


def t3d_machine(p: int) -> BSPMachine:
    L, g = CRAY_T3D[min(CRAY_T3D, key=lambda q: abs(q - p))]
    return BSPMachine(p=p, L=L, g=g)


def predicted_t3d(cfg: SortConfig):
    return predict(cfg, t3d_machine(cfg.p))


def fmt_row(cells: List, widths=None) -> str:
    return ",".join(str(c) for c in cells)


#: every emitted row of the current process, in emit order — the JSON
#: trajectory writer (benchmarks.run --json OUT) drains this.
ROWS: List[Tuple[str, Dict]] = []


def emit(table: str, row: Dict):
    """CSV line: table,key=value,... (greppable, machine-readable)."""
    ROWS.append((table, dict(row)))
    print(f"{table}," + ",".join(f"{k}={v}" for k, v in row.items()), flush=True)


def write_json(out_dir: str) -> List[str]:
    """Write every collected table as ``OUT/BENCH_<table>.json``.

    One file per table, rows in emit order with keys sorted — inputs are
    seeded, so reruns differ only in the timing fields, which is what makes
    the files a diffable perf trajectory. Returns the written paths.
    """
    os.makedirs(out_dir, exist_ok=True)
    by_table: Dict[str, List[Dict]] = {}
    for table, row in ROWS:
        by_table.setdefault(table, []).append(row)
    paths = []
    for table, rows in sorted(by_table.items()):
        path = os.path.join(out_dir, f"BENCH_{table}.json")
        with open(path, "w") as f:
            json.dump(
                {"table": table, "rows": rows},
                f,
                indent=1,
                sort_keys=True,
                default=str,
            )
            f.write("\n")
        paths.append(path)
    return paths
