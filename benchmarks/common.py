"""Benchmark utilities: timing, calibration, and table rendering.

Methodology mirrors the paper's §6: wall-clock timing (bsp_time analogue =
perf_counter around block_until_ready), ≥4 runs after one warmup, and the
paper's calibration of the comparison rate (its T3D quicksort did 1M keys
in ~3 s ⇒ 7 cmp/µs; we measure the same constant for this CPU + XLA's
sort). One deliberate departure: the paper *averages* its runs on a
dedicated T3D; we report the *minimum*, the stable estimator on a shared
machine where CPU steal is additive one-sided noise (same rationale as
python -m timeit) — the committed baselines gate on these walls, and a
mean lets one stalled repeat fail the diff.

The Cray T3D is simulated: p processors = a vmapped axis on one CPU core,
so measured "parallel" time is total-work time. We therefore report
    work_eff = T_seq(jnp.sort of n keys) / T_sim
(the simulated-processor analogue of the paper's efficiency — both count
total comparisons), alongside the BSP-model PREDICTED efficiency under the
paper's own T3D constants, which reproduces the paper's §6 numbers.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BSPMachine, CRAY_T3D, SortConfig, predict

#: paper §6 runs ≥4 experiments; default 2 keeps the harness's default
#: single-core run short — raise via benchmarks.run --full for paper fidelity.
REPEATS = 2


def timeit(fn: Callable, *args, repeats: int = REPEATS) -> float:
    out = fn(*args)
    jax.block_until_ready(out)  # warmup / compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts))


_seq_cache: Dict[int, float] = {}


def seq_sort_time(n: int, seed: int = 0) -> float:
    """Best sequential comparison sort on this substrate (jit jnp.sort)."""
    if n not in _seq_cache:
        x = jnp.asarray(
            np.random.default_rng(seed).integers(0, 2**31, n, dtype=np.int64).astype(np.int32)
        )
        f = jax.jit(jnp.sort)
        _seq_cache[n] = timeit(f, x)
    return _seq_cache[n]


def t_comp_per_cmp() -> float:
    """Calibrated seconds/comparison (paper: 1/7e6 on the T3D)."""
    n = 1 << 20
    return seq_sort_time(n) / (n * np.log2(n))


def t3d_machine(p: int) -> BSPMachine:
    L, g = CRAY_T3D[min(CRAY_T3D, key=lambda q: abs(q - p))]
    return BSPMachine(p=p, L=L, g=g)


def predicted_t3d(cfg: SortConfig):
    return predict(cfg, t3d_machine(cfg.p))


def fmt_row(cells: List, widths=None) -> str:
    return ",".join(str(c) for c in cells)


def sharded_collective_counts(
    combos: Dict[str, Dict], p: int = 8, n_p: int = 128
) -> Dict[str, Dict[str, int]]:
    """Collective-op counts in the shard_map lowering of the full sort.

    Collectives only appear as HLO ops under ``shard_map`` (the vmap runner
    batches them into transposes), and forcing host devices must happen
    before jax initializes — so the lowering runs in a subprocess with
    ``p`` forced host devices (the tests/test_distributed.py idiom).
    Lowering only: nothing is compiled or executed.

    ``combos`` maps row name -> SortConfig override kwargs plus ``nv`` (the
    payload count). Returns ``{name: {"all_to_all": n, "all_gather": n}}``.
    The single source of truth for both the ``hotpath`` table's identity
    column and the tests/test_hotpath_fusion.py HLO regression — caveat for
    both: ``all_gather`` matches a fixed number of times per op in the
    StableHLO text (more than once), so compare *deltas*, not absolutes.
    """
    import json
    import subprocess
    import sys
    import textwrap

    src = textwrap.dedent(
        f"""
        import json, re
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core import SortConfig
        from repro.core.api import SortExecutor
        combos = json.loads({json.dumps(json.dumps(combos))})
        p, n_p = {p}, {n_p}
        mesh = Mesh(np.array(jax.devices()), ("procs",))
        out = {{}}
        for name, kw in combos.items():
            nv = kw.pop("nv", 0)
            fn = SortExecutor().sort_sharded(
                SortConfig(p=p, n_per_proc=n_p, **kw), mesh, "procs", nv
            )
            args = [jax.random.key_data(jax.random.key(0)),
                    jnp.zeros((p, n_p), jnp.int32)]
            args += [jnp.zeros((p, n_p), jnp.int32)] * nv
            txt = jax.jit(fn).lower(*args).as_text()
            out[name] = {{"all_to_all": len(re.findall("all_to_all", txt)),
                          "all_gather": len(re.findall("all_gather", txt))}}
        print(json.dumps(out))
        """
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    r = subprocess.run(
        [sys.executable, "-c", src], capture_output=True, text=True, env=env,
        timeout=560,
    )
    if r.returncode != 0:
        raise RuntimeError(
            f"collective-count subprocess failed:\n{r.stderr[-3000:]}"
        )
    return json.loads(r.stdout.strip().splitlines()[-1])


#: every emitted row of the current process, in emit order — the JSON
#: trajectory writer (benchmarks.run --json OUT) drains this.
ROWS: List[Tuple[str, Dict]] = []


def emit(table: str, row: Dict):
    """CSV line: table,key=value,... (greppable, machine-readable)."""
    ROWS.append((table, dict(row)))
    print(f"{table}," + ",".join(f"{k}={v}" for k, v in row.items()), flush=True)


def write_json(out_dir: str) -> List[str]:
    """Write every collected table as ``OUT/BENCH_<table>.json``.

    One file per table, rows in emit order with keys sorted — inputs are
    seeded, so reruns differ only in the timing fields, which is what makes
    the files a diffable perf trajectory. Returns the written paths.
    """
    os.makedirs(out_dir, exist_ok=True)
    by_table: Dict[str, List[Dict]] = {}
    for table, row in ROWS:
        by_table.setdefault(table, []).append(row)
    paths = []
    for table, rows in sorted(by_table.items()):
        path = os.path.join(out_dir, f"BENCH_{table}.json")
        with open(path, "w") as f:
            json.dump(
                {"table": table, "rows": rows},
                f,
                indent=1,
                sort_keys=True,
                default=str,
            )
            f.write("\n")
        paths.append(path)
    return paths
