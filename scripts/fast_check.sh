#!/usr/bin/env bash
# Quick verification loop (~4 min): the fast-marked tier-1 subset, a
# one-batch capacity-planner smoke (fingerprint → segment-aware bound →
# planned-tier fused sort → persisted history round-trip, plus a
# balanced dense-int batch that must take the radix route with zero
# retries), and the perf gates — the `hotpath`, `soak` and `radix`
# benchmark tables regenerated from seeded inputs and diffed against
# the committed baselines (benchmarks/baselines/): HLO collective
# counts, pipeline saturation (in_flight_peak/overlapped), the radix
# table's zero-retry guarantee and other identity fields must match
# exactly, walls within a generous shared-core tolerance and the soak
# p99 under bench_diff's looser percentile gate. The `obs` table rides
# the same regen (traced h volume / imbalance / fitted (g, L)), as does
# the `delta` table (fold vs full-resort speedup — higher-better — plus
# the fold/resort route counts and the Δ split size as identities), and
# the `chaos` table (seeded FaultPlan soak: innocents_failed == 0,
# byte-identical recovery and recovered_batches as identities). An
# obs smoke runs one traced sort end-to-end: byte-identical output,
# valid Chrome trace, clean span schema, working cost report; a chaos
# smoke runs a poisoned+faulted batch mix and asserts every innocent's
# bytes match the un-faulted run, the poison future names its rid, and
# a cancelled request never launches. Set
# SKIP_BENCH=1 to skip the perf gates (e.g. on a loaded machine).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -m fast -q

if [[ "${SKIP_BENCH:-0}" != "1" ]]; then
  tmp="$(mktemp -d)"
  trap 'rm -rf "$tmp"' EXIT
  python -m benchmarks.run --tables hotpath,soak,radix,obs,delta,chaos --json "$tmp" > /dev/null
  python scripts/bench_diff.py \
    benchmarks/baselines/BENCH_hotpath.json "$tmp/BENCH_hotpath.json" \
    --tol 0.6
  python scripts/bench_diff.py \
    benchmarks/baselines/BENCH_soak.json "$tmp/BENCH_soak.json" \
    --tol 0.6
  python scripts/bench_diff.py \
    benchmarks/baselines/BENCH_radix.json "$tmp/BENCH_radix.json" \
    --tol 0.6 --allow-missing-baseline
  python scripts/bench_diff.py \
    benchmarks/baselines/BENCH_obs.json "$tmp/BENCH_obs.json" \
    --tol 0.6 --allow-missing-baseline
  python scripts/bench_diff.py \
    benchmarks/baselines/BENCH_delta.json "$tmp/BENCH_delta.json" \
    --tol 0.6 --allow-missing-baseline
  python scripts/bench_diff.py \
    benchmarks/baselines/BENCH_chaos.json "$tmp/BENCH_chaos.json" \
    --tol 0.6 --allow-missing-baseline
fi

python - <<'EOF'
import os, tempfile
import numpy as np
from repro.core import datagen
from repro.planner import CapacityPlanner, bucket_key, fingerprint_arrays
from repro.service import ServiceConfig, SortService
from repro.core.api import SortExecutor

with tempfile.TemporaryDirectory() as d:
    path = os.path.join(d, "planner.json")
    arrays = [datagen.generate("U", 1, int(s), seed=i)[0]
              for i, s in enumerate(datagen.zipf_sizes(16, 4096, seed=0))]
    svc = SortService(ServiceConfig(p=8, planner_path=path),
                      executor=SortExecutor())
    results = svc.sort_many(arrays)
    assert all(np.array_equal(r.keys, np.sort(a))
               for a, r in zip(arrays, results)), "fused sort mismatch"
    assert results[0].tier == "planned", results[0].tier
    assert svc.stats.retries == 0, svc.stats.as_row()

    fp = fingerprint_arrays(arrays, 8)
    reloaded = CapacityPlanner(path=path)  # history round-trip
    assert bucket_key(fp) in reloaded.history, reloaded.history

    # balanced dense-int batch: the planner must pick the radix route —
    # one exact-capacity rung, zero retries by construction
    dense = [datagen.dense_int(1, 256, seed=40 + i, domain=32)[0]
             for i in range(16)]
    svc2 = SortService(ServiceConfig(p=8, planner_path=path),
                       executor=SortExecutor())
    r2 = svc2.sort_many(dense)
    assert all(np.array_equal(r.keys, np.sort(a))
               for a, r in zip(dense, r2)), "radix fused sort mismatch"
    assert r2[0].tier == "radix", r2[0].tier
    assert svc2.stats.retries == 0, svc2.stats.as_row()
    print("planner smoke: planned-tier fused sort + radix route + "
          "history round-trip OK")
EOF

python - <<'EOF'
# obs smoke: one traced overflow-safe sort — output byte-identical to the
# untraced run, Chrome trace + span schema validate clean, cost report has
# per-superstep h volume and a sane imbalance.
import json, os, tempfile
import numpy as np
import jax.numpy as jnp
from repro import obs
from repro.core import (SortConfig, bsp_sort_safe, datagen, gathered_output,
                        theoretical_max_imbalance)

p, n_p = 8, 4096
x = jnp.asarray(datagen.generate("U", p, n_p, seed=21))
base = dict(p=p, n_per_proc=n_p, routing="a2a_dense", pair_capacity="whp")
res0, _, _ = bsp_sort_safe(x, SortConfig(**base))

tracer = obs.Tracer()
res1, _, _ = bsp_sort_safe(x, SortConfig(obs=tracer, **base))
assert np.array_equal(gathered_output(res0), gathered_output(res1)), \
    "traced run changed the output"

assert obs.validate_spans(tracer) == [], obs.validate_spans(tracer)
with tempfile.TemporaryDirectory() as d:
    path = tracer.save(os.path.join(d, "trace.json"))
    with open(path) as f:
        problems = obs.validate_chrome_trace(json.load(f))
    assert problems == [], problems

rep = tracer.cost_report()
rows = rep["supersteps"]
assert rows and all(r["h_words"] >= n_p for r in rows), rows
bound = 1.0 + theoretical_max_imbalance(SortConfig(**base))
assert rep["max_imbalance"] <= bound, (rep["max_imbalance"], bound)
print(f"obs smoke: traced sort byte-identical, valid Chrome trace "
      f"({len(rows)} route span(s)), imbalance "
      f"{rep['max_imbalance']:.3f} <= {bound:.3f} OK")
EOF

python - <<'EOF'
# chaos smoke: a seeded FaultPlan (capacity faults + a poison rid +
# transient launch faults) over a Zipf request mix — every innocent
# request's bytes must match the un-faulted run exactly, the poison
# future must fail with a SortServiceError naming its rid, and a
# cancelled request must never launch.
import numpy as np
from repro.chaos import FaultPlan
from repro.core import datagen
from repro.core.api import SortExecutor
from repro.service import (ServiceConfig, SortCancelledError, SortService,
                           SortServiceError)

arrays = [datagen.generate("zipf", 1, int(s), seed=100 + i)[0]
          for i, s in enumerate(datagen.zipf_sizes(16, 8192, seed=7))]
ex = SortExecutor()
cfg = dict(p=8, max_batch_keys=1 << 13)

ref_svc = SortService(ServiceConfig(**cfg), executor=ex)
ref = {f.rid: f for f in [ref_svc.submit(a) for a in arrays]}
ref_svc.flush()

plan = FaultPlan(seed=7, poison_rids=(3,), capacity_fault_rate=0.5,
                 capacity_fault_rungs=(0,), transient_error_rate=0.5)
svc = SortService(ServiceConfig(**cfg, chaos=plan), executor=ex)
futs = [svc.submit(a) for a in arrays]
svc.flush()
for f in futs:
    if f.rid == 3:
        exc = f.exception()
        assert isinstance(exc, SortServiceError) and "rid=3" in str(exc), exc
        continue
    assert f.exception() is None, (f.rid, f.exception())
    r, r0 = f.result(), ref[f.rid].result()
    assert np.array_equal(r.keys, r0.keys), f"rid {f.rid} keys diverged"
    assert np.array_equal(r.order, r0.order), f"rid {f.rid} order diverged"
assert plan.injected_total > 0, "chaos plan injected nothing"

# cancellation: an unformed request unpicks cleanly and never launches
svc2 = SortService(ServiceConfig(**cfg), executor=ex)
fut = svc2.submit(arrays[0])
assert fut.cancel() and fut.cancelled()
assert svc2.dispatcher.launches == 0, "cancelled request launched"
try:
    fut.result()
    raise AssertionError("cancelled future resolved with a result")
except SortCancelledError:
    pass
print(f"chaos smoke: {plan.injected_total} injected fault(s) "
      f"({plan.injected}), innocents byte-identical, poison names rid, "
      f"cancel never launches OK")
EOF
