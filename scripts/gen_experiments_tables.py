"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from sweep JSON."""
import json
import sys

V5E = "197 TF/s bf16 · 819 GB/s HBM · 50 GB/s ICI"


def load(path):
    try:
        return json.load(open(path))
    except FileNotFoundError:
        return {}


def main():
    single = load("dryrun_single_pod.json")
    multi = load("dryrun_multi_pod.json")

    out = []
    out.append("### Dry-run matrix (status per cell)\n")
    out.append("| arch | shape | 16x16 | 2x16x16 | bytes/dev (16x16) | compile s |")
    out.append("|---|---|---|---|---|---|")
    for key in single:
        arch, shape, _ = key.split("|")
        s = single[key]
        mkey = f"{arch}|{shape}|2x16x16"
        m = multi.get(mkey, {})
        stat = s["status"]
        mstat = m.get("status", "—")
        mem = s.get("mem_total_gb", "—")
        comp = s.get("compile_s", "—")
        if stat == "skipped":
            out.append(f"| {arch} | {shape} | skip | skip | — | — |")
        else:
            out.append(f"| {arch} | {shape} | {stat} | {mstat} | {mem} GB | {comp} |")
    out.append("")

    out.append(f"### Roofline terms — single-pod 16x16 (256 chips, {V5E})\n")
    out.append(
        "| arch | shape | t_compute (HLO) | t_compute (6N·D) | t_memory | "
        "t_collective | dominant | useful-FLOPs | roofline frac |"
    )
    out.append("|---|---|---|---|---|---|---|---|---|")
    for key, i in single.items():
        if i["status"] != "ok":
            continue
        arch, shape, _ = key.split("|")
        out.append(
            f"| {arch} | {shape} | {i['t_compute_s']:.4f} | "
            f"{i['t_compute_model_s']:.4f} | {i['t_memory_s']:.4f} | "
            f"{i['t_collective_s']:.4f} | {i['dominant']} | "
            f"{i.get('useful_flops_ratio', 0):.3f} | "
            f"{100 * i.get('roofline_fraction', 0):.2f}% |"
        )
    out.append("")

    out.append("### Multi-pod deltas (2x16x16, 512 chips) — collective MB/device\n")
    out.append("| arch | shape | coll MB (1 pod) | coll MB (2 pods) | pod-axis cost |")
    out.append("|---|---|---|---|---|")
    for key, i in single.items():
        if i["status"] != "ok":
            continue
        arch, shape, _ = key.split("|")
        m = multi.get(f"{arch}|{shape}|2x16x16", {})
        if m.get("status") != "ok":
            continue
        c1 = i.get("collective_mb_per_dev", 0)
        c2 = m.get("collective_mb_per_dev", 0)
        delta = "—" if not c1 else f"{(c2 - c1) / max(c1, 1e-9) * 100:+.1f}%"
        out.append(f"| {arch} | {shape} | {c1} | {c2} | {delta} |")
    print("\n".join(out))


if __name__ == "__main__":
    main()
