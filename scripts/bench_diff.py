"""Compare a fresh ``BENCH_<table>.json`` against a committed baseline.

    PYTHONPATH=src python scripts/bench_diff.py BASELINE.json FRESH.json \
        [--tol 0.3] [--list]

The ``--json OUT`` trajectory files (benchmarks/common.write_json) hold
seeded-input rows in deterministic emit order, so two runs of the same
commit differ only in their metric fields. This tool makes that trajectory
*enforceable*: rows are matched positionally, identity fields (shapes,
variants, tier names, counters' non-metric context) must match exactly,
and metric fields are compared under a relative tolerance —

* lower-is-better: wall/latency seconds (``wall*``, ``*_s``, ``lat_*``),
  retry counters (``retries*``, ``retry_cost``), received-key
  ``imbalance`` (the obs table's max/mean load skew);
* higher-is-better: ``speedup``, ``*keys_per_s``, ``work_eff*``, and the
  obs table's fit quality ``r2``;
* identity-by-name: the delta table's fold/resort route counts
  (``folds``/``resorts``/``tombstones``) and Δ split size (``delta_n``)
  are deterministic on seeded input, so they must match *exactly* — a
  changed fold count is a routing regression, not timing noise;
* latency *percentiles* (``*_p99*``, ``*_p95*``, ``*_p90*``, ``*_p50*``)
  are lower-is-better but gated under ``--tol-pctile`` (default 2× the
  base tolerance): a tail quantile over an open-loop arrival process is
  far noisier than a mean, and gating it at mean-tightness would make the
  soak table's p99 headline flake on every loaded CI core.

A metric worse than baseline by more than ``--tol`` (default 30% — CI
timing noise on a shared core is real) is a **regression**: nonzero exit,
one line per offender. Improvements are reported, never fatal. Structural
drift (row count, identity mismatch, new/missing tables) exits 2 so a
reshaped benchmark fails loudly instead of silently passing.

Exit codes: 0 clean · 1 regression · 2 structural mismatch / bad input.

``--allow-missing-baseline`` is the bootstrap escape: a brand-new table has
no committed baseline yet, and without the flag that reads as structural
failure (exit 2) — the right behaviour once a baseline exists, but a
chicken-and-egg block when wiring a new table into CI in the same change
that first produces it. With the flag, a *missing baseline file* prints a
note and exits 0 (the fresh file still has to parse); every other
structural problem still exits 2.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

#: exact names pinned as identity fields regardless of the fragment lists
#: below: the delta table's fold/resort route counts and its Δ split size,
#: and the chaos table's recovery outcomes, are deterministic on seeded
#: input — any drift is a routing/recovery change to fail structurally
#: (exit 2), never a tolerated "metric" move. ``innocents_failed`` would
#: match no direction fragment anyway, but pinning it here makes the
#: contract explicit: a faulted run failing an innocent request is a
#: correctness regression at any magnitude.
_IDENTITY = (
    "folds",
    "resorts",
    "tombstones",
    "delta_n",
    "innocents_failed",
    "recovered_batches",
)
#: metric-name fragments, direction: +1 = higher is better, -1 = lower
_HIGHER = ("speedup", "keys_per_s", "work_eff", "r2")
_LOWER = ("wall", "lat_", "retry", "retries", "imbalance")
#: latency-percentile fragments: lower is better, looser tolerance
_PCTILE = ("_p99", "_p95", "_p90", "_p50")


def is_percentile(name: str) -> bool:
    """Latency-percentile metrics get the looser ``--tol-pctile`` gate."""
    return any(frag in name for frag in _PCTILE)


def metric_direction(name: str):
    """+1 / -1 for metric fields, None for identity fields.

    The seconds suffix is matched with ``endswith`` only — a substring test
    would swallow identity fields that merely contain ``_s`` (e.g. the
    planner table's ``lane_spread_max``) and let structural drift pass as a
    metric "improvement". ``_IDENTITY`` names are checked first so route
    counters stay exact-match even if a direction fragment ever collides.
    """
    if name in _IDENTITY:
        return None
    for frag in _HIGHER:
        if frag in name:
            return 1
    for frag in _LOWER:
        if frag in name:
            return -1
    if name.endswith("_s"):
        return -1
    return None


def load_rows(path: str) -> Tuple[str, List[Dict]]:
    with open(path) as f:
        data = json.load(f)
    if "table" not in data or "rows" not in data:
        raise ValueError(f"{path}: not a BENCH_<table>.json file")
    return data["table"], data["rows"]


def diff_rows(
    base: Dict,
    fresh: Dict,
    tol: float,
    where: str,
    tol_pctile: Optional[float] = None,
) -> Tuple[List[str], List[str]]:
    """(regressions, notes) comparing one matched row pair."""
    if tol_pctile is None:
        tol_pctile = 2 * tol
    regressions, notes = [], []
    for key in sorted(set(base) | set(fresh)):
        if key not in base or key not in fresh:
            regressions.append(f"{where}: field {key!r} only in one side")
            continue
        b, f = base[key], fresh[key]
        d = metric_direction(key)
        numeric = isinstance(b, (int, float)) and isinstance(f, (int, float))
        if d is None or not numeric:
            if b != f:
                regressions.append(
                    f"{where}: identity field {key}={f!r} (baseline {b!r})"
                )
            continue
        if b == f:
            continue
        key_tol = tol_pctile if is_percentile(key) else tol
        # relative change, signed so positive = better
        ref = max(abs(float(b)), 1e-12)
        change = d * (float(f) - float(b)) / ref
        if change < -key_tol:
            regressions.append(
                f"{where}: {key} {b} -> {f} ({change * 100:+.1f}% vs tol "
                f"{key_tol * 100:.0f}%)"
            )
        elif change > key_tol:
            notes.append(f"{where}: {key} {b} -> {f} ({change * 100:+.1f}%)")
    return regressions, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed BENCH_<table>.json")
    ap.add_argument("fresh", help="freshly produced BENCH_<table>.json")
    ap.add_argument(
        "--tol", type=float, default=0.3,
        help="relative regression tolerance on metric fields (default 0.3)",
    )
    ap.add_argument(
        "--tol-pctile", type=float, default=None,
        help="tolerance for latency-percentile metrics (*_p99/_p95/_p90/"
        "_p50); default 2x --tol — tail quantiles are noisier than means",
    )
    ap.add_argument(
        "--list", action="store_true",
        help="also print rows that stayed within tolerance",
    )
    ap.add_argument(
        "--allow-missing-baseline", action="store_true",
        help="exit 0 (with a note) when the baseline file does not exist — "
        "for wiring a brand-new table into CI before its first committed "
        "baseline",
    )
    args = ap.parse_args(argv)

    if args.allow_missing_baseline and not os.path.exists(args.baseline):
        try:
            ftab, frows = load_rows(args.fresh)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"bench_diff: {e}", file=sys.stderr)
            return 2
        print(
            f"bench_diff: no baseline at {args.baseline} — skipping "
            f"({ftab}: {len(frows)} fresh rows; commit the fresh file to "
            f"start gating)"
        )
        return 0

    try:
        btab, brows = load_rows(args.baseline)
        ftab, frows = load_rows(args.fresh)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"bench_diff: {e}", file=sys.stderr)
        return 2
    if btab != ftab:
        print(f"bench_diff: table mismatch {btab!r} vs {ftab!r}", file=sys.stderr)
        return 2
    if len(brows) != len(frows):
        print(
            f"bench_diff: {btab}: row count {len(frows)} vs baseline "
            f"{len(brows)}",
            file=sys.stderr,
        )
        return 2

    regressions: List[str] = []
    notes: List[str] = []
    for i, (b, f) in enumerate(zip(brows, frows)):
        r, n = diff_rows(b, f, args.tol, f"{btab}[{i}]", args.tol_pctile)
        regressions += r
        notes += n
        if args.list and not r:
            print(f"ok   {btab}[{i}]")
    for line in notes:
        print(f"note {line}")
    for line in regressions:
        print(f"REGR {line}")
    identity_regr = any("identity field" in r or "only in one" in r for r in regressions)
    if regressions:
        print(f"bench_diff: {len(regressions)} regression(s) in {btab}")
        return 2 if identity_regr else 1
    print(f"bench_diff: {btab}: {len(brows)} rows within {args.tol * 100:.0f}%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
