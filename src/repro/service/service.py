"""SortService — request queue + fused dispatch over the segmented BSP sort.

Consumers (serve admission ordering, data-pipeline length bucketing, MoE-ish
"sort these ids by key" callers) each used to run one whole BSP sort per
array: a small request wastes the p-lane mesh, and every distinct length
risks a recompile. The service turns that regime into a first-class
workload:

* ``submit(keys)`` queues a ragged int32 request and returns a request id;
* ``flush()`` packs the queue into pow2-bucketed batches
  (:class:`repro.service.batch.BatchFormer`), runs ONE overflow-safe
  segmented sort per batch (`repro.core.segmented` — the (segment, key)
  tagged fusion of every request in the batch), and returns every
  *unclaimed* result. Completed results stay in the service's store until
  claimed (``take_result`` / ``sort_one`` / ``sort_many``), so a request
  piggybacked onto another caller's flush is never lost. Flushes also fire
  automatically from ``submit`` when configured: ``max_pending`` queued
  requests (size trigger) or an oldest-request age past ``flush_after_s``
  (deadline trigger — also checkable via :meth:`maybe_flush` from an event
  loop), so trickle traffic gets bounded tail latency; telemetry records
  which trigger fired;
* escalation is per batch through ``bsp_sort_safe``'s capacity-tier
  ladder, so one adversarial request escalates only its own batch. The
  starting tier is resolved per batch (``pair_capacity="auto"``) by the
  **capacity planner** (:class:`repro.planner.CapacityPlanner`): the batch
  is fingerprinted (sizes, lane segment spread, sampled duplicate
  fractions), multi-segment batches are packed *striped* so each lane
  holds ~1/p of every segment, and the planner's segment-aware whp bound
  picks a sub-exact ``planned`` pair capacity — replacing PR 3's rule that
  pinned every fused batch to ``exact``. Observed fault outcomes feed back
  into the planner's per-bucket rung history (JSON-persisted via
  ``planner_path``), so tiers adapt to live traffic. An explicit
  ``pair_capacity="whp"``/``"exact"`` still pins every batch;
* telemetry: per-request wall latency (submit → result), the accumulated
  :class:`TierStats` of every escalation, per-bucket batch counts,
  auto-flush trigger counts, planner plan/promotion counters, and the
  shared :class:`SortExecutor`'s trace counts for compile-reuse assertions.

One process-wide default executor serves all services, so every service
instance (and every other sort caller) shares compiled programs per bucket.
"""
from __future__ import annotations

import collections
import dataclasses
import time
import warnings
from typing import Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.core import TierStats
from repro.core.api import SortExecutor, default_executor
from repro.core.segmented import pack_segments, segmented_sort_safe
from repro.planner import CapacityPlanner
from repro.service.batch import BatchFormer


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Static service knobs; the sort fields mirror SortConfig's."""

    p: int = 8  # simulated-processor lanes per fused sort
    algorithm: str = "iran"  # randomized oversampling: production default
    # First capacity tier, resolved per batch when "auto": the capacity
    # planner fingerprints the batch and picks (layout, starting tier,
    # oversampling ratio) — single-segment batches keep the raw-int32
    # contiguous hot path, multi-segment batches pack striped and start at
    # the segment-aware planned bound (repro.planner). An explicit
    # "whp"/"exact" pins the starting tier for every batch.
    pair_capacity: str = "auto"
    local_sort: str = "lax"
    # Ph6 tail of the fused sort: "sort" (stable re-sort) or "tree" (the
    # payload-generic rank-merge tail — the int64 composites and their pos
    # payload ride the lg p rank merges instead of a full re-sort).
    merge: str = "sort"
    max_batch_keys: int = 1 << 16  # batch former's packing cap
    min_n_per_proc: int = 8
    seed: int = 0
    # planner history persistence (pair_capacity="auto" only); None keeps
    # the learned rungs in-process
    planner_path: Optional[str] = None
    # auto-flush triggers (both optional): flush from submit() once this
    # many requests are pending / once the oldest pending request is older
    # than this deadline. Caller-driven flush() stays supported.
    max_pending: Optional[int] = None
    flush_after_s: Optional[float] = None


@dataclasses.dataclass
class RequestResult:
    """One request's output: sorted keys + stable argsort + telemetry."""

    rid: int
    keys: np.ndarray  # sorted ascending
    order: np.ndarray  # stable argsort: input[order] == keys
    tier: Optional[str]  # capacity tier that served this request's batch
    n_per_proc: int  # pow2 bucket the batch compiled under
    latency_s: float  # submit -> result wall time


@dataclasses.dataclass
class _Pending:
    rid: int
    keys: np.ndarray
    submitted_at: float


class SortService:
    def __init__(
        self,
        cfg: ServiceConfig = ServiceConfig(),
        *,
        executor: Optional[SortExecutor] = None,
        stats: Optional[TierStats] = None,
        planner: Optional[CapacityPlanner] = None,
    ) -> None:
        # reject unsupported pins up front: "planned" needs a per-batch
        # bound only the planner can supply — a pinned service would raise
        # inside flush and the crash-safe re-queue would then re-raise on
        # every later flush (the request could never complete)
        if cfg.pair_capacity not in ("auto", "whp", "exact"):
            raise ValueError(
                f"unsupported service pair_capacity {cfg.pair_capacity!r}: "
                "use 'auto' (planner-resolved) or pin 'whp'/'exact'"
            )
        self.cfg = cfg
        self.executor = executor if executor is not None else default_executor()
        self.stats = stats if stats is not None else TierStats()
        # the capacity planner resolves "auto" starting tiers; a shared
        # instance lets several services pool their traffic history
        self.planner = (
            planner
            if planner is not None
            else CapacityPlanner(path=cfg.planner_path)
        )
        self.former = BatchFormer(
            cfg.p, cfg.max_batch_keys, cfg.min_n_per_proc
        )
        self._pending: List[_Pending] = []
        self._completed: Dict[int, RequestResult] = {}  # unclaimed results
        self._next_rid = 0
        # telemetry — latencies keep a bounded window (a long-lived serving
        # process must not grow one float per request forever); the
        # lifetime request count is its own counter
        self.latencies: Deque[float] = collections.deque(maxlen=1 << 16)
        self.requests_done = 0
        self.batches_dispatched = 0
        self.keys_sorted = 0
        self.bucket_counts: Dict[int, int] = {}  # n_per_proc -> batches
        self.flush_triggers: Dict[str, int] = {}  # manual/size/deadline
        self.start_tiers: Dict[str, int] = {}  # starting tier -> batches

    # ------------------------------------------------------------- queue
    def submit(self, keys: np.ndarray) -> int:
        """Queue one ragged request (1-D int32 keys); returns its id.

        May flush the queue before returning when an auto-flush trigger is
        configured and fires — the submitted request's result is then
        already claimable (``take_result``).
        """
        arr = np.asarray(keys, np.int32).reshape(-1)
        rid = self._next_rid
        self._next_rid += 1
        self._pending.append(_Pending(rid, arr, time.perf_counter()))
        if (
            self.cfg.max_pending is not None
            and len(self._pending) >= self.cfg.max_pending
        ):
            self.flush(trigger="size")
        else:
            self.maybe_flush()
        return rid

    def maybe_flush(self) -> bool:
        """Deadline check: flush if the oldest pending request is overdue.

        Called from ``submit`` and pollable from an event loop (the service
        has no thread of its own, so a deadline only fires when *somebody*
        calls in). Returns whether a flush ran.
        """
        if (
            self.cfg.flush_after_s is not None
            and self._pending
            and time.perf_counter() - self._pending[0].submitted_at
            >= self.cfg.flush_after_s
        ):
            self.flush(trigger="deadline")
            return True
        return False

    @property
    def pending(self) -> int:
        return len(self._pending)

    # ---------------------------------------------------------- dispatch
    def _resolve_batch(self, batch):
        """(packed, sort overrides, decision) for one formed batch."""
        if self.cfg.pair_capacity != "auto":  # explicit pin: PR 3 behaviour
            packed = pack_segments(
                batch.arrays,
                self.cfg.p,
                n_per_proc=batch.n_per_proc,
                min_n_per_proc=self.cfg.min_n_per_proc,
            )
            return packed, {"pair_capacity": self.cfg.pair_capacity}, None
        decision = self.planner.plan(
            batch.arrays,
            self.cfg.p,
            n_per_proc=batch.n_per_proc,
            min_n_per_proc=self.cfg.min_n_per_proc,
        )
        packed = pack_segments(
            batch.arrays,
            self.cfg.p,
            n_per_proc=batch.n_per_proc,
            min_n_per_proc=self.cfg.min_n_per_proc,
            layout=decision.layout,
        )
        overrides = {"pair_capacity": decision.pair_capacity}
        if decision.pair_capacity == "planned":
            overrides["pair_cap_override"] = decision.pair_cap_override
            overrides["omega"] = decision.omega
        return packed, overrides, decision

    def flush(self, trigger: str = "manual") -> Dict[int, RequestResult]:
        """Sort everything queued; one fused segmented sort per batch.

        Returns every unclaimed result — the newly completed ones plus any
        earlier completion not yet taken (a request fused into another
        caller's flush stays claimable). Claiming (``take_result`` /
        ``sort_one`` / ``sort_many``) removes a result from the store.
        """
        todo, self._pending = self._pending, []
        results = self._completed
        if todo:
            self.flush_triggers[trigger] = (
                self.flush_triggers.get(trigger, 0) + 1
            )
        submitted = {r.rid: r.submitted_at for r in todo}
        completed_rids = set()
        try:
            for batch in self.former.form([(r.rid, r.keys) for r in todo]):
                packed, overrides, decision = self._resolve_batch(batch)
                batch_stats = TierStats()  # isolates this batch's outcome
                seg = segmented_sort_safe(
                    packed,
                    algorithm=self.cfg.algorithm,
                    local_sort=self.cfg.local_sort,
                    merge=self.cfg.merge,
                    seed=self.cfg.seed,
                    stats=batch_stats,
                    executor=self.executor,
                    **overrides,
                )
                self.stats.merge_from(batch_stats)
                if decision is not None:
                    # planner feedback: did the starting tier overflow?
                    self.planner.record(
                        decision, faulted=batch_stats.retries > 0
                    )
                self.start_tiers[overrides["pair_capacity"]] = (
                    self.start_tiers.get(overrides["pair_capacity"], 0) + 1
                )
                self.batches_dispatched += 1
                self.keys_sorted += batch.total_keys
                self.bucket_counts[batch.n_per_proc] = (
                    self.bucket_counts.get(batch.n_per_proc, 0) + 1
                )
                done = time.perf_counter()
                for rid, keys, order in zip(batch.rids, seg.keys, seg.order):
                    lat = done - submitted[rid]
                    self.latencies.append(lat)
                    self.requests_done += 1
                    results[rid] = RequestResult(
                        rid=rid,
                        keys=keys,
                        order=order,
                        tier=seg.tier,
                        n_per_proc=seg.n_per_proc,
                        latency_s=lat,
                    )
                completed_rids.update(batch.rids)
        finally:
            # an admitted request may never be dropped: if a batch raised
            # (XLA OOM, backend error), everything not yet completed goes
            # back to the queue head for the next flush
            if len(completed_rids) < len(todo):
                self._pending = [
                    r for r in todo if r.rid not in completed_rids
                ] + self._pending
            # one history write per flush (not per batch), raise or not.
            # Persistence is telemetry, not dispatch: an unwritable path
            # must neither fail completed sorts nor mask a batch exception.
            try:
                self.planner.save_if_dirty()
            except OSError as e:
                warnings.warn(f"planner history not persisted: {e}")
        return dict(results)

    def take_result(self, rid: int) -> RequestResult:
        """Claim (remove) one completed result; flushes it if still queued."""
        if rid not in self._completed and any(
            r.rid == rid for r in self._pending
        ):
            self.flush()
        return self._completed.pop(rid)

    # ------------------------------------------------------ conveniences
    def sort_many(self, arrays: Sequence[np.ndarray]) -> List[RequestResult]:
        """Submit a batch of requests and flush; results in input order."""
        rids = [self.submit(a) for a in arrays]
        self.flush()
        return [self._completed.pop(rid) for rid in rids]

    def sort_one(self, keys: np.ndarray) -> RequestResult:
        """Sort a single request through the service. It fuses with anything
        already queued — and the piggybacked requests' results stay in the
        store for their own callers (``flush``/``take_result``)."""
        rid = self.submit(keys)
        self.flush()
        return self._completed.pop(rid)

    def telemetry(self) -> Dict[str, object]:
        """Flat snapshot for logs/benchmark rows; latency stats cover the
        bounded recent window, ``requests`` the service lifetime."""
        lat = np.fromiter(self.latencies, np.float64)
        row: Dict[str, object] = {
            "requests": self.requests_done,
            "batches": self.batches_dispatched,
            "keys_sorted": self.keys_sorted,
            "buckets": dict(sorted(self.bucket_counts.items())),
            "flush_triggers": dict(sorted(self.flush_triggers.items())),
            "start_tiers": dict(sorted(self.start_tiers.items())),
        }
        if self.cfg.pair_capacity == "auto":
            row["planner"] = self.planner.telemetry()
        if lat.size:
            row["lat_mean_ms"] = round(float(lat.mean()) * 1e3, 3)
            row["lat_p99_ms"] = round(float(np.quantile(lat, 0.99)) * 1e3, 3)
        row.update(self.stats.as_row())
        return row
