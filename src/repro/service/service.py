"""SortService — request queue + fused dispatch over the segmented BSP sort.

Consumers (serve admission ordering, data-pipeline length bucketing, MoE-ish
"sort these ids by key" callers) each used to run one whole BSP sort per
array: a small request wastes the p-lane mesh, and every distinct length
risks a recompile. The service turns that regime into a first-class
workload:

* ``submit(keys)`` queues a ragged int32 request and returns a request id;
* ``flush()`` packs the queue into pow2-bucketed batches
  (:class:`repro.service.batch.BatchFormer`), runs ONE overflow-safe
  segmented sort per batch (`repro.core.segmented` — the (segment, key)
  tagged fusion of every request in the batch), and returns every
  *unclaimed* result. Completed results stay in the service's store until
  claimed (``take_result`` / ``sort_one`` / ``sort_many``), so a request
  piggybacked onto another caller's flush is never lost;
* escalation is per batch through ``bsp_sort_safe``'s capacity-tier
  ladder, so one adversarial request escalates only its own batch. The
  starting tier is picked per batch (``pair_capacity="auto"``): a
  single-segment batch runs the classic cheap regime whp → whp×2 → exact
  → allgather, while a multi-segment batch starts at exact → allgather —
  contiguous segment packing value-clusters every lane's run, which
  structurally violates the whp per-pair bound, so whp rungs would only
  waste full sort executions there;
* telemetry: per-request wall latency (submit → result), the accumulated
  :class:`TierStats` of every escalation, per-bucket batch counts, and the
  shared :class:`SortExecutor`'s trace counts for compile-reuse assertions.

One process-wide default executor serves all services, so every service
instance (and every other sort caller) shares compiled programs per bucket.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import TierStats
from repro.core.api import SortExecutor, default_executor
from repro.core.segmented import pack_segments, segmented_sort_safe
from repro.service.batch import BatchFormer


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Static service knobs; the sort fields mirror SortConfig's."""

    p: int = 8  # simulated-processor lanes per fused sort
    algorithm: str = "iran"  # randomized oversampling: production default
    # First capacity tier, resolved per batch when "auto":
    # * single-segment batch → "whp": the classic cheap production regime
    #   (each lane holds an even, distribution-representative share);
    # * multi-segment batch → "exact": contiguous segment packing
    #   value-clusters each lane's run (it spans only a couple of
    #   segments and routes almost whole to one or two destinations,
    #   where the whp bound assumes per-pair shares near n/p²), so the
    #   whp rungs would fault structurally and waste two full sort
    #   executions per batch before exact serves.
    # An explicit "whp"/"exact" pins the starting tier for every batch.
    pair_capacity: str = "auto"
    local_sort: str = "lax"
    max_batch_keys: int = 1 << 16  # batch former's packing cap
    min_n_per_proc: int = 8
    seed: int = 0


@dataclasses.dataclass
class RequestResult:
    """One request's output: sorted keys + stable argsort + telemetry."""

    rid: int
    keys: np.ndarray  # sorted ascending
    order: np.ndarray  # stable argsort: input[order] == keys
    tier: Optional[str]  # capacity tier that served this request's batch
    n_per_proc: int  # pow2 bucket the batch compiled under
    latency_s: float  # submit -> result wall time


@dataclasses.dataclass
class _Pending:
    rid: int
    keys: np.ndarray
    submitted_at: float


class SortService:
    def __init__(
        self,
        cfg: ServiceConfig = ServiceConfig(),
        *,
        executor: Optional[SortExecutor] = None,
        stats: Optional[TierStats] = None,
    ) -> None:
        self.cfg = cfg
        self.executor = executor if executor is not None else default_executor()
        self.stats = stats if stats is not None else TierStats()
        self.former = BatchFormer(
            cfg.p, cfg.max_batch_keys, cfg.min_n_per_proc
        )
        self._pending: List[_Pending] = []
        self._completed: Dict[int, RequestResult] = {}  # unclaimed results
        self._next_rid = 0
        # telemetry
        self.latencies: List[float] = []  # per-request, completion order
        self.batches_dispatched = 0
        self.keys_sorted = 0
        self.bucket_counts: Dict[int, int] = {}  # n_per_proc -> batches

    # ------------------------------------------------------------- queue
    def submit(self, keys: np.ndarray) -> int:
        """Queue one ragged request (1-D int32 keys); returns its id."""
        arr = np.asarray(keys, np.int32).reshape(-1)
        rid = self._next_rid
        self._next_rid += 1
        self._pending.append(_Pending(rid, arr, time.perf_counter()))
        return rid

    @property
    def pending(self) -> int:
        return len(self._pending)

    # ---------------------------------------------------------- dispatch
    def flush(self) -> Dict[int, RequestResult]:
        """Sort everything queued; one fused segmented sort per batch.

        Returns every unclaimed result — the newly completed ones plus any
        earlier completion not yet taken (a request fused into another
        caller's flush stays claimable). Claiming (``take_result`` /
        ``sort_one`` / ``sort_many``) removes a result from the store.
        """
        todo, self._pending = self._pending, []
        results = self._completed
        submitted = {r.rid: r.submitted_at for r in todo}
        completed_rids = set()
        try:
            for batch in self.former.form([(r.rid, r.keys) for r in todo]):
                packed = pack_segments(
                    batch.arrays,
                    self.cfg.p,
                    n_per_proc=batch.n_per_proc,
                    min_n_per_proc=self.cfg.min_n_per_proc,
                )
                pair_capacity = self.cfg.pair_capacity
                if pair_capacity == "auto":
                    pair_capacity = (
                        "whp" if len(batch.arrays) == 1 else "exact"
                    )
                seg = segmented_sort_safe(
                    packed,
                    algorithm=self.cfg.algorithm,
                    pair_capacity=pair_capacity,
                    local_sort=self.cfg.local_sort,
                    seed=self.cfg.seed,
                    stats=self.stats,  # accumulates across batches/calls
                    executor=self.executor,
                )
                self.batches_dispatched += 1
                self.keys_sorted += batch.total_keys
                self.bucket_counts[batch.n_per_proc] = (
                    self.bucket_counts.get(batch.n_per_proc, 0) + 1
                )
                done = time.perf_counter()
                for rid, keys, order in zip(batch.rids, seg.keys, seg.order):
                    lat = done - submitted[rid]
                    self.latencies.append(lat)
                    results[rid] = RequestResult(
                        rid=rid,
                        keys=keys,
                        order=order,
                        tier=seg.tier,
                        n_per_proc=seg.n_per_proc,
                        latency_s=lat,
                    )
                completed_rids.update(batch.rids)
        finally:
            # an admitted request may never be dropped: if a batch raised
            # (XLA OOM, backend error), everything not yet completed goes
            # back to the queue head for the next flush
            if len(completed_rids) < len(todo):
                self._pending = [
                    r for r in todo if r.rid not in completed_rids
                ] + self._pending
        return dict(results)

    def take_result(self, rid: int) -> RequestResult:
        """Claim (remove) one completed result; flushes it if still queued."""
        if rid not in self._completed and any(
            r.rid == rid for r in self._pending
        ):
            self.flush()
        return self._completed.pop(rid)

    # ------------------------------------------------------ conveniences
    def sort_many(self, arrays: Sequence[np.ndarray]) -> List[RequestResult]:
        """Submit a batch of requests and flush; results in input order."""
        rids = [self.submit(a) for a in arrays]
        self.flush()
        return [self._completed.pop(rid) for rid in rids]

    def sort_one(self, keys: np.ndarray) -> RequestResult:
        """Sort a single request through the service. It fuses with anything
        already queued — and the piggybacked requests' results stay in the
        store for their own callers (``flush``/``take_result``)."""
        rid = self.submit(keys)
        self.flush()
        return self._completed.pop(rid)

    def telemetry(self) -> Dict[str, object]:
        """Flat snapshot for logs/benchmark rows."""
        lat = np.asarray(self.latencies, np.float64)
        row: Dict[str, object] = {
            "requests": int(lat.size),
            "batches": self.batches_dispatched,
            "keys_sorted": self.keys_sorted,
            "buckets": dict(sorted(self.bucket_counts.items())),
        }
        if lat.size:
            row["lat_mean_ms"] = round(float(lat.mean()) * 1e3, 3)
            row["lat_p99_ms"] = round(float(np.quantile(lat, 0.99)) * 1e3, 3)
        row.update(self.stats.as_row())
        return row
