"""SortService — async request queue + fused dispatch over the segmented sort.

Consumers (serve admission ordering, data-pipeline length bucketing, MoE-ish
"sort these ids by key" callers) each used to run one whole BSP sort per
array: a small request wastes the p-lane mesh, and every distinct length
risks a recompile. The service turns that regime into a first-class
workload — and, since the async restructure, into a *pipelined* one:

* ``submit(keys)`` queues a ragged int32 request and returns a
  :class:`repro.service.dispatch.SortFuture` **immediately** — nothing is
  dispatched at submit time. ``future.result()`` is the only blocking
  point; it drives the dispatcher until the request's batch completes;
* batches are formed pow2-bucketed (:class:`repro.service.batch.BatchFormer`)
  and handed to the :class:`repro.service.dispatch.Dispatcher`, which keeps
  up to ``max_in_flight`` of them launched at once: the host-side
  fingerprint → plan → pack → launch of batch k+1 overlaps batch k's device
  collectives via JAX async dispatch. Per-request *failsink* fault
  isolation lives there too — a failed batch is bisected until the poison
  request stands alone, so one bad request cannot wedge the queue;
* escalation is per batch through ``bsp_sort_safe``'s capacity-tier
  ladder. The starting tier is resolved per batch (``pair_capacity="auto"``)
  by the **capacity planner** (:class:`repro.planner.CapacityPlanner`),
  whose fault feedback now arrives as a *completion callback* when a
  flight lands, not inline on the dispatch path. An explicit
  ``pair_capacity="whp"``/``"exact"`` still pins every batch;
* the blocking API is a compatibility wrapper over futures, byte-identical
  to the synchronous path: ``flush()`` drains the pipeline and returns
  every *unclaimed* result, ``sort_one``/``sort_many`` are
  submit + ``future.result()``. Completed results stay in a **bounded**
  unclaimed store until claimed (``take_result`` / ``sort_one`` /
  ``sort_many``): past ``max_unclaimed`` the oldest entries are evicted
  (``evicted_results`` telemetry) — but a result is cached on its future
  at resolution, so the caller that actually holds the future never loses
  it. Auto-flush triggers (``max_pending`` size / ``flush_after_s``
  deadline) are now non-blocking: they form + launch, and let the caller
  block at claim time;
* telemetry: per-request wall latency (submit → result) with
  memoized percentiles (recomputed only when new completions landed, so
  soak-loop polling doesn't scale with window size), the accumulated
  :class:`TierStats`, dispatcher counters (in-flight peak, overlapped
  launches, failsink outcomes), per-bucket batch counts, auto-flush
  trigger counts, and planner plan/promotion counters.

One process-wide default executor serves all services, so every service
instance (and every other sort caller) shares compiled programs per bucket.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from typing import Deque, Dict, List, Optional, Sequence, Union

import numpy as np

from repro import obs
from repro.core import TierStats
from repro.core.api import SortExecutor, default_executor
from repro.planner import CapacityPlanner
from repro.service.batch import BatchFormer
from repro.service.dispatch import (
    Dispatcher,
    SortCancelledError,
    SortFuture,
    SortServiceError,
    SortTimeoutError,
)


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Static service knobs; the sort fields mirror SortConfig's."""

    p: int = 8  # simulated-processor lanes per fused sort
    algorithm: str = "iran"  # randomized oversampling: production default
    # First capacity tier, resolved per batch when "auto": the capacity
    # planner fingerprints the batch and picks (layout, starting tier,
    # oversampling ratio) — single-segment batches keep the raw-int32
    # contiguous hot path, multi-segment batches pack striped and start at
    # the segment-aware planned bound (repro.planner). An explicit
    # "whp"/"exact" pins the starting tier for every batch.
    pair_capacity: str = "auto"
    local_sort: str = "lax"
    # Ph6 tail of the fused sort: "sort" (stable re-sort) or "tree" (the
    # payload-generic rank-merge tail — the int64 composites and their pos
    # payload ride the lg p rank merges instead of a full re-sort).
    merge: str = "sort"
    max_batch_keys: int = 1 << 16  # batch former's packing cap
    min_n_per_proc: int = 8
    seed: int = 0
    # planner history persistence (pair_capacity="auto" only); None keeps
    # the learned rungs in-process
    planner_path: Optional[str] = None
    # auto-flush triggers (both optional): form + launch from submit() once
    # this many requests are pending / once the oldest pending request is
    # older than this deadline (non-blocking — block at future.result()).
    # Caller-driven flush() stays supported.
    max_pending: Optional[int] = None
    flush_after_s: Optional[float] = None
    # dispatch pipeline depth: batches launched-but-unawaited at once; 1
    # restores strictly serial dispatch (launch, wait, launch, ...)
    max_in_flight: int = 2
    # unclaimed-result store bound: oldest-first eviction past this many
    # unclaimed results (each eviction counts in ``evicted_results``; the
    # result stays cached on its SortFuture). None disables the bound.
    max_unclaimed: Optional[int] = 1024
    # failure hardening (repro.service.dispatch docstring has the model):
    # failsink re-enqueues back off failsink_backoff_s · 2^attempt (capped
    # at failsink_backoff_max_s) before relaunch eligibility; 0 restores
    # immediate retry. A failsink lineage past fault_retry_budget
    # generations stops bisecting and isolates every rid solo at once.
    failsink_backoff_s: float = 0.0
    failsink_backoff_max_s: float = 1.0
    fault_retry_budget: int = 8
    # circuit breaker: breaker_threshold consecutive failed launches in one
    # pow2 bucket degrade the bucket from fused batches to per-request
    # exact sorts for breaker_cooldown_s (0 disables the breaker)
    breaker_threshold: int = 4
    breaker_cooldown_s: float = 30.0
    # Observability handle (repro.obs.Tracer or None), hash/compare-excluded
    # like SortConfig.obs: the dispatcher records its queue→form→launch→
    # flight timeline on it and threads it into every fused sort launch.
    obs: Optional[object] = dataclasses.field(
        default=None, compare=False, repr=False
    )
    # Chaos handle (repro.chaos.FaultPlan or None), hash/compare-excluded
    # like ``obs``: deterministic seeded fault injection across the
    # dispatch path (launch faults, stragglers), the capacity ladder and
    # the delta views. A faulted service runs the same compiled programs
    # as a clean one.
    chaos: Optional[object] = dataclasses.field(
        default=None, compare=False, repr=False
    )


@dataclasses.dataclass
class RequestResult:
    """One request's output: sorted keys + stable argsort + telemetry."""

    rid: int
    keys: np.ndarray  # sorted ascending
    order: np.ndarray  # stable argsort: input[order] == keys
    tier: Optional[str]  # capacity tier that served this request's batch
    n_per_proc: int  # pow2 bucket the batch compiled under
    latency_s: float  # submit -> result wall time
    failsink: bool = False  # completed via a failsink re-dispatch


@dataclasses.dataclass
class _Pending:
    rid: int
    keys: np.ndarray
    future: SortFuture


class SortService:
    def __init__(
        self,
        cfg: ServiceConfig = ServiceConfig(),
        *,
        executor: Optional[SortExecutor] = None,
        stats: Optional[TierStats] = None,
        planner: Optional[CapacityPlanner] = None,
    ) -> None:
        # reject unsupported pins up front: "planned" needs a per-batch
        # bound only the planner can supply — a pinned service would fail
        # every batch into the failsink and error every future
        if cfg.pair_capacity not in ("auto", "whp", "exact"):
            raise ValueError(
                f"unsupported service pair_capacity {cfg.pair_capacity!r}: "
                "use 'auto' (planner-resolved) or pin 'whp'/'exact'"
            )
        self.cfg = cfg
        self.executor = executor if executor is not None else default_executor()
        self.stats = stats if stats is not None else TierStats()
        # the capacity planner resolves "auto" starting tiers; a shared
        # instance lets several services pool their traffic history
        self.planner = (
            planner
            if planner is not None
            else CapacityPlanner(path=cfg.planner_path)
        )
        self.former = BatchFormer(
            cfg.p, cfg.max_batch_keys, cfg.min_n_per_proc
        )
        self.dispatcher = Dispatcher(
            cfg,
            former=self.former,
            executor=self.executor,
            planner=self.planner,
            stats=self.stats,
            on_result=self._deliver,
            on_failure=self._deliver_failure,
            max_in_flight=cfg.max_in_flight,
        )
        self._pending: List[_Pending] = []
        self._completed: Dict[int, RequestResult] = {}  # unclaimed results
        self._next_rid = 0
        # submit/flush/drive share queue state; the RLock makes them safe
        # to call from a background driver thread (start_driver) alongside
        # the submitting thread. Reentrant: _drive flushes under the lock.
        self._lock = threading.RLock()
        self._driver: Optional[threading.Thread] = None
        self._driver_stop = threading.Event()
        # telemetry — lives in the process-wide metrics registry under the
        # dispatcher's instance label (one label per service). The latency
        # histogram keeps a bounded window (a long-lived serving process
        # must not grow one float per request forever) with the lifetime
        # request count as its own counter; the legacy attribute names
        # (latencies, requests_done, ...) are read-only property views.
        self.label = self.dispatcher.label
        reg = obs.metrics()
        self._lat = reg.histogram("service.request_latency_s", svc=self.label)
        self._requests_done = reg.counter("service.requests_done", svc=self.label)
        self._requests_failed = reg.counter(
            "service.requests_failed", svc=self.label
        )
        self._evicted = reg.counter("service.evicted_results", svc=self.label)
        self._cancelled = reg.counter(
            "service.cancelled_requests", svc=self.label
        )
        self._deadline_timeouts = reg.counter(
            "service.deadline_timeouts", svc=self.label
        )

    # ----------------------------------------------- registry metric views
    @property
    def latencies(self) -> Deque[float]:
        """The latency histogram's bounded recent-value window (seconds)."""
        return self._lat.values

    @property
    def requests_done(self) -> int:
        return self._requests_done.value

    @property
    def requests_failed(self) -> int:
        return self._requests_failed.value

    @property
    def evicted_results(self) -> int:
        return self._evicted.value

    @property
    def flush_triggers(self) -> Dict[str, int]:
        """trigger (manual/size/deadline/ready/claim) -> flush count."""
        return {
            str(lbl["trigger"]): c.value
            for lbl, c in obs.metrics().collect(
                "service.flush_triggers", svc=self.label
            )
        }

    def _count_flush(self, trigger: str) -> None:
        obs.metrics().counter(
            "service.flush_triggers", svc=self.label, trigger=trigger
        ).inc()

    # -------------------------------------------- dispatcher delegation
    # batch-level counters live on the dispatcher (completion is its job
    # now); these read-only views keep the PR-3/4 telemetry surface
    @property
    def batches_dispatched(self) -> int:
        return self.dispatcher.batches_dispatched

    @property
    def keys_sorted(self) -> int:
        return self.dispatcher.keys_sorted

    @property
    def bucket_counts(self) -> Dict[int, int]:
        return self.dispatcher.bucket_counts

    @property
    def start_tiers(self) -> Dict[str, int]:
        return self.dispatcher.start_tiers

    # ------------------------------------------------------------- queue
    def submit(
        self,
        keys: np.ndarray,
        *,
        stream: Optional[object] = None,
        deadline_s: Optional[float] = None,
    ) -> SortFuture:
        """Queue one ragged request (1-D int32 keys); returns a future.

        The future resolves at ``result()`` time (driving the dispatcher as
        needed) — nothing is dispatched before an auto-flush trigger, a
        ``flush``/``flush_async``, or a claim forces it. Auto-flush
        triggers launch batches without blocking; the submitted request's
        result is then claimable via the returned future or
        ``take_result``.

        ``deadline_s`` bounds the *un-launched* wait: a request still
        queued (pending here, or formed in the dispatcher queue) when the
        deadline passes is expired by the deadline sweeps
        (:meth:`run_pending`, any flush entry) and its future resolves
        with a :class:`SortTimeoutError` naming the rid. Once its batch
        launches the deadline no longer applies — completing paid-for
        device work is strictly better than discarding it. The returned
        future also supports ``cancel()`` while un-launched.

        ``stream`` opts into **incremental** semantics: submits naming the
        same stream key share one standing sorted view, and each submit
        folds its keys in (Δ-sized device work — ``repro.delta``) instead
        of resorting the stream's whole history. The result covers the
        *entire stream so far*: ``keys`` is the sorted concatenation of
        every batch submitted to the stream, ``order`` its stable argsort
        (int64 arrival indices). Stream folds are synchronous — each fold
        depends on the view the previous one produced — so the future
        returns already resolved.
        """
        arr = np.asarray(keys, np.int32).reshape(-1)
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            if stream is not None:
                fut = SortFuture(rid, self._drive)
                t0 = fut.submitted_at
                skeys, order, tier, n_p = self.dispatcher.fold_stream(
                    stream, arr
                )
                lat = time.perf_counter() - t0
                self._lat.observe(lat)
                self._requests_done.inc()
                res = RequestResult(
                    rid=rid, keys=skeys, order=order, tier=tier,
                    n_per_proc=n_p, latency_s=lat,
                )
                fut._resolve(res)
                self._completed[rid] = res
                return fut
            fut = SortFuture(rid, self._drive)
            if deadline_s is not None:
                fut.deadline_at = fut.submitted_at + float(deadline_s)
            fut._canceller = self._cancel
            self._pending.append(_Pending(rid, arr, fut))
            if (
                self.cfg.max_pending is not None
                and len(self._pending) >= self.cfg.max_pending
            ):
                self.flush_async(trigger="size")
            else:
                self.maybe_flush()
            return fut

    def maybe_flush(self) -> bool:
        """Deadline check: launch the queue if the oldest request is overdue.

        Called from ``submit`` and pollable from an event loop (the service
        has no thread of its own, so a deadline only fires when *somebody*
        calls in). Non-blocking: batches are formed and launched, results
        claimed later. Returns whether a flush was triggered.
        """
        if (
            self.cfg.flush_after_s is not None
            and self._pending
            and time.perf_counter() - self._pending[0].future.submitted_at
            >= self.cfg.flush_after_s
        ):
            self.flush_async(trigger="deadline")
            return True
        return False

    @property
    def pending(self) -> int:
        return len(self._pending)

    # ---------------------------------------------------------- dispatch
    def flush_async(self, trigger: str = "manual") -> bool:
        """Form every pending request into batches and start launching.

        Non-blocking: batches enter the dispatcher's queue and up to
        ``max_in_flight`` of them launch immediately (host planning/packing
        overlapping any in-flight device work). Returns whether anything
        was enqueued.
        """
        with self._lock:
            self._expire_deadlines()
            todo, self._pending = self._pending, []
            if todo:
                self._count_flush(trigger)
            fut_by_rid = {r.rid: r.future for r in todo}
            for batch in self.former.form([(r.rid, r.keys) for r in todo]):
                self.dispatcher.enqueue(
                    batch, {rid: fut_by_rid[rid] for rid in batch.rids}
                )
            self.dispatcher.pump()
            return bool(todo)

    def flush_ready(self, min_keys: Optional[int] = None) -> bool:
        """Admission-aware launch for open-loop arrival pumps.

        Dispatches only batches that are full enough
        (:meth:`BatchFormer.form_ready`); an underfilled tail batch stays
        pending for more traffic — the deadline trigger or any plain
        ``flush`` clears it, so nothing starves. Non-blocking; returns
        whether any batch launched.
        """
        with self._lock:
            self._expire_deadlines()
            todo, self._pending = self._pending, []
            fut_by_rid = {r.rid: r.future for r in todo}
            batches, held = self.former.form_ready(
                [(r.rid, r.keys) for r in todo], min_keys=min_keys
            )
            if batches:
                self._count_flush("ready")
            for batch in batches:
                self.dispatcher.enqueue(
                    batch, {rid: fut_by_rid[rid] for rid in batch.rids}
                )
            self._pending = [
                _Pending(rid, keys, fut_by_rid[rid]) for rid, keys in held
            ] + self._pending
            self.dispatcher.pump()
            return bool(batches)

    def flush(self, trigger: str = "manual") -> Dict[int, RequestResult]:
        """Sort everything queued; one fused segmented sort per batch.

        Blocking wrapper over the async pipeline: forms + launches, then
        drains every in-flight batch. Returns every unclaimed result — the
        newly completed ones plus any earlier completion not yet taken (a
        request fused into another caller's flush stays claimable).
        Claiming (``take_result`` / ``sort_one`` / ``sort_many``) removes a
        result from the store. A failed request does NOT raise here — its
        future (and ``take_result``) carries the :class:`SortServiceError`.
        """
        with self._lock:
            self.flush_async(trigger)
            try:
                self.dispatcher.drain()
            finally:
                # one history write per flush (not per batch), raise or not.
                # Persistence is telemetry, not dispatch: an unwritable path
                # must neither fail completed sorts nor mask a batch
                # exception.
                try:
                    self.planner.save_if_dirty()
                except OSError as e:
                    warnings.warn(f"planner history not persisted: {e}")
            return dict(self._completed)

    def _drive(self, fut: SortFuture) -> None:
        """SortFuture's engine: launch anything queued, run until it lands."""
        with self._lock:
            if any(r.rid == fut.rid for r in self._pending):
                self.flush_async(trigger="claim")
            self.dispatcher.drive(fut)

    # ------------------------------------- deadlines, cancellation, driver
    def _cancel(self, fut: SortFuture) -> bool:
        """``SortFuture.cancel()``'s backend: unpick an un-launched request.

        Pending requests are removed from the submit queue; formed-but-
        queued ones are unpicked from their batch in the dispatcher (the
        batch re-forms without them). A launched/resolved request reports
        False and runs to completion. On success the future resolves with
        a :class:`SortCancelledError` — the request never launches.
        """
        with self._lock:
            if fut.done():
                return False
            was_pending = any(r.rid == fut.rid for r in self._pending)
            if was_pending:
                self._pending = [r for r in self._pending if r.rid != fut.rid]
            elif not self.dispatcher.cancel_rid(fut.rid):
                return False
            self._cancelled.inc()
            fut._fail(
                SortCancelledError(
                    f"request rid={fut.rid} cancelled before launch",
                    rids=(fut.rid,),
                )
            )
            return True

    def _expire_deadlines(self, now: Optional[float] = None) -> int:
        """Fail every un-launched request whose deadline passed.

        Sweeps both queues: requests still pending here, and requests
        formed into the dispatcher's batch queue (its own sweep unpicks
        them). Launched requests are never expired.
        """
        with self._lock:
            now = time.perf_counter() if now is None else now
            expired = [
                r
                for r in self._pending
                if r.future.deadline_at is not None
                and now >= r.future.deadline_at
                and not r.future.done()
            ]
            if expired:
                dead = {r.rid for r in expired}
                self._pending = [
                    r for r in self._pending if r.rid not in dead
                ]
                for r in expired:
                    self._deliver_failure(
                        r.future,
                        SortTimeoutError(
                            f"request rid={r.rid} expired un-launched "
                            f"(deadline passed while pending)",
                            rids=(r.rid,),
                        ),
                    )
            return len(expired) + self.dispatcher.expire_deadlines(now)

    def run_pending(self, max_steps: int = 1) -> bool:
        """Driver pump: advance time-triggered work without a submitter.

        One call expires overdue deadlines (pending + formed), fires the
        ``flush_after_s`` auto-flush if the oldest pending request is
        overdue — so a quiet service still flushes without anyone
        submitting or claiming — and lets the dispatcher launch
        backoff-due batches and complete up to ``max_steps`` flights.
        Callable from a thread (:meth:`start_driver`) or polled from an
        event loop. Returns whether work remains.
        """
        with self._lock:
            self._expire_deadlines()
            self.maybe_flush()
            busy = self.dispatcher.run_pending(max_steps=max_steps)
            return busy or bool(self._pending)

    def start_driver(self, interval_s: float = 0.002) -> None:
        """Run :meth:`run_pending` on a daemon thread every ``interval_s``.

        Idempotent. With a driver running, deadline flushes, backoff
        retries and deadline expirations proceed while every caller thread
        is idle; futures resolve in the background and ``result()`` returns
        without driving.
        """
        with self._lock:
            if self._driver is not None and self._driver.is_alive():
                return
            self._driver_stop.clear()

            def _loop() -> None:
                while not self._driver_stop.wait(interval_s):
                    self.run_pending(max_steps=1)

            self._driver = threading.Thread(
                target=_loop, name=f"sort-service-driver-{self.label}",
                daemon=True,
            )
            self._driver.start()

    def stop_driver(self) -> None:
        """Stop the driver thread (waits for the current pump to finish)."""
        t = self._driver
        if t is None:
            return
        self._driver_stop.set()
        t.join(timeout=5.0)
        self._driver = None

    # -------------------------------------------------------- completion
    def _deliver(self, fut: SortFuture, keys, order, tier, n_per_proc) -> None:
        """Dispatcher completion callback: resolve the future + store."""
        lat = time.perf_counter() - fut.submitted_at
        self._lat.observe(lat)
        self._requests_done.inc()
        res = RequestResult(
            rid=fut.rid,
            keys=keys,
            order=order,
            tier=tier,
            n_per_proc=n_per_proc,
            latency_s=lat,
            failsink=fut.failsink,
        )
        fut._resolve(res)
        self._completed[fut.rid] = res
        if self.cfg.max_unclaimed is not None:
            while len(self._completed) > self.cfg.max_unclaimed:
                oldest = next(iter(self._completed))  # insertion order
                del self._completed[oldest]
                self._evicted.inc()

    def _deliver_failure(self, fut: SortFuture, exc: BaseException) -> None:
        self._requests_failed.inc()
        if isinstance(exc, SortTimeoutError):
            self._deadline_timeouts.inc()
        fut._fail(exc)

    def take_result(
        self, rid: Union[int, SortFuture]
    ) -> RequestResult:
        """Claim (remove) one completed result; drives it if still in flight.

        Accepts a rid or the :class:`SortFuture` itself. Raises the
        request's :class:`SortServiceError` if it terminally failed, and a
        ``SortServiceError`` naming the rid if no such result exists
        (never a bare ``KeyError``) — unknown, already claimed, or evicted
        without the future in hand.
        """
        if isinstance(rid, SortFuture):
            res = rid.result()  # drives; raises the failure if it failed
            self._completed.pop(rid.rid, None)
            return res
        if rid not in self._completed and (
            any(r.rid == rid for r in self._pending)
            or not self.dispatcher.idle
        ):
            self.flush()
        try:
            return self._completed.pop(rid)
        except KeyError:
            raise SortServiceError(
                f"no claimable result for rid={rid}: unknown, already "
                "claimed, failed, or evicted from the unclaimed store "
                "(hold the SortFuture to survive eviction)",
                rids=(rid,),
            ) from None

    # ------------------------------------------------------ conveniences
    def sort_many(self, arrays: Sequence[np.ndarray]) -> List[RequestResult]:
        """Submit a batch of requests and flush; results in input order.

        A request that terminally failed (failsink-isolated solo and still
        failing) raises a :class:`SortServiceError` naming every failed
        rid — nothing is claimed then, so the completed requests' results
        all remain claimable via ``take_result``.
        """
        futs = [self.submit(a) for a in arrays]
        self.flush()
        failed = [f for f in futs if f.exception() is not None]
        if failed:
            raise SortServiceError(
                f"sort_many: {len(failed)} of {len(futs)} requests failed "
                f"(rids {[f.rid for f in failed]}); completed results stay "
                "claimable via take_result",
                rids=tuple(f.rid for f in failed),
            ) from failed[0].exception()
        return [self.take_result(f) for f in futs]

    def sort_one(self, keys: np.ndarray) -> RequestResult:
        """Sort a single request through the service. It fuses with anything
        already queued — and the piggybacked requests' results stay in the
        store for their own callers (``flush``/``take_result``)."""
        fut = self.submit(keys)
        self.flush()
        return self.take_result(fut)

    def _latency_row(self) -> Dict[str, object]:
        """Latency stats from the registry histogram. The memoization the
        soak loop relies on (poll telemetry without rescanning the window
        when nothing new completed) lives in ``Histogram.summary``."""
        s = self._lat.summary()
        if not s.get("count"):
            return {}
        return {
            "lat_mean_ms": round(s["mean"] * 1e3, 3),
            "lat_p50_ms": round(s["p50"] * 1e3, 3),
            "lat_p99_ms": round(s["p99"] * 1e3, 3),
        }

    def telemetry(self) -> Dict[str, object]:
        """Flat snapshot for logs/benchmark rows; latency stats cover the
        bounded recent window, ``requests`` the service lifetime."""
        row: Dict[str, object] = {
            "requests": self.requests_done,
            "requests_failed": self.requests_failed,
            "batches": self.batches_dispatched,
            "keys_sorted": self.keys_sorted,
            "buckets": dict(sorted(self.bucket_counts.items())),
            "flush_triggers": dict(sorted(self.flush_triggers.items())),
            "start_tiers": dict(sorted(self.start_tiers.items())),
            "evicted_results": self.evicted_results,
            "cancelled_requests": self._cancelled.value,
            "deadline_timeouts": self._deadline_timeouts.value,
            "dispatch": self.dispatcher.telemetry(),
        }
        if self.cfg.pair_capacity == "auto":
            row["planner"] = self.planner.telemetry()
        row.update(self._latency_row())
        row.update(self.stats.as_row())
        return row
