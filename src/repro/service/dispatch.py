"""Async dispatch queue: futures, in-flight batches, failsink isolation.

The service's original ``flush`` was a barrier: every submitter stalled
while one batch's collectives ran, and the host sat idle between batches —
exactly the regularity the BSP model promises, wasted at the service layer.
This module restructures dispatch around three pieces:

* :class:`SortFuture` — ``submit()``'s return value. Created unresolved;
  ``result()`` drives the dispatcher until the request completes (or
  re-raises its failure). A future outlives the service's bounded
  unclaimed-result store: the result is cached on the future at resolution,
  so an evicted store entry is still claimable by the caller that holds the
  future.

* :class:`Dispatcher` — a queue of formed batches plus up to
  ``max_in_flight`` *launched* ones. Launching a batch is host work
  (fingerprint → plan → pack) ending in :func:`segmented_sort_launch`,
  which dispatches the sort's first capacity rung to the device queue and
  returns without blocking — so while batch k's collectives execute, the
  dispatcher is already planning/packing/launching batch k+1 (JAX async
  dispatch provides the overlap; ``overlapped_launches`` counts launches
  performed with another batch's device work outstanding). Completion
  (:meth:`Dispatcher.step`) blocks on the *oldest* flight only, resolves
  its futures, and feeds the planner its fault outcome — planner feedback
  is a completion callback, not a dispatch-path stall.

* **Failsink** per-request fault isolation. A batch that raises (backend
  error, ladder exhaustion) used to crash-requeue every rid and re-raise at
  the submitter; one poison request could re-fail the whole queue forever.
  Now the dispatcher *bisects*: the failed batch is split in two and both
  halves re-formed and re-enqueued at the queue head, recursively, until
  the poison request stands alone. A solo request gets one failsink retry;
  if it still fails, its future resolves with a :class:`SortServiceError`
  naming the rid — every innocent rid in the original batch completes
  normally, and every future resolves (no rid is ever lost or silently
  requeued). Requests that rode a failsink re-dispatch carry a
  ``failsink=True`` telemetry mark on their result and future.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, List, Optional, Tuple

from repro.core import TierStats
from repro.core.api import SortExecutor
from repro.core.segmented import (
    InFlightSegmentedSort,
    pack_segments,
    segmented_sort_launch,
)
from repro.planner import CapacityPlanner

from .batch import Batch, BatchFormer


class SortServiceError(RuntimeError):
    """A service request (or batch) failed; ``rids`` names the victims."""

    def __init__(self, message: str, rids: Tuple[int, ...] = ()) -> None:
        super().__init__(message)
        self.rids = tuple(rids)


class SortFuture:
    """Handle for one submitted request; resolves to a ``RequestResult``.

    ``submit()`` returns immediately with one of these — nothing has been
    dispatched yet. ``result()`` blocks (driving the service's dispatcher)
    until the request's batch completes, then returns the request's
    :class:`repro.service.RequestResult`; if the request failed past the
    failsink ladder, it re-raises the stored :class:`SortServiceError`.
    ``done()`` never blocks. The resolved result is cached here, so the
    future stays claimable even after the service's bounded unclaimed-result
    store evicted it.
    """

    def __init__(self, rid: int, drive: Callable[["SortFuture"], None]) -> None:
        self.rid = rid
        self.submitted_at = time.perf_counter()
        self.failsink = False  # rode a failsink re-dispatch
        self._drive = drive
        self._done = False
        self._result = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done

    def result(self):
        if not self._done:
            self._drive(self)
        if self._exc is not None:
            raise self._exc
        return self._result

    def exception(self) -> Optional[BaseException]:
        if not self._done:
            self._drive(self)
        return self._exc

    # internal — called by the dispatcher exactly once
    def _resolve(self, result) -> None:
        self._result = result
        self._done = True

    def _fail(self, exc: BaseException) -> None:
        self._exc = exc
        self._done = True

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        state = "done" if self._done else "pending"
        return f"SortFuture(rid={self.rid}, {state})"


@dataclasses.dataclass
class _Queued:
    """One formed batch waiting for a launch slot."""

    batch: Batch
    futures: Dict[int, SortFuture]
    failsink: bool  # this batch is a failsink re-dispatch


@dataclasses.dataclass
class _Flight:
    """One launched batch: device work in the queue, not yet awaited."""

    batch: Batch
    futures: Dict[int, SortFuture]
    failsink: bool
    decision: object  # planner PlanDecision (None when tier pinned)
    start_tier: str
    stats: TierStats  # isolated per batch; merged into the shared stats
    inflight: InFlightSegmentedSort


class Dispatcher:
    """Formed-batch queue + up to ``max_in_flight`` launched batches.

    Owns the batch-level dispatch pipeline (plan → pack → launch → await →
    resolve futures) and its telemetry; :class:`repro.service.SortService`
    is a thin facade that forms batches into :meth:`enqueue` and claims
    results through the futures. Two completion callbacks connect the
    layers: ``on_result(future, keys, order, tier, n_per_proc)`` delivers
    one finished request, ``on_failure(future, exc)`` one terminal failure.
    """

    def __init__(
        self,
        cfg,
        *,
        former: BatchFormer,
        executor: SortExecutor,
        planner: CapacityPlanner,
        stats: TierStats,
        on_result: Callable,
        on_failure: Callable,
        max_in_flight: int = 2,
    ) -> None:
        self.cfg = cfg
        self.former = former
        self.executor = executor
        self.planner = planner
        self.stats = stats
        self.on_result = on_result
        self.on_failure = on_failure
        self.max_in_flight = max(1, int(max_in_flight))
        self._queue: Deque[_Queued] = collections.deque()
        self._flights: Deque[_Flight] = collections.deque()
        # telemetry
        self.launches = 0
        self.overlapped_launches = 0  # launched while another batch flew
        self.in_flight_peak = 0
        self.batches_dispatched = 0
        self.keys_sorted = 0
        self.bucket_counts: Dict[int, int] = {}  # n_per_proc -> batches
        self.start_tiers: Dict[str, int] = {}  # starting tier -> batches
        self.failsink_splits = 0  # batch bisections after a failure
        self.failsink_solo_retries = 0  # solo re-dispatch of a failed rid
        self.failsink_errors = 0  # rids terminally failed past failsink
        self.failsink_resolved = 0  # rids completing on a failsink re-dispatch

    # ------------------------------------------------------------- queue
    @property
    def idle(self) -> bool:
        return not self._queue and not self._flights

    @property
    def in_flight(self) -> int:
        return len(self._flights)

    def enqueue(
        self,
        batch: Batch,
        futures: Dict[int, SortFuture],
        *,
        failsink: bool = False,
        front: bool = False,
    ) -> None:
        item = _Queued(batch=batch, futures=futures, failsink=failsink)
        if front:
            self._queue.appendleft(item)
        else:
            self._queue.append(item)

    # ---------------------------------------------------------- dispatch
    def _resolve_batch(self, batch: Batch):
        """(packed, sort overrides, decision) for one formed batch."""
        if self.cfg.pair_capacity != "auto":  # explicit pin: PR 3 behaviour
            packed = pack_segments(
                batch.arrays,
                self.cfg.p,
                n_per_proc=batch.n_per_proc,
                min_n_per_proc=self.cfg.min_n_per_proc,
            )
            return packed, {"pair_capacity": self.cfg.pair_capacity}, None
        decision = self.planner.plan(
            batch.arrays,
            self.cfg.p,
            n_per_proc=batch.n_per_proc,
            min_n_per_proc=self.cfg.min_n_per_proc,
        )
        packed = pack_segments(
            batch.arrays,
            self.cfg.p,
            n_per_proc=batch.n_per_proc,
            min_n_per_proc=self.cfg.min_n_per_proc,
            layout=decision.layout,
        )
        overrides = {"pair_capacity": decision.pair_capacity}
        if decision.route == "radix":
            # count-then-distribute: the launch driver host-reads the exact
            # counts and runs ONE rung — radix batches report retries == 0
            # by construction
            overrides["route"] = "radix"
        elif decision.pair_capacity == "planned":
            overrides["pair_cap_override"] = decision.pair_cap_override
            overrides["omega"] = decision.omega
        return packed, overrides, decision

    def pump(self) -> None:
        """Launch queued batches into free in-flight slots (non-blocking).

        The host-side plan/pack/launch of a later batch runs while earlier
        flights' collectives execute on the device — this loop is the
        overlap the async restructure exists for.
        """
        while self._queue and len(self._flights) < self.max_in_flight:
            item = self._queue.popleft()
            try:
                packed, overrides, decision = self._resolve_batch(item.batch)
                batch_stats = TierStats()  # isolates this batch's outcome
                inflight = segmented_sort_launch(
                    packed,
                    algorithm=self.cfg.algorithm,
                    local_sort=self.cfg.local_sort,
                    merge=self.cfg.merge,
                    seed=self.cfg.seed,
                    stats=batch_stats,
                    executor=self.executor,
                    **overrides,
                )
            except Exception as exc:  # launch-time failure: same failsink
                self._handle_failure(item, exc)
                continue
            self.launches += 1
            if len(self._flights) >= 1:
                self.overlapped_launches += 1
            self._flights.append(
                _Flight(
                    batch=item.batch,
                    futures=item.futures,
                    failsink=item.failsink,
                    decision=decision,
                    start_tier=(
                        "radix"
                        if overrides.get("route") == "radix"
                        else overrides["pair_capacity"]
                    ),
                    stats=batch_stats,
                    inflight=inflight,
                )
            )
            self.in_flight_peak = max(self.in_flight_peak, len(self._flights))

    def step(self) -> bool:
        """Complete the oldest in-flight batch (blocking), refill the slots.

        Returns False when there was nothing to do. Completion order is
        launch order — FIFO, like the synchronous flush — so shared-stats
        accumulation and planner feedback see batches in the same order as
        before the async restructure.
        """
        self.pump()
        if not self._flights:
            return False
        flight = self._flights.popleft()
        try:
            seg = flight.inflight.wait()
        except Exception as exc:
            self._handle_failure(flight, exc)
            self.pump()
            return True
        self._complete(flight, seg)
        self.pump()
        return True

    def drain(self) -> None:
        """Run the pipeline dry: every queued batch launched and awaited."""
        while self.step():
            pass

    def drive(self, fut: SortFuture) -> None:
        """Advance the pipeline until ``fut`` resolves (or the queue dries)."""
        while not fut.done() and not self.idle:
            self.step()

    # -------------------------------------------------------- completion
    def _complete(self, flight: _Flight, seg) -> None:
        self.stats.merge_from(flight.stats)
        if flight.decision is not None:
            # planner feedback as a completion callback: did the starting
            # tier overflow? (Persistence stays deferred to the service's
            # flush boundary — save_if_dirty there.)
            self.planner.record(flight.decision, faulted=flight.stats.retries > 0)
        self.start_tiers[flight.start_tier] = (
            self.start_tiers.get(flight.start_tier, 0) + 1
        )
        self.batches_dispatched += 1
        self.keys_sorted += flight.batch.total_keys
        self.bucket_counts[flight.batch.n_per_proc] = (
            self.bucket_counts.get(flight.batch.n_per_proc, 0) + 1
        )
        if flight.failsink:
            self.failsink_resolved += len(flight.batch.rids)
        for rid, keys, order in zip(flight.batch.rids, seg.keys, seg.order):
            fut = flight.futures[rid]
            fut.failsink = fut.failsink or flight.failsink
            self.on_result(fut, keys, order, seg.tier, seg.n_per_proc)

    def _handle_failure(self, item, exc: Exception) -> None:
        """Failsink: bisect a failed batch instead of failing everyone.

        Halves are re-formed through the batch former (their pow2 bucket
        shrinks with the batch) and re-enqueued at the queue *head*, so the
        isolation converges before new traffic is admitted. A solo request
        gets exactly one failsink retry (``failsink`` marks it); a marked
        solo failure is terminal — its future carries a
        :class:`SortServiceError` naming the rid, chained to the backend
        error.
        """
        rids, arrays = item.batch.rids, item.batch.arrays
        if len(rids) == 1 and item.failsink:
            rid = rids[0]
            fut = item.futures[rid]
            fut.failsink = True
            err = SortServiceError(
                f"request rid={rid} failed solo after failsink isolation: "
                f"{exc!r}",
                rids=(rid,),
            )
            err.__cause__ = exc
            self.failsink_errors += 1
            self.on_failure(fut, err)
            return
        if len(rids) == 1:
            self.failsink_solo_retries += 1
            halves = [list(zip(rids, arrays))]
        else:
            self.failsink_splits += 1
            mid = len(rids) // 2
            halves = [
                list(zip(rids[:mid], arrays[:mid])),
                list(zip(rids[mid:], arrays[mid:])),
            ]
        requeue: List[_Queued] = []
        for half in halves:
            for batch in self.former.form(half):
                requeue.append(
                    _Queued(
                        batch=batch,
                        futures={r: item.futures[r] for r in batch.rids},
                        failsink=True,
                    )
                )
        self._queue.extendleft(reversed(requeue))  # keep half order at head

    def telemetry(self) -> Dict[str, int]:
        return {
            "max_in_flight": self.max_in_flight,
            "in_flight_peak": self.in_flight_peak,
            "overlapped_launches": self.overlapped_launches,
            "failsink_splits": self.failsink_splits,
            "failsink_solo_retries": self.failsink_solo_retries,
            "failsink_resolved": self.failsink_resolved,
            "failsink_errors": self.failsink_errors,
        }
