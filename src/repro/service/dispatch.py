"""Async dispatch queue: futures, in-flight batches, failsink isolation.

The service's original ``flush`` was a barrier: every submitter stalled
while one batch's collectives ran, and the host sat idle between batches —
exactly the regularity the BSP model promises, wasted at the service layer.
This module restructures dispatch around three pieces:

* :class:`SortFuture` — ``submit()``'s return value. Created unresolved;
  ``result()`` drives the dispatcher until the request completes (or
  re-raises its failure). A future outlives the service's bounded
  unclaimed-result store: the result is cached on the future at resolution,
  so an evicted store entry is still claimable by the caller that holds the
  future. ``cancel()`` unpicks a not-yet-launched request — out of the
  service's pending list or out of its *formed* batch in the dispatcher
  queue (the batch re-forms without it) — and resolves the future with a
  :class:`SortCancelledError`; a launched request is past cancellation.

* :class:`Dispatcher` — a queue of formed batches plus up to
  ``max_in_flight`` *launched* ones. Launching a batch is host work
  (fingerprint → plan → pack) ending in :func:`segmented_sort_launch`,
  which dispatches the sort's first capacity rung to the device queue and
  returns without blocking — so while batch k's collectives execute, the
  dispatcher is already planning/packing/launching batch k+1 (JAX async
  dispatch provides the overlap; ``overlapped_launches`` counts launches
  performed with another batch's device work outstanding). Completion
  (:meth:`Dispatcher.step`) blocks on the *oldest* flight only, resolves
  its futures, and feeds the planner its fault outcome — planner feedback
  is a completion callback, not a dispatch-path stall.
  :meth:`Dispatcher.run_pending` is the driver pump: callable from a
  thread or event loop, it expires overdue deadlines, launches
  backoff-due batches into free slots, and (optionally) completes
  flights — so deadline- and backoff-due work proceeds without any
  submitter blocking.

* **Failsink** per-request fault isolation. A batch that raises (backend
  error, ladder exhaustion, injected :class:`repro.chaos.ChaosError`)
  used to crash-requeue every rid and re-raise at the submitter; one
  poison request could re-fail the whole queue forever. Now the
  dispatcher *bisects*: the failed batch is split in two and both halves
  re-formed and re-enqueued at the queue head, recursively, until the
  poison request stands alone. Every rid then gets exactly one solo
  retry (whether it arrived solo or was isolated by bisection — so a
  one-shot fault on the isolation dispatch never kills an innocent);
  if it still fails, its future resolves with a :class:`SortServiceError`
  naming the rid — every innocent rid in the original batch completes
  normally, and every future resolves (no rid is ever lost or silently
  requeued). Requests that rode a failsink re-dispatch carry a
  ``failsink=True`` telemetry mark on their result and future.

Failsink re-enqueues are wrapped in a **retry budget with exponential
backoff**: each re-dispatch generation waits
``failsink_backoff_s · 2^attempt`` (capped at ``failsink_backoff_max_s``)
before it is launch-eligible, and the pump *scans past* backing-off
entries — innocents from a bisected batch and fresh traffic never starve
behind the retry queue. A lineage that exhausts ``fault_retry_budget``
generations skips further bisection and explodes straight to per-rid solo
dispatches (isolation accelerates; innocents still complete). A **circuit
breaker** watches consecutive failures per pow2 bucket: at
``breaker_threshold`` the bucket degrades from fused-batch to per-request
exact sort for ``breaker_cooldown_s`` (``breaker_opened`` /
``breaker_degraded_batches`` telemetry) — a repeatedly-poisoned bucket
stops dragging innocents into its failing fused launches at all.

Chaos injection (``ServiceConfig.chaos`` — a ``repro.chaos.FaultPlan``,
hash-excluded like ``obs``) exercises all of the above deterministically:
launch faults raise at the top of the launch path, straggler delays sleep
at the flight sync (feeding the ``train/elastic.StragglerMonitor`` wiring
— slow flights count in ``svc.straggler_flights``), and capacity faults
ride the plan into ``core.api.InFlightSort``.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.chaos import ChaosError, resolve_chaos
from repro.core import TierStats
from repro.core.api import SortExecutor
from repro.core.segmented import (
    InFlightSegmentedSort,
    pack_segments,
    segmented_sort_launch,
)
from repro.delta import SortedView, near_sorted_sort_launch
from repro.planner import CapacityPlanner

from .batch import Batch, BatchFormer


class SortServiceError(RuntimeError):
    """A service request (or batch) failed; ``rids`` names the victims."""

    def __init__(self, message: str, rids: Tuple[int, ...] = ()) -> None:
        super().__init__(message)
        self.rids = tuple(rids)


class SortTimeoutError(SortServiceError):
    """A request's ``deadline_s`` expired before its batch launched."""


class SortCancelledError(SortServiceError):
    """A request was cancelled before its batch launched."""


class SortFuture:
    """Handle for one submitted request; resolves to a ``RequestResult``.

    ``submit()`` returns immediately with one of these — nothing has been
    dispatched yet. ``result()`` blocks (driving the service's dispatcher)
    until the request's batch completes, then returns the request's
    :class:`repro.service.RequestResult`; if the request failed past the
    failsink ladder, it re-raises the stored :class:`SortServiceError`.
    ``done()`` never blocks. The resolved result is cached here, so the
    future stays claimable even after the service's bounded unclaimed-result
    store evicted it.

    ``cancel()`` asks the service to unpick the request while it is still
    un-launched (pending, or formed-but-queued — the batch re-forms
    without it); on success the future resolves with a
    :class:`SortCancelledError` and returns True. A request whose batch
    already launched (or that already resolved) reports False and runs to
    completion normally. ``deadline_at`` (set by ``submit(deadline_s=…)``)
    is the perf_counter instant past which an *un-launched* request is
    expired with a :class:`SortTimeoutError` by the deadline sweeps.
    """

    def __init__(self, rid: int, drive: Callable[["SortFuture"], None]) -> None:
        self.rid = rid
        self.submitted_at = time.perf_counter()
        self.deadline_at: Optional[float] = None
        self.failsink = False  # rode a failsink re-dispatch
        self._drive = drive
        self._canceller: Optional[Callable[["SortFuture"], bool]] = None
        self._done = False
        self._result = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done

    def result(self):
        if not self._done:
            self._drive(self)
        if self._exc is not None:
            raise self._exc
        return self._result

    def exception(self) -> Optional[BaseException]:
        if not self._done:
            self._drive(self)
        return self._exc

    def cancel(self) -> bool:
        """Unpick the request if it has not launched; True on success."""
        if self._done or self._canceller is None:
            return False
        return bool(self._canceller(self))

    def cancelled(self) -> bool:
        """Whether the future resolved via :meth:`cancel` (never blocks)."""
        return isinstance(self._exc, SortCancelledError)

    # internal — called by the dispatcher exactly once
    def _resolve(self, result) -> None:
        self._result = result
        self._done = True

    def _fail(self, exc: BaseException) -> None:
        self._exc = exc
        self._done = True

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        state = "done" if self._done else "pending"
        return f"SortFuture(rid={self.rid}, {state})"


@dataclasses.dataclass
class _Queued:
    """One formed batch waiting for a launch slot."""

    batch: Batch
    futures: Dict[int, SortFuture]
    failsink: bool  # this batch is a failsink re-dispatch
    attempt: int = 0  # failsink lineage generation (0 = fresh traffic)
    not_before: float = 0.0  # perf_counter backoff gate (0 = launchable)
    degraded: bool = False  # circuit-breaker per-request exact dispatch
    solo_retry: bool = False  # this IS the rid's one solo retry
    tid: Optional[str] = None  # trace timeline lane (traced runs only)
    t_enqueued: float = 0.0  # tracer clock at enqueue (traced runs only)


@dataclasses.dataclass
class _Flight:
    """One launched batch: device work in the queue, not yet awaited."""

    batch: Batch
    futures: Dict[int, SortFuture]
    failsink: bool
    decision: object  # planner PlanDecision (None when tier pinned)
    start_tier: str
    stats: TierStats  # isolated per batch; merged into the shared stats
    inflight: InFlightSegmentedSort
    attempt: int = 0  # failsink lineage generation
    degraded: bool = False
    solo_retry: bool = False
    t_wall: float = 0.0  # perf_counter at launch (straggler timing)
    tid: Optional[str] = None  # trace timeline lane (traced runs only)
    t_launched: float = 0.0  # tracer clock at launch end (traced runs only)


class Dispatcher:
    """Formed-batch queue + up to ``max_in_flight`` launched batches.

    Owns the batch-level dispatch pipeline (plan → pack → launch → await →
    resolve futures) and its telemetry; :class:`repro.service.SortService`
    is a thin facade that forms batches into :meth:`enqueue` and claims
    results through the futures. Two completion callbacks connect the
    layers: ``on_result(future, keys, order, tier, n_per_proc)`` delivers
    one finished request, ``on_failure(future, exc)`` one terminal failure.
    """

    def __init__(
        self,
        cfg,
        *,
        former: BatchFormer,
        executor: SortExecutor,
        planner: CapacityPlanner,
        stats: TierStats,
        on_result: Callable,
        on_failure: Callable,
        max_in_flight: int = 2,
        straggler_monitor=None,
    ) -> None:
        self.cfg = cfg
        self.former = former
        self.executor = executor
        self.planner = planner
        self.stats = stats
        self.on_result = on_result
        self.on_failure = on_failure
        self.max_in_flight = max(1, int(max_in_flight))
        self._queue: Deque[_Queued] = collections.deque()
        self._flights: Deque[_Flight] = collections.deque()
        # failure-hardening knobs (ServiceConfig; getattr so a bare config
        # object without them keeps the legacy immediate-retry behaviour)
        self.backoff_base_s = float(getattr(cfg, "failsink_backoff_s", 0.0))
        self.backoff_max_s = float(
            getattr(cfg, "failsink_backoff_max_s", 1.0)
        )
        self.retry_budget = int(getattr(cfg, "fault_retry_budget", 8))
        self.breaker_threshold = int(getattr(cfg, "breaker_threshold", 4))
        self.breaker_cooldown_s = float(
            getattr(cfg, "breaker_cooldown_s", 30.0)
        )
        # circuit breaker: consecutive failures / open instant per bucket
        self._breaker_fails: Dict[int, int] = {}
        self._breaker_open_at: Dict[int, float] = {}
        # straggler wiring: flight wall times feed the EWMA monitor; slow
        # flights count in svc.straggler_flights (train/elastic's monitor
        # finally has a production call site)
        if straggler_monitor is None:
            from repro.train.elastic import StragglerMonitor

            straggler_monitor = StragglerMonitor()
        self.stragglers = straggler_monitor
        # chaos injection plan (repro.chaos.FaultPlan; hash-excluded on the
        # config like obs — None in production)
        self._chaos = resolve_chaos(getattr(cfg, "chaos", None))
        # telemetry — counters live in the process-wide metrics registry
        # under this dispatcher's instance label; the legacy attribute names
        # (launches, in_flight_peak, bucket_counts, ...) are read-only
        # property views over the same counters
        self.label = obs.next_instance("svc")
        reg = obs.metrics()
        self._launches = reg.counter("dispatch.launches", svc=self.label)
        self._overlapped = reg.counter(
            "dispatch.overlapped_launches", svc=self.label
        )
        self._in_flight_peak = reg.gauge("dispatch.in_flight_peak", svc=self.label)
        self._batches = reg.counter("dispatch.batches", svc=self.label)
        self._keys_sorted = reg.counter("dispatch.keys_sorted", svc=self.label)
        self._failsink_splits = reg.counter(
            "dispatch.failsink_splits", svc=self.label
        )
        self._failsink_solo_retries = reg.counter(
            "dispatch.failsink_solo_retries", svc=self.label
        )
        self._failsink_errors = reg.counter(
            "dispatch.failsink_errors", svc=self.label
        )
        self._failsink_resolved = reg.counter(
            "dispatch.failsink_resolved", svc=self.label
        )
        self._recovered_batches = reg.counter(
            "dispatch.recovered_batches", svc=self.label
        )
        self._straggler_flights = reg.counter(
            "svc.straggler_flights", svc=self.label
        )
        self._breaker_opened = reg.counter(
            "dispatch.breaker_opened", svc=self.label
        )
        self._breaker_degraded = reg.counter(
            "dispatch.breaker_degraded_batches", svc=self.label
        )
        self._budget_exceeded = reg.counter(
            "dispatch.retry_budget_exceeded", svc=self.label
        )
        self._cancelled = reg.counter("dispatch.cancelled_rids", svc=self.label)
        self._timeouts = reg.counter(
            "dispatch.deadline_timeouts", svc=self.label
        )
        # queue→form→launch→flight timeline (ServiceConfig.obs; off by
        # default — every tracer touch below is guarded)
        self._tracer = obs.resolve_tracer(getattr(cfg, "obs", None))
        # per-key-space standing views: repeat submits against the same
        # logical stream fold into the stream's SortedView instead of
        # resorting its whole history (see fold_stream)
        self._stream_views: Dict[object, SortedView] = {}
        self._stream_offsets: Dict[object, int] = {}

    # ----------------------------------------------- legacy telemetry views
    @property
    def launches(self) -> int:
        return self._launches.value

    @property
    def overlapped_launches(self) -> int:
        """Launches performed while another batch's device work flew."""
        return self._overlapped.value

    @property
    def in_flight_peak(self) -> int:
        return self._in_flight_peak.value

    @property
    def batches_dispatched(self) -> int:
        return self._batches.value

    @property
    def keys_sorted(self) -> int:
        return self._keys_sorted.value

    @property
    def bucket_counts(self) -> Dict[int, int]:
        """n_per_proc -> completed batches (view over the registry)."""
        return {
            int(lbl["bucket"]): c.value
            for lbl, c in obs.metrics().collect(
                "dispatch.batches_by_bucket", svc=self.label
            )
        }

    @property
    def start_tiers(self) -> Dict[str, int]:
        """starting tier -> completed batches (view over the registry)."""
        return {
            str(lbl["tier"]): c.value
            for lbl, c in obs.metrics().collect(
                "dispatch.start_tier", svc=self.label
            )
        }

    @property
    def failsink_splits(self) -> int:
        """Batch bisections after a failure."""
        return self._failsink_splits.value

    @property
    def failsink_solo_retries(self) -> int:
        """Solo re-dispatches of a failed rid."""
        return self._failsink_solo_retries.value

    @property
    def failsink_errors(self) -> int:
        """Rids terminally failed past failsink."""
        return self._failsink_errors.value

    @property
    def failsink_resolved(self) -> int:
        """Rids completing on a failsink re-dispatch."""
        return self._failsink_resolved.value

    @property
    def recovered_batches(self) -> int:
        """Batches that completed on a failsink re-dispatch."""
        return self._recovered_batches.value

    @property
    def straggler_flights(self) -> int:
        """Flights the EWMA straggler monitor marked slow."""
        return self._straggler_flights.value

    @property
    def breaker_opened(self) -> int:
        """Circuit-breaker open events (bucket degraded to per-request)."""
        return self._breaker_opened.value

    @property
    def cancelled_rids(self) -> int:
        """Requests unpicked from a formed batch before launch."""
        return self._cancelled.value

    @property
    def deadline_timeouts(self) -> int:
        """Formed-but-unlaunched requests expired past their deadline."""
        return self._timeouts.value

    # ------------------------------------------------------------- queue
    @property
    def idle(self) -> bool:
        return not self._queue and not self._flights

    @property
    def in_flight(self) -> int:
        return len(self._flights)

    def _breaker_is_open(self, bucket: int) -> bool:
        """Open-circuit check with time-based half-open: past the cooldown
        the bucket readmits fused batches (a clean completion then resets
        the failure streak; another failure re-opens)."""
        t = self._breaker_open_at.get(bucket)
        if t is None:
            return False
        if time.perf_counter() - t >= self.breaker_cooldown_s:
            del self._breaker_open_at[bucket]
            self._breaker_fails[bucket] = 0
            return False
        return True

    def _make_queued(
        self,
        batch: Batch,
        futures: Dict[int, SortFuture],
        *,
        failsink: bool = False,
        attempt: int = 0,
        not_before: float = 0.0,
        degraded: bool = False,
        solo_retry: bool = False,
    ) -> _Queued:
        tr = self._tracer
        return _Queued(
            batch=batch,
            futures=futures,
            failsink=failsink,
            attempt=attempt,
            not_before=not_before,
            degraded=degraded,
            solo_retry=solo_retry,
            tid=tr.next_tid("batch") if tr is not None else None,
            t_enqueued=tr.now() if tr is not None else 0.0,
        )

    def enqueue(
        self,
        batch: Batch,
        futures: Dict[int, SortFuture],
        *,
        failsink: bool = False,
        front: bool = False,
    ) -> None:
        if (
            not failsink
            and len(batch.rids) > 1
            and self._breaker_is_open(batch.n_per_proc)
        ):
            # degraded mode: the bucket's fused launches keep failing, so
            # stop fusing — every request dispatches solo at the exact
            # capacity (the never-fails tier) until the breaker cools down
            self._breaker_degraded.inc()
            if self._tracer is not None:
                self._tracer.point(
                    "breaker_degrade",
                    cat="dispatch",
                    tid="main",
                    bucket=batch.n_per_proc,
                    n_rids=len(batch.rids),
                )
            for rid, arr in zip(batch.rids, batch.arrays):
                for solo in self.former.form([(rid, arr)]):
                    self._queue.append(
                        self._make_queued(
                            solo, {rid: futures[rid]}, degraded=True
                        )
                    )
            return
        item = self._make_queued(batch, futures, failsink=failsink)
        if front:
            self._queue.appendleft(item)
        else:
            self._queue.append(item)

    def unpick(self, rid: int) -> bool:
        """Remove one rid from a *queued* (not launched) batch.

        The batch re-forms without it — remaining rids keep their place in
        the queue (their pow2 bucket may shrink). Returns False when the
        rid is not in the queue (pending at the service, launched, done).
        """
        for idx, item in enumerate(self._queue):
            if rid not in item.futures:
                continue
            del self._queue[idx]
            rest = [
                (r, a)
                for r, a in zip(item.batch.rids, item.batch.arrays)
                if r != rid
            ]
            repl = [
                dataclasses.replace(
                    item,
                    batch=b,
                    futures={r: item.futures[r] for r in b.rids},
                )
                for b in self.former.form(rest)
            ]
            for b in reversed(repl):
                self._queue.insert(idx, b)
            return True
        return False

    def cancel_rid(self, rid: int) -> bool:
        """Cancellation entry: :meth:`unpick` plus the cancelled counter."""
        if self.unpick(rid):
            self._cancelled.inc()
            return True
        return False

    def expire_deadlines(self, now: Optional[float] = None) -> int:
        """Fail formed-but-unlaunched requests whose deadline passed.

        Each victim is unpicked from its queued batch (the batch re-forms)
        and its future resolves with a :class:`SortTimeoutError` naming
        the rid. Launched requests are never expired — their device work
        is already paid for, and completing is strictly better.
        """
        now = time.perf_counter() if now is None else now
        victims = [
            fut
            for q in self._queue
            for fut in q.futures.values()
            if fut.deadline_at is not None
            and now >= fut.deadline_at
            and not fut.done()
        ]
        n = 0
        for fut in victims:
            if not self.unpick(fut.rid):
                continue
            self._timeouts.inc()
            self.on_failure(
                fut,
                SortTimeoutError(
                    f"request rid={fut.rid} expired un-launched "
                    f"(deadline passed before its batch got a slot)",
                    rids=(fut.rid,),
                ),
            )
            n += 1
        return n

    # ---------------------------------------------------------- dispatch
    def _resolve_batch(self, batch: Batch, degraded: bool = False):
        """(packed, sort overrides, decision) for one formed batch."""
        if degraded:
            # circuit-breaker fallback: per-request exact sort — no planner
            # (nothing fused to learn from), no sub-exact rung to fault
            packed = pack_segments(
                batch.arrays,
                self.cfg.p,
                n_per_proc=batch.n_per_proc,
                min_n_per_proc=self.cfg.min_n_per_proc,
            )
            return packed, {"pair_capacity": "exact"}, None
        if self.cfg.pair_capacity != "auto":  # explicit pin: PR 3 behaviour
            packed = pack_segments(
                batch.arrays,
                self.cfg.p,
                n_per_proc=batch.n_per_proc,
                min_n_per_proc=self.cfg.min_n_per_proc,
            )
            return packed, {"pair_capacity": self.cfg.pair_capacity}, None
        decision = self.planner.plan(
            batch.arrays,
            self.cfg.p,
            n_per_proc=batch.n_per_proc,
            min_n_per_proc=self.cfg.min_n_per_proc,
        )
        packed = pack_segments(
            batch.arrays,
            self.cfg.p,
            n_per_proc=batch.n_per_proc,
            min_n_per_proc=self.cfg.min_n_per_proc,
            layout=decision.layout,
        )
        if decision.route == "delta" and len(batch.arrays) == 1:
            # near-sorted solo batch: no packing — the delta launch splits
            # the stream on host and routes only the out-of-place Δ through
            # the h-relation (repro.delta). pump() branches on packed=None.
            return None, {"route": "delta"}, decision
        overrides = {"pair_capacity": decision.pair_capacity}
        if decision.route == "radix":
            # count-then-distribute: the launch driver host-reads the exact
            # counts and runs ONE rung — radix batches report retries == 0
            # by construction
            overrides["route"] = "radix"
        elif decision.pair_capacity == "planned":
            overrides["pair_cap_override"] = decision.pair_cap_override
            overrides["omega"] = decision.omega
        return packed, overrides, decision

    def _next_launchable(self, now: float) -> Optional[int]:
        """Queue index of the first launch-eligible batch, scanning *past*
        backing-off failsink retries — innocents never starve behind them."""
        for idx, item in enumerate(self._queue):
            if item.not_before <= now:
                return idx
        return None

    def pump(self) -> None:
        """Launch queued batches into free in-flight slots (non-blocking).

        The host-side plan/pack/launch of a later batch runs while earlier
        flights' collectives execute on the device — this loop is the
        overlap the async restructure exists for. Backoff-gated failsink
        retries are skipped (not waited on) until their ``not_before``
        instant passes.
        """
        tr = self._tracer
        while self._queue and len(self._flights) < self.max_in_flight:
            idx = self._next_launchable(time.perf_counter())
            if idx is None:
                return  # everything queued is backing off
            item = self._queue[idx]
            del self._queue[idx]
            if tr is not None:
                tr.add_span(
                    "queue",
                    item.t_enqueued,
                    cat="dispatch",
                    tid=item.tid,
                    n_rids=len(item.batch.rids),
                    failsink=item.failsink,
                )
            t_form = tr.now() if tr is not None else 0.0
            try:
                if self._chaos is not None:
                    # injected launch faults (poison rids / transient
                    # errors) raise ChaosError here — recovered by the
                    # same failsink path as organic launch failures
                    self._chaos.check_launch(
                        self._chaos.next_batch(), item.batch.rids
                    )
                packed, overrides, decision = self._resolve_batch(
                    item.batch, degraded=item.degraded
                )
                if tr is not None:
                    if packed is not None:
                        tr.add_span(
                            "form",
                            t_form,
                            cat="dispatch",
                            tid=item.tid,
                            n_per_proc=packed.n_per_proc,
                            layout=packed.layout,
                            n_keys=packed.n_keys,
                        )
                    # the fused sort traces onto the same Tracer (its own
                    # sortN lane; the launch span below links the two)
                    overrides["obs"] = self.cfg.obs
                if self._chaos is not None and packed is not None:
                    # capacity-fault injection rides the sort config the
                    # same hash-excluded way as obs (core.api strips it
                    # before any executor key)
                    overrides["chaos"] = self._chaos
                batch_stats = TierStats()  # isolates this batch's outcome
                t_launch = tr.now() if tr is not None else 0.0
                if packed is None:  # route="delta": near-sorted solo batch
                    inflight = near_sorted_sort_launch(
                        item.batch.arrays[0],
                        self.cfg.p,
                        min_n_per_proc=self.cfg.min_n_per_proc,
                        executor=self.executor,
                        stats=batch_stats,
                        obs_handle=overrides.get("obs"),
                    )
                else:
                    inflight = segmented_sort_launch(
                        packed,
                        algorithm=self.cfg.algorithm,
                        local_sort=self.cfg.local_sort,
                        merge=self.cfg.merge,
                        seed=self.cfg.seed,
                        stats=batch_stats,
                        executor=self.executor,
                        **overrides,
                    )
            except Exception as exc:  # launch-time failure: same failsink
                self._handle_failure(item, exc)
                continue
            start_tier = (
                overrides["route"]
                if overrides.get("route") in ("radix", "delta")
                else overrides["pair_capacity"]
            )
            if tr is not None:
                tr.add_span(
                    "launch",
                    t_launch,
                    cat="dispatch",
                    tid=item.tid,
                    start_tier=start_tier,
                    sort_tid=getattr(
                        getattr(inflight, "flight", None), "trace_tid", None
                    ),
                )
            self._launches.inc()
            if len(self._flights) >= 1:
                self._overlapped.inc()
            self._flights.append(
                _Flight(
                    batch=item.batch,
                    futures=item.futures,
                    failsink=item.failsink,
                    decision=decision,
                    start_tier=start_tier,
                    stats=batch_stats,
                    inflight=inflight,
                    attempt=item.attempt,
                    degraded=item.degraded,
                    solo_retry=item.solo_retry,
                    t_wall=time.perf_counter(),
                    tid=item.tid,
                    t_launched=tr.now() if tr is not None else 0.0,
                )
            )
            self._in_flight_peak.set_max(len(self._flights))

    def step(self) -> bool:
        """Complete the oldest in-flight batch (blocking), refill the slots.

        Returns False when there was nothing to do. Completion order is
        launch order — FIFO, like the synchronous flush — so shared-stats
        accumulation and planner feedback see batches in the same order as
        before the async restructure. When everything queued is backing
        off and nothing flies, the step honours the earliest ``not_before``
        (sleeps up to it) instead of spinning — ``drain``/``drive`` make
        progress through backoff windows.
        """
        self.pump()
        if not self._flights and self._queue:
            delay = min(q.not_before for q in self._queue) - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            self.pump()
        if not self._flights:
            return False
        flight = self._flights.popleft()
        if self._chaos is not None:
            # injected straggler: host-side delay before the flight sync —
            # the flight wall below inflates, feeding the EWMA monitor
            delay = self._chaos.straggle_delay(self._chaos.next_flight())
            if delay > 0:
                if self._tracer is not None:
                    self._tracer.point(
                        "chaos_straggle",
                        cat="chaos",
                        tid=flight.tid or "main",
                        delay_s=delay,
                    )
                time.sleep(delay)
        try:
            seg = flight.inflight.wait()
        except Exception as exc:
            self._handle_failure(flight, exc)
            self.pump()
            return True
        wall = time.perf_counter() - flight.t_wall
        if self.stragglers.is_slow(wall):
            self._straggler_flights.inc()
        self.stragglers.record(wall)
        if self._tracer is not None:
            self._tracer.add_span(
                "flight",
                flight.t_launched,
                cat="dispatch",
                tid=flight.tid,
                start_tier=flight.start_tier,
                tier=seg.tier,
                n_rids=len(flight.batch.rids),
                retries=flight.stats.retries,
            )
        self._complete(flight, seg)
        self.pump()
        return True

    def drain(self) -> None:
        """Run the pipeline dry: every queued batch launched and awaited."""
        while self.step():
            pass

    def drive(self, fut: SortFuture) -> None:
        """Advance the pipeline until ``fut`` resolves (or the queue dries)."""
        while not fut.done() and not self.idle:
            self.step()

    def run_pending(self, *, max_steps: int = 0) -> bool:
        """Driver pump for a thread/event loop: advance without a caller.

        Expires overdue deadlines, launches backoff-due and queued batches
        into free slots (non-blocking), then completes up to ``max_steps``
        flights (each completion blocks on that flight's device work — a
        driver thread passes 1, a latency-sensitive event loop 0 and lets
        claimants block instead). Returns whether work remains.
        """
        self.expire_deadlines()
        self.pump()
        for _ in range(max(0, int(max_steps))):
            if not self._flights:
                break
            self.step()
        return not self.idle

    # -------------------------------------------------------- completion
    def _complete(self, flight: _Flight, seg) -> None:
        self.stats.merge_from(flight.stats)
        if flight.decision is not None:
            # planner feedback as a completion callback: did the starting
            # tier overflow? (Persistence stays deferred to the service's
            # flush boundary — save_if_dirty there.)
            self.planner.record(flight.decision, faulted=flight.stats.retries > 0)
        obs.metrics().counter(
            "dispatch.start_tier", svc=self.label, tier=flight.start_tier
        ).inc()
        self._batches.inc()
        self._keys_sorted.inc(flight.batch.total_keys)
        obs.metrics().counter(
            "dispatch.batches_by_bucket",
            svc=self.label,
            bucket=flight.batch.n_per_proc,
        ).inc()
        # clean completion closes the bucket's breaker failure streak
        self._breaker_fails[flight.batch.n_per_proc] = 0
        if flight.failsink:
            self._failsink_resolved.inc(len(flight.batch.rids))
            self._recovered_batches.inc()
        for rid, keys, order in zip(flight.batch.rids, seg.keys, seg.order):
            fut = flight.futures[rid]
            fut.failsink = fut.failsink or flight.failsink
            self.on_result(fut, keys, order, seg.tier, seg.n_per_proc)

    def _backoff_for(self, attempt: int) -> float:
        """Exponential backoff for failsink generation ``attempt`` (the
        requeued batches' generation, i.e. parent attempt + 1)."""
        if self.backoff_base_s <= 0:
            return 0.0
        return min(
            self.backoff_max_s,
            self.backoff_base_s * (2.0 ** max(0, attempt - 1)),
        )

    def _handle_failure(self, item, exc: Exception) -> None:
        """Failsink: bisect a failed batch instead of failing everyone.

        Halves are re-formed through the batch former (their pow2 bucket
        shrinks with the batch) and re-enqueued at the queue *head* with
        the lineage's exponential backoff gate, so the isolation converges
        before new traffic is admitted but never blocks it (the pump scans
        past backing-off entries). Every rid gets exactly one solo retry
        (``solo_retry`` marks the retry dispatch); a failed solo retry is
        terminal — its future carries a :class:`SortServiceError` naming
        the rid, chained to the backend error. A lineage past
        ``fault_retry_budget`` generations stops bisecting and explodes to
        per-rid solo dispatches. Consecutive failures per bucket feed the
        circuit breaker.
        """
        rids, arrays = item.batch.rids, item.batch.arrays
        tr = self._tracer
        if tr is not None and isinstance(exc, ChaosError):
            tr.point(
                "chaos_launch_fault",
                cat="chaos",
                tid=getattr(item, "tid", None) or "main",
                rids=list(rids),
                error=str(exc),
            )
        # circuit breaker: consecutive failures in this pow2 bucket
        bucket = item.batch.n_per_proc
        fails = self._breaker_fails.get(bucket, 0) + 1
        self._breaker_fails[bucket] = fails
        if (
            self.breaker_threshold > 0
            and fails >= self.breaker_threshold
            and bucket not in self._breaker_open_at
        ):
            self._breaker_open_at[bucket] = time.perf_counter()
            self._breaker_opened.inc()
            if tr is not None:
                tr.point(
                    "breaker_open",
                    cat="dispatch",
                    tid="main",
                    bucket=bucket,
                    fails=fails,
                )
        solo_retry = False
        if len(rids) == 1 and getattr(item, "solo_retry", False):
            # the rid's one solo retry also failed: terminal. (Every rid
            # gets exactly one solo retry before this — whether it arrived
            # solo as fresh traffic or was isolated by bisection — so a
            # one-shot transient fault landing on the isolation dispatch
            # can never kill an innocent.)
            rid = rids[0]
            fut = item.futures[rid]
            fut.failsink = True
            err = SortServiceError(
                f"request rid={rid} failed solo after failsink isolation: "
                f"{exc!r}",
                rids=(rid,),
            )
            err.__cause__ = exc
            self._failsink_errors.inc()
            self.on_failure(fut, err)
            return
        if len(rids) == 1:
            self._failsink_solo_retries.inc()
            solo_retry = True
            halves = [list(zip(rids, arrays))]
        elif item.attempt >= self.retry_budget:
            # retry budget exhausted: skip the remaining bisection levels
            # and isolate every rid at once — bounded work, innocents still
            # complete (solo dispatches take the exact/allgather path)
            self._budget_exceeded.inc()
            halves = [[(r, a)] for r, a in zip(rids, arrays)]
        else:
            self._failsink_splits.inc()
            mid = len(rids) // 2
            halves = [
                list(zip(rids[:mid], arrays[:mid])),
                list(zip(rids[mid:], arrays[mid:])),
            ]
        attempt = item.attempt + 1
        not_before = time.perf_counter() + self._backoff_for(attempt)
        requeue: List[_Queued] = []
        for half in halves:
            for batch in self.former.form(half):
                requeue.append(
                    self._make_queued(
                        batch,
                        {r: item.futures[r] for r in batch.rids},
                        failsink=True,
                        attempt=attempt,
                        not_before=not_before,
                        solo_retry=solo_retry,
                    )
                )
        self._queue.extendleft(reversed(requeue))  # keep half order at head

    # ----------------------------------------------------- stream folding
    def fold_stream(self, stream, keys) -> Tuple[np.ndarray, np.ndarray, str, int]:
        """Fold one submit's keys into ``stream``'s standing sorted view.

        The first submit against a stream installs its view (a resort —
        there is nothing to rank against); every later submit folds: the
        Δ batch runs the h-relation at a Δ-sized rung and rank-merges in
        (``repro.delta.SortedView``). The view carries one payload — the
        arrival index across the whole stream — so the returned ``order``
        is the stable argsort of the *concatenated stream history*, exactly
        what a cold sort of everything submitted so far would produce.
        Returns ``(keys, order, tier, n_per_proc)`` for the full view.
        """
        v = self._stream_views.get(stream)
        if v is None:
            v = self._stream_views[stream] = SortedView(
                p=self.cfg.p,
                min_n_per_proc=self.cfg.min_n_per_proc,
                executor=self.executor,
                stats=self.stats,
                obs_handle=getattr(self.cfg, "obs", None),
                chaos_handle=getattr(self.cfg, "chaos", None),
            )
        base = self._stream_offsets.get(stream, 0)
        arr = np.asarray(keys, np.int32).reshape(-1)
        pos = np.arange(base, base + arr.size, dtype=np.int64)
        v.fold(arr, (pos,))
        self._stream_offsets[stream] = base + arr.size
        return (
            v.keys.copy(),
            v.payloads[0].copy(),
            v.last_tier or "delta",
            v.last_n_per_proc,
        )

    def telemetry(self) -> Dict[str, int]:
        return {
            "max_in_flight": self.max_in_flight,
            "in_flight_peak": self.in_flight_peak,
            "overlapped_launches": self.overlapped_launches,
            "failsink_splits": self.failsink_splits,
            "failsink_solo_retries": self.failsink_solo_retries,
            "failsink_resolved": self.failsink_resolved,
            "failsink_errors": self.failsink_errors,
            "recovered_batches": self.recovered_batches,
            "straggler_flights": self.straggler_flights,
            "breaker_opened": self.breaker_opened,
            "breaker_degraded_batches": self._breaker_degraded.value,
            "retry_budget_exceeded": self._budget_exceeded.value,
            "cancelled_rids": self.cancelled_rids,
            "deadline_timeouts": self.deadline_timeouts,
            "stream_views": len(self._stream_views),
        }
