"""Async dispatch queue: futures, in-flight batches, failsink isolation.

The service's original ``flush`` was a barrier: every submitter stalled
while one batch's collectives ran, and the host sat idle between batches —
exactly the regularity the BSP model promises, wasted at the service layer.
This module restructures dispatch around three pieces:

* :class:`SortFuture` — ``submit()``'s return value. Created unresolved;
  ``result()`` drives the dispatcher until the request completes (or
  re-raises its failure). A future outlives the service's bounded
  unclaimed-result store: the result is cached on the future at resolution,
  so an evicted store entry is still claimable by the caller that holds the
  future.

* :class:`Dispatcher` — a queue of formed batches plus up to
  ``max_in_flight`` *launched* ones. Launching a batch is host work
  (fingerprint → plan → pack) ending in :func:`segmented_sort_launch`,
  which dispatches the sort's first capacity rung to the device queue and
  returns without blocking — so while batch k's collectives execute, the
  dispatcher is already planning/packing/launching batch k+1 (JAX async
  dispatch provides the overlap; ``overlapped_launches`` counts launches
  performed with another batch's device work outstanding). Completion
  (:meth:`Dispatcher.step`) blocks on the *oldest* flight only, resolves
  its futures, and feeds the planner its fault outcome — planner feedback
  is a completion callback, not a dispatch-path stall.

* **Failsink** per-request fault isolation. A batch that raises (backend
  error, ladder exhaustion) used to crash-requeue every rid and re-raise at
  the submitter; one poison request could re-fail the whole queue forever.
  Now the dispatcher *bisects*: the failed batch is split in two and both
  halves re-formed and re-enqueued at the queue head, recursively, until
  the poison request stands alone. A solo request gets one failsink retry;
  if it still fails, its future resolves with a :class:`SortServiceError`
  naming the rid — every innocent rid in the original batch completes
  normally, and every future resolves (no rid is ever lost or silently
  requeued). Requests that rode a failsink re-dispatch carry a
  ``failsink=True`` telemetry mark on their result and future.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.core import TierStats
from repro.core.api import SortExecutor
from repro.core.segmented import (
    InFlightSegmentedSort,
    pack_segments,
    segmented_sort_launch,
)
from repro.delta import SortedView, near_sorted_sort_launch
from repro.planner import CapacityPlanner

from .batch import Batch, BatchFormer


class SortServiceError(RuntimeError):
    """A service request (or batch) failed; ``rids`` names the victims."""

    def __init__(self, message: str, rids: Tuple[int, ...] = ()) -> None:
        super().__init__(message)
        self.rids = tuple(rids)


class SortFuture:
    """Handle for one submitted request; resolves to a ``RequestResult``.

    ``submit()`` returns immediately with one of these — nothing has been
    dispatched yet. ``result()`` blocks (driving the service's dispatcher)
    until the request's batch completes, then returns the request's
    :class:`repro.service.RequestResult`; if the request failed past the
    failsink ladder, it re-raises the stored :class:`SortServiceError`.
    ``done()`` never blocks. The resolved result is cached here, so the
    future stays claimable even after the service's bounded unclaimed-result
    store evicted it.
    """

    def __init__(self, rid: int, drive: Callable[["SortFuture"], None]) -> None:
        self.rid = rid
        self.submitted_at = time.perf_counter()
        self.failsink = False  # rode a failsink re-dispatch
        self._drive = drive
        self._done = False
        self._result = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done

    def result(self):
        if not self._done:
            self._drive(self)
        if self._exc is not None:
            raise self._exc
        return self._result

    def exception(self) -> Optional[BaseException]:
        if not self._done:
            self._drive(self)
        return self._exc

    # internal — called by the dispatcher exactly once
    def _resolve(self, result) -> None:
        self._result = result
        self._done = True

    def _fail(self, exc: BaseException) -> None:
        self._exc = exc
        self._done = True

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        state = "done" if self._done else "pending"
        return f"SortFuture(rid={self.rid}, {state})"


@dataclasses.dataclass
class _Queued:
    """One formed batch waiting for a launch slot."""

    batch: Batch
    futures: Dict[int, SortFuture]
    failsink: bool  # this batch is a failsink re-dispatch
    tid: Optional[str] = None  # trace timeline lane (traced runs only)
    t_enqueued: float = 0.0  # tracer clock at enqueue (traced runs only)


@dataclasses.dataclass
class _Flight:
    """One launched batch: device work in the queue, not yet awaited."""

    batch: Batch
    futures: Dict[int, SortFuture]
    failsink: bool
    decision: object  # planner PlanDecision (None when tier pinned)
    start_tier: str
    stats: TierStats  # isolated per batch; merged into the shared stats
    inflight: InFlightSegmentedSort
    tid: Optional[str] = None  # trace timeline lane (traced runs only)
    t_launched: float = 0.0  # tracer clock at launch end (traced runs only)


class Dispatcher:
    """Formed-batch queue + up to ``max_in_flight`` launched batches.

    Owns the batch-level dispatch pipeline (plan → pack → launch → await →
    resolve futures) and its telemetry; :class:`repro.service.SortService`
    is a thin facade that forms batches into :meth:`enqueue` and claims
    results through the futures. Two completion callbacks connect the
    layers: ``on_result(future, keys, order, tier, n_per_proc)`` delivers
    one finished request, ``on_failure(future, exc)`` one terminal failure.
    """

    def __init__(
        self,
        cfg,
        *,
        former: BatchFormer,
        executor: SortExecutor,
        planner: CapacityPlanner,
        stats: TierStats,
        on_result: Callable,
        on_failure: Callable,
        max_in_flight: int = 2,
    ) -> None:
        self.cfg = cfg
        self.former = former
        self.executor = executor
        self.planner = planner
        self.stats = stats
        self.on_result = on_result
        self.on_failure = on_failure
        self.max_in_flight = max(1, int(max_in_flight))
        self._queue: Deque[_Queued] = collections.deque()
        self._flights: Deque[_Flight] = collections.deque()
        # telemetry — counters live in the process-wide metrics registry
        # under this dispatcher's instance label; the legacy attribute names
        # (launches, in_flight_peak, bucket_counts, ...) are read-only
        # property views over the same counters
        self.label = obs.next_instance("svc")
        reg = obs.metrics()
        self._launches = reg.counter("dispatch.launches", svc=self.label)
        self._overlapped = reg.counter(
            "dispatch.overlapped_launches", svc=self.label
        )
        self._in_flight_peak = reg.gauge("dispatch.in_flight_peak", svc=self.label)
        self._batches = reg.counter("dispatch.batches", svc=self.label)
        self._keys_sorted = reg.counter("dispatch.keys_sorted", svc=self.label)
        self._failsink_splits = reg.counter(
            "dispatch.failsink_splits", svc=self.label
        )
        self._failsink_solo_retries = reg.counter(
            "dispatch.failsink_solo_retries", svc=self.label
        )
        self._failsink_errors = reg.counter(
            "dispatch.failsink_errors", svc=self.label
        )
        self._failsink_resolved = reg.counter(
            "dispatch.failsink_resolved", svc=self.label
        )
        # queue→form→launch→flight timeline (ServiceConfig.obs; off by
        # default — every tracer touch below is guarded)
        self._tracer = obs.resolve_tracer(getattr(cfg, "obs", None))
        # per-key-space standing views: repeat submits against the same
        # logical stream fold into the stream's SortedView instead of
        # resorting its whole history (see fold_stream)
        self._stream_views: Dict[object, SortedView] = {}
        self._stream_offsets: Dict[object, int] = {}

    # ----------------------------------------------- legacy telemetry views
    @property
    def launches(self) -> int:
        return self._launches.value

    @property
    def overlapped_launches(self) -> int:
        """Launches performed while another batch's device work flew."""
        return self._overlapped.value

    @property
    def in_flight_peak(self) -> int:
        return self._in_flight_peak.value

    @property
    def batches_dispatched(self) -> int:
        return self._batches.value

    @property
    def keys_sorted(self) -> int:
        return self._keys_sorted.value

    @property
    def bucket_counts(self) -> Dict[int, int]:
        """n_per_proc -> completed batches (view over the registry)."""
        return {
            int(lbl["bucket"]): c.value
            for lbl, c in obs.metrics().collect(
                "dispatch.batches_by_bucket", svc=self.label
            )
        }

    @property
    def start_tiers(self) -> Dict[str, int]:
        """starting tier -> completed batches (view over the registry)."""
        return {
            str(lbl["tier"]): c.value
            for lbl, c in obs.metrics().collect(
                "dispatch.start_tier", svc=self.label
            )
        }

    @property
    def failsink_splits(self) -> int:
        """Batch bisections after a failure."""
        return self._failsink_splits.value

    @property
    def failsink_solo_retries(self) -> int:
        """Solo re-dispatches of a failed rid."""
        return self._failsink_solo_retries.value

    @property
    def failsink_errors(self) -> int:
        """Rids terminally failed past failsink."""
        return self._failsink_errors.value

    @property
    def failsink_resolved(self) -> int:
        """Rids completing on a failsink re-dispatch."""
        return self._failsink_resolved.value

    # ------------------------------------------------------------- queue
    @property
    def idle(self) -> bool:
        return not self._queue and not self._flights

    @property
    def in_flight(self) -> int:
        return len(self._flights)

    def enqueue(
        self,
        batch: Batch,
        futures: Dict[int, SortFuture],
        *,
        failsink: bool = False,
        front: bool = False,
    ) -> None:
        tr = self._tracer
        item = _Queued(
            batch=batch,
            futures=futures,
            failsink=failsink,
            tid=tr.next_tid("batch") if tr is not None else None,
            t_enqueued=tr.now() if tr is not None else 0.0,
        )
        if front:
            self._queue.appendleft(item)
        else:
            self._queue.append(item)

    # ---------------------------------------------------------- dispatch
    def _resolve_batch(self, batch: Batch):
        """(packed, sort overrides, decision) for one formed batch."""
        if self.cfg.pair_capacity != "auto":  # explicit pin: PR 3 behaviour
            packed = pack_segments(
                batch.arrays,
                self.cfg.p,
                n_per_proc=batch.n_per_proc,
                min_n_per_proc=self.cfg.min_n_per_proc,
            )
            return packed, {"pair_capacity": self.cfg.pair_capacity}, None
        decision = self.planner.plan(
            batch.arrays,
            self.cfg.p,
            n_per_proc=batch.n_per_proc,
            min_n_per_proc=self.cfg.min_n_per_proc,
        )
        packed = pack_segments(
            batch.arrays,
            self.cfg.p,
            n_per_proc=batch.n_per_proc,
            min_n_per_proc=self.cfg.min_n_per_proc,
            layout=decision.layout,
        )
        if decision.route == "delta" and len(batch.arrays) == 1:
            # near-sorted solo batch: no packing — the delta launch splits
            # the stream on host and routes only the out-of-place Δ through
            # the h-relation (repro.delta). pump() branches on packed=None.
            return None, {"route": "delta"}, decision
        overrides = {"pair_capacity": decision.pair_capacity}
        if decision.route == "radix":
            # count-then-distribute: the launch driver host-reads the exact
            # counts and runs ONE rung — radix batches report retries == 0
            # by construction
            overrides["route"] = "radix"
        elif decision.pair_capacity == "planned":
            overrides["pair_cap_override"] = decision.pair_cap_override
            overrides["omega"] = decision.omega
        return packed, overrides, decision

    def pump(self) -> None:
        """Launch queued batches into free in-flight slots (non-blocking).

        The host-side plan/pack/launch of a later batch runs while earlier
        flights' collectives execute on the device — this loop is the
        overlap the async restructure exists for.
        """
        tr = self._tracer
        while self._queue and len(self._flights) < self.max_in_flight:
            item = self._queue.popleft()
            if tr is not None:
                tr.add_span(
                    "queue",
                    item.t_enqueued,
                    cat="dispatch",
                    tid=item.tid,
                    n_rids=len(item.batch.rids),
                    failsink=item.failsink,
                )
            t_form = tr.now() if tr is not None else 0.0
            try:
                packed, overrides, decision = self._resolve_batch(item.batch)
                if tr is not None:
                    if packed is not None:
                        tr.add_span(
                            "form",
                            t_form,
                            cat="dispatch",
                            tid=item.tid,
                            n_per_proc=packed.n_per_proc,
                            layout=packed.layout,
                            n_keys=packed.n_keys,
                        )
                    # the fused sort traces onto the same Tracer (its own
                    # sortN lane; the launch span below links the two)
                    overrides["obs"] = self.cfg.obs
                batch_stats = TierStats()  # isolates this batch's outcome
                t_launch = tr.now() if tr is not None else 0.0
                if packed is None:  # route="delta": near-sorted solo batch
                    inflight = near_sorted_sort_launch(
                        item.batch.arrays[0],
                        self.cfg.p,
                        min_n_per_proc=self.cfg.min_n_per_proc,
                        executor=self.executor,
                        stats=batch_stats,
                        obs_handle=overrides.get("obs"),
                    )
                else:
                    inflight = segmented_sort_launch(
                        packed,
                        algorithm=self.cfg.algorithm,
                        local_sort=self.cfg.local_sort,
                        merge=self.cfg.merge,
                        seed=self.cfg.seed,
                        stats=batch_stats,
                        executor=self.executor,
                        **overrides,
                    )
            except Exception as exc:  # launch-time failure: same failsink
                self._handle_failure(item, exc)
                continue
            start_tier = (
                overrides["route"]
                if overrides.get("route") in ("radix", "delta")
                else overrides["pair_capacity"]
            )
            if tr is not None:
                tr.add_span(
                    "launch",
                    t_launch,
                    cat="dispatch",
                    tid=item.tid,
                    start_tier=start_tier,
                    sort_tid=getattr(
                        getattr(inflight, "flight", None), "trace_tid", None
                    ),
                )
            self._launches.inc()
            if len(self._flights) >= 1:
                self._overlapped.inc()
            self._flights.append(
                _Flight(
                    batch=item.batch,
                    futures=item.futures,
                    failsink=item.failsink,
                    decision=decision,
                    start_tier=start_tier,
                    stats=batch_stats,
                    inflight=inflight,
                    tid=item.tid,
                    t_launched=tr.now() if tr is not None else 0.0,
                )
            )
            self._in_flight_peak.set_max(len(self._flights))

    def step(self) -> bool:
        """Complete the oldest in-flight batch (blocking), refill the slots.

        Returns False when there was nothing to do. Completion order is
        launch order — FIFO, like the synchronous flush — so shared-stats
        accumulation and planner feedback see batches in the same order as
        before the async restructure.
        """
        self.pump()
        if not self._flights:
            return False
        flight = self._flights.popleft()
        try:
            seg = flight.inflight.wait()
        except Exception as exc:
            self._handle_failure(flight, exc)
            self.pump()
            return True
        if self._tracer is not None:
            self._tracer.add_span(
                "flight",
                flight.t_launched,
                cat="dispatch",
                tid=flight.tid,
                start_tier=flight.start_tier,
                tier=seg.tier,
                n_rids=len(flight.batch.rids),
                retries=flight.stats.retries,
            )
        self._complete(flight, seg)
        self.pump()
        return True

    def drain(self) -> None:
        """Run the pipeline dry: every queued batch launched and awaited."""
        while self.step():
            pass

    def drive(self, fut: SortFuture) -> None:
        """Advance the pipeline until ``fut`` resolves (or the queue dries)."""
        while not fut.done() and not self.idle:
            self.step()

    # -------------------------------------------------------- completion
    def _complete(self, flight: _Flight, seg) -> None:
        self.stats.merge_from(flight.stats)
        if flight.decision is not None:
            # planner feedback as a completion callback: did the starting
            # tier overflow? (Persistence stays deferred to the service's
            # flush boundary — save_if_dirty there.)
            self.planner.record(flight.decision, faulted=flight.stats.retries > 0)
        obs.metrics().counter(
            "dispatch.start_tier", svc=self.label, tier=flight.start_tier
        ).inc()
        self._batches.inc()
        self._keys_sorted.inc(flight.batch.total_keys)
        obs.metrics().counter(
            "dispatch.batches_by_bucket",
            svc=self.label,
            bucket=flight.batch.n_per_proc,
        ).inc()
        if flight.failsink:
            self._failsink_resolved.inc(len(flight.batch.rids))
        for rid, keys, order in zip(flight.batch.rids, seg.keys, seg.order):
            fut = flight.futures[rid]
            fut.failsink = fut.failsink or flight.failsink
            self.on_result(fut, keys, order, seg.tier, seg.n_per_proc)

    def _handle_failure(self, item, exc: Exception) -> None:
        """Failsink: bisect a failed batch instead of failing everyone.

        Halves are re-formed through the batch former (their pow2 bucket
        shrinks with the batch) and re-enqueued at the queue *head*, so the
        isolation converges before new traffic is admitted. A solo request
        gets exactly one failsink retry (``failsink`` marks it); a marked
        solo failure is terminal — its future carries a
        :class:`SortServiceError` naming the rid, chained to the backend
        error.
        """
        rids, arrays = item.batch.rids, item.batch.arrays
        if len(rids) == 1 and item.failsink:
            rid = rids[0]
            fut = item.futures[rid]
            fut.failsink = True
            err = SortServiceError(
                f"request rid={rid} failed solo after failsink isolation: "
                f"{exc!r}",
                rids=(rid,),
            )
            err.__cause__ = exc
            self._failsink_errors.inc()
            self.on_failure(fut, err)
            return
        if len(rids) == 1:
            self._failsink_solo_retries.inc()
            halves = [list(zip(rids, arrays))]
        else:
            self._failsink_splits.inc()
            mid = len(rids) // 2
            halves = [
                list(zip(rids[:mid], arrays[:mid])),
                list(zip(rids[mid:], arrays[mid:])),
            ]
        tr = self._tracer
        requeue: List[_Queued] = []
        for half in halves:
            for batch in self.former.form(half):
                requeue.append(
                    _Queued(
                        batch=batch,
                        futures={r: item.futures[r] for r in batch.rids},
                        failsink=True,
                        tid=tr.next_tid("batch") if tr is not None else None,
                        t_enqueued=tr.now() if tr is not None else 0.0,
                    )
                )
        self._queue.extendleft(reversed(requeue))  # keep half order at head

    # ----------------------------------------------------- stream folding
    def fold_stream(self, stream, keys) -> Tuple[np.ndarray, np.ndarray, str, int]:
        """Fold one submit's keys into ``stream``'s standing sorted view.

        The first submit against a stream installs its view (a resort —
        there is nothing to rank against); every later submit folds: the
        Δ batch runs the h-relation at a Δ-sized rung and rank-merges in
        (``repro.delta.SortedView``). The view carries one payload — the
        arrival index across the whole stream — so the returned ``order``
        is the stable argsort of the *concatenated stream history*, exactly
        what a cold sort of everything submitted so far would produce.
        Returns ``(keys, order, tier, n_per_proc)`` for the full view.
        """
        v = self._stream_views.get(stream)
        if v is None:
            v = self._stream_views[stream] = SortedView(
                p=self.cfg.p,
                min_n_per_proc=self.cfg.min_n_per_proc,
                executor=self.executor,
                stats=self.stats,
                obs_handle=getattr(self.cfg, "obs", None),
            )
        base = self._stream_offsets.get(stream, 0)
        arr = np.asarray(keys, np.int32).reshape(-1)
        pos = np.arange(base, base + arr.size, dtype=np.int64)
        v.fold(arr, (pos,))
        self._stream_offsets[stream] = base + arr.size
        return (
            v.keys.copy(),
            v.payloads[0].copy(),
            v.last_tier or "delta",
            v.last_n_per_proc,
        )

    def telemetry(self) -> Dict[str, int]:
        return {
            "max_in_flight": self.max_in_flight,
            "in_flight_peak": self.in_flight_peak,
            "overlapped_launches": self.overlapped_launches,
            "failsink_splits": self.failsink_splits,
            "failsink_solo_retries": self.failsink_solo_retries,
            "failsink_resolved": self.failsink_resolved,
            "failsink_errors": self.failsink_errors,
            "stream_views": len(self._stream_views),
        }
