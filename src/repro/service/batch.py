"""Batch former: pack ragged sort requests into pow2-bucketed batch shapes.

Every distinct ``(p, n_per_proc)`` packed shape is a distinct XLA compile of
the segmented sort's whole capacity-tier ladder, and serving traffic has
unbounded length variety — so the former quantizes each batch to the next
power-of-two per-proc run length (``n_per_proc ∈ {min, 2·min, 4·min, …}``).
Arbitrary request mixes then share O(log n) compiled programs, and two
batches whose totals round to the same bucket reuse ONE compiled segmented
sort via the :class:`repro.core.SortExecutor` registry (trace-count asserted
in tests/test_service.py).

Batches are formed greedily in submit order (FIFO fairness — a request is
never reordered past another by the former; the *sort* handles ordering) and
closed when adding the next request would exceed ``max_batch_keys``. A
single request larger than the cap still gets its own (larger-bucket) batch:
the service must sort anything it admitted.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.segmented import _pow2_n_per_proc


@dataclasses.dataclass
class Batch:
    """One dispatch unit: requests packed together into a single fused sort."""

    rids: List[int]  # request ids, submit order
    arrays: List[np.ndarray]  # the requests' key arrays, aligned with rids
    n_per_proc: int  # pow2 bucket the packed batch compiles under
    total_keys: int


class BatchFormer:
    def __init__(
        self, p: int, max_batch_keys: int = 1 << 16, min_n_per_proc: int = 8
    ) -> None:
        self.p = p
        self.max_batch_keys = max_batch_keys
        self.min_n_per_proc = min_n_per_proc

    def bucket(self, total_keys: int) -> int:
        """The pow2 n_per_proc bucket a batch of ``total_keys`` packs into."""
        return _pow2_n_per_proc(total_keys, self.p, self.min_n_per_proc)

    def form(self, requests: Sequence[Tuple[int, np.ndarray]]) -> List[Batch]:
        """Greedy FIFO batching of ``(rid, keys)`` pairs under the key cap."""
        batches: List[Batch] = []
        rids: List[int] = []
        arrays: List[np.ndarray] = []
        total = 0

        def close() -> None:
            nonlocal rids, arrays, total
            if rids:
                batches.append(
                    Batch(
                        rids=rids,
                        arrays=arrays,
                        n_per_proc=self.bucket(total),
                        total_keys=total,
                    )
                )
            rids, arrays, total = [], [], 0

        for rid, keys in requests:
            n = int(np.asarray(keys).shape[0])
            if total and total + n > self.max_batch_keys:
                close()
            rids.append(rid)
            arrays.append(keys)
            total += n
        close()
        return batches

    def form_ready(
        self,
        requests: Sequence[Tuple[int, np.ndarray]],
        *,
        min_keys: Optional[int] = None,
    ) -> Tuple[List[Batch], List[Tuple[int, np.ndarray]]]:
        """Admission-aware forming for open-loop traffic: dispatch batches
        that are full enough, hold the partial tail for more arrivals.

        ``form`` packs everything it is given — fine at a flush barrier,
        but an arrival loop that pumps on every poll would dispatch a
        stream of tiny underfilled batches and waste the fused sort's
        fan-in. ``form_ready`` returns ``(batches, held)``: every batch
        except an underfilled *tail* (total below ``min_keys``, default
        half the key cap) dispatches; the tail's ``(rid, keys)`` pairs are
        handed back, still in submit order, to rejoin the queue. Only the
        tail can be held — earlier batches were closed by the cap, and
        holding a middle batch would reorder admissions past FIFO. A
        deadline trigger (or plain ``form``) flushes the held tail
        eventually, so no request is starved.
        """
        if min_keys is None:
            min_keys = self.max_batch_keys // 2
        batches = self.form(requests)
        held: List[Tuple[int, np.ndarray]] = []
        if batches and batches[-1].total_keys < min_keys:
            tail = batches.pop()
            held = list(zip(tail.rids, tail.arrays))
        return batches, held
