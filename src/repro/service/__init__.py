"""Sort service — fuse many concurrent ragged sort requests into one
tagged, segmented BSP sort (the layer between the sort library and its
serving/data consumers).

    SortService    — async request queue + facade over the dispatcher:
                     submit() returns a SortFuture immediately; flush()
                     (caller-driven, or auto via max_pending /
                     flush_after_s triggers) packs the queue into
                     pow2-bucketed batches and drains the dispatch
                     pipeline; blocking sort_one/sort_many/take_result
                     wrap futures byte-identically to the synchronous
                     path. Starting tiers are resolved per batch by the
                     capacity planner (repro.planner), with fault
                     outcomes fed back on completion callbacks.
    Dispatcher     — the async dispatch queue: up to max_in_flight
                     launched batches (host plan/pack of batch k+1
                     overlaps batch k's device collectives) plus failsink
                     per-request fault isolation (bisect a failed batch
                     until the poison request stands alone).
    SortFuture     — submit()'s handle: done()/result()/exception()/
                     cancel(), the failsink telemetry mark, and a cached
                     result that survives unclaimed-store eviction.
    SortServiceError — terminal per-request failure, naming its rids.
    SortTimeoutError — a submit(deadline_s=...) request expired before its
                     batch launched (subclass of SortServiceError).
    SortCancelledError — a request was cancel()ed before launch (subclass
                     of SortServiceError).
    BatchFormer    — the pow2 length-bucketed batch former (bounds XLA
                     recompiles to one program per bucket shape).
    ServiceConfig  — p / algorithm / capacity-tier / bucketing / auto-flush
                     / pipeline-depth / store-bound / planner knobs.
    RequestResult  — per-request output record (+ failsink mark).
"""
from .batch import Batch, BatchFormer
from .dispatch import (
    Dispatcher,
    SortCancelledError,
    SortFuture,
    SortServiceError,
    SortTimeoutError,
)
from .service import RequestResult, ServiceConfig, SortService

__all__ = [
    "Batch",
    "BatchFormer",
    "Dispatcher",
    "RequestResult",
    "ServiceConfig",
    "SortCancelledError",
    "SortFuture",
    "SortService",
    "SortServiceError",
    "SortTimeoutError",
]
