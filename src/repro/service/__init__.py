"""Sort service — fuse many concurrent ragged sort requests into one
tagged, segmented BSP sort (the layer between the sort library and its
serving/data consumers).

    SortService    — request queue + dispatch: submit ragged int32 arrays,
                     flush() (caller-driven, or auto via max_pending /
                     flush_after_s triggers) packs them into pow2-bucketed
                     batches, runs one overflow-safe segmented sort per
                     batch, and returns every request sorted with its
                     stable argsort, latency and capacity-tier telemetry.
                     Starting tiers are resolved per batch by the capacity
                     planner (repro.planner): fingerprint → segment-aware
                     whp bound over the striped layout → traffic-learned
                     rung, with fault outcomes fed back.
    BatchFormer    — the pow2 length-bucketed batch former (bounds XLA
                     recompiles to one program per bucket shape).
    ServiceConfig  — p / algorithm / capacity-tier / bucketing / auto-flush
                     / planner-persistence knobs.
    RequestResult  — per-request output record.
"""
from .batch import Batch, BatchFormer
from .service import RequestResult, ServiceConfig, SortService

__all__ = [
    "Batch",
    "BatchFormer",
    "RequestResult",
    "ServiceConfig",
    "SortService",
]
