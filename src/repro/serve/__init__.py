from repro.serve.engine import ServeConfig, ServeEngine  # noqa: F401
from repro.serve.sampling import sample, top_k_logits  # noqa: F401
