"""Batched serving engine: continuous-batching decode loop over a KV cache.

Single-host reference implementation of the serving driver the dry-run
lowers. Two decode modes:

* :meth:`ServeEngine.generate` — one fixed batch in lockstep (a retired slot
  keeps decoding into a scratch token — the static-shape analogue of slot
  reuse).
* :meth:`ServeEngine.serve` — continuous batching over a request queue: a
  fixed number of decode *slots*, each slot an independent (cache, position)
  lane stacked into one vmapped decode step. When a sequence retires (EOS or
  its token budget), the slot is refilled from the admission queue between
  steps: the new request is prefilled alone and its cache written into the
  retired slot's lane, while the other slots keep decoding uninterrupted.

Admission ordering goes through the sort *service*
(:meth:`ServeEngine.admission_order` → :class:`repro.service.SortService`):
queued requests are globally sorted by prompt length so each admitted batch
is length-homogeneous (minimal padding waste — and consecutive refills share
prefill compile cache, since prefill is jitted per distinct prompt length).
The service fuses the admission sort with any concurrently queued requests
as one segment of a tagged segmented BSP sort, and its processor count is
derived from the engine's mesh (the largest power of two ≤ the device
count; 8 simulated lanes without a mesh) so sharded serving buckets for the
actual topology. Production traffic is adversarial by nature — a burst of
identical lengths aims every key at one bucket — so every batch runs the
capacity-escalation ladder and the engine's per-tier retry counters
(``capacity_stats``, shared with the service) stay observable.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ArchConfig
from repro.core import TierStats
from repro.data import length_bucketed_order
from repro.delta import SortedView
from repro.models import Model
from repro.serve.sampling import sample
from repro.service import ServiceConfig, SortService, SortServiceError


def _mesh_sort_p(mesh) -> int:
    """Simulated-processor count for the engine's sort service.

    The largest power of two ≤ the mesh's device count (``SortConfig``
    requires pow2 ``p``); 8 lanes for the single-host no-mesh reference —
    a hardcoded 8 on a sharded engine would silently bucket admission for
    the wrong processor count.
    """
    if mesh is None:
        return 8
    nd = int(np.asarray(mesh.devices).size)
    return max(1, 1 << (nd.bit_length() - 1))


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 1.0
    top_k: int = 40
    top_p: float = 0.0
    eos_id: int = 2


class ServeEngine:
    def __init__(self, model: Model, params, serve_cfg: ServeConfig, mesh=None):
        self.model = model
        self.params = params
        self.scfg = serve_cfg
        self.mesh = mesh
        self.capacity_stats = TierStats()  # sort-driver retry counters
        self.sort_p = _mesh_sort_p(mesh)
        # admission sorts go through the service: fused segmented dispatch,
        # pow2-bucketed compiles, escalation stats shared with the engine
        self.sort_service = SortService(
            ServiceConfig(p=self.sort_p), stats=self.capacity_stats
        )
        # engine counters live in the process-wide metrics registry; the
        # attribute names stay as read-only property views
        self.label = obs.next_instance("engine")
        reg = obs.metrics()
        self._refills = reg.counter("serve.refills", engine=self.label)
        self._admission_prefetches = reg.counter(
            "serve.admission_prefetches", engine=self.label
        )
        self._admission_fallbacks = reg.counter(
            "serve.admission_fallbacks", engine=self.label
        )
        self._decode = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t, None)
        )
        # slot-stacked decode: each slot is an independent batch-1 lane with
        # its own cache['pos'], so slots at different depths step together.
        self._decode_slots = jax.jit(
            jax.vmap(
                lambda p, c, t: model.decode_step(p, c, t, None),
                in_axes=(None, 0, 0),
            )
        )
        self._prefill_jits: Dict[tuple, object] = {}  # per (prompt_len, cache_len)

    @property
    def refills(self) -> int:
        """Queue admissions into retired decode slots."""
        return self._refills.value

    @property
    def admission_prefetches(self) -> int:
        """Prefills launched ahead of retirement."""
        return self._admission_prefetches.value

    @property
    def admission_fallbacks(self) -> int:
        """Admissions served by bucketed order after a sort-service failure."""
        return self._admission_fallbacks.value

    def admission_order(self, prompt_lengths, p: Optional[int] = None) -> np.ndarray:
        """Globally length-sorted admission order for a request queue.

        One balanced BSP sort (fused through the engine's sort service)
        replaces the scheduler's gather-sort-scatter; the overflow-safe
        per-batch escalation guarantees no request id is ever dropped even
        when every prompt has the same length (the all-keys-to-one-bucket
        adversarial case). Retry activity accumulates in
        ``self.capacity_stats``. ``p`` defaults to the mesh-derived
        ``self.sort_p``; an explicit override takes a one-off service so
        the engine's compiled-bucket cache keying stays consistent.
        """
        lengths = np.asarray(prompt_lengths, np.int32)
        if p is not None and p != self.sort_p:
            return length_bucketed_order(lengths, p=p, stats=self.capacity_stats)
        try:
            return self.sort_service.sort_one(lengths).order
        except SortServiceError:
            # graceful degradation: a terminally failing sort service must
            # not take admission down with it — the host-side bucketed
            # order is weaker (bucket-stable, not globally key-stable) but
            # every request is still admitted exactly once
            self._admission_fallbacks.inc()
            return length_bucketed_order(
                lengths, p=self.sort_p, stats=self.capacity_stats
            )

    def generate(self, prompts: jnp.ndarray, extras: Optional[Dict] = None, rng=None):
        """prompts: (B, S_prompt) int32 -> (B, max_new_tokens) int32."""
        rng = rng if rng is not None else jax.random.key(0)
        b, s = prompts.shape
        cache_len = s + self.scfg.max_new_tokens
        batch = {"tokens": prompts, **(extras or {})}
        cache, logits = self.model.prefill(self.params, batch, cache_len=cache_len)
        outs: List[jnp.ndarray] = []
        done = jnp.zeros((b,), bool)
        tok = self._sample(logits, rng)
        for i in range(self.scfg.max_new_tokens):
            outs.append(jnp.where(done, self.scfg.eos_id, tok))
            done = done | (tok == self.scfg.eos_id)
            logits, cache = self._decode(self.params, cache, tok)
            rng = jax.random.fold_in(rng, i)
            tok = self._sample(logits, rng)
        return jnp.stack(outs, axis=1)

    # ------------------------------------------------ continuous batching
    def _prefill_one(self, tokens: np.ndarray, cache_len: int):
        """Prefill one request (batch 1). Jitted per distinct
        (prompt length, cache length) pair — which the length-sorted
        admission order keeps to a minimum."""
        key = (int(tokens.shape[0]), int(cache_len))
        fn = self._prefill_jits.get(key)
        if fn is None:
            fn = self._prefill_jits[key] = jax.jit(
                lambda p, t: self.model.prefill(
                    p, {"tokens": t}, cache_len=cache_len
                )
            )
        return fn(self.params, jnp.asarray(tokens, jnp.int32)[None])

    def _sample(self, logits, rng):
        return sample(
            logits,
            rng,
            temperature=self.scfg.temperature,
            top_k=self.scfg.top_k,
            top_p=self.scfg.top_p,
        )

    def serve(
        self,
        prompts: Sequence[np.ndarray],
        slots: int = 4,
        max_new: Optional[Sequence[int]] = None,
        rng=None,
        arrivals=None,
    ) -> List[np.ndarray]:
        """Serve a request queue with continuous batching.

        ``prompts``: per-request 1-D int32 token arrays (ragged lengths).
        ``max_new``: optional per-request new-token budgets (default: the
        engine's ``max_new_tokens``). Returns the generated tokens per
        request, in the original request order, truncated at EOS.

        Requests are admitted in globally length-sorted order: ONE cold BSP
        sort through the service seeds a **standing length-sorted view**
        (``repro.delta.SortedView`` — the delta subsystem's first in-tree
        consumer), and every admission thereafter is a ``pop_min`` tombstone
        off the view. A slot that retires — EOS or budget — is refilled from
        the view *between* decode steps, so short sequences never hold the
        batch hostage (``self.refills`` counts these mid-flight admissions).

        ``arrivals``: optional ``step -> iterable of prompt arrays`` hook,
        polled once per decode step while the loop runs. Arriving requests
        **fold** into the standing view (Δ-sized device work, counted in
        the ``delta.folds`` metric) instead of resorting the queue, inherit
        the default token budget, and must fit the initial ``cache_len``
        (prompt + budget); their outputs append after the initial requests'
        in arrival order. Arrivals after the loop drains are not served.

        Admission is *double-buffered*: the next queued request's prefill
        is launched ahead of any retirement (JAX async dispatch — the
        launch returns while the device still owns the work), so it
        overlaps the running decode steps instead of stalling them; when a
        slot retires, the already-launched prefill is consumed and the one
        after it launches immediately (``self.admission_prefetches``).
        """
        rng = rng if rng is not None else jax.random.key(0)
        reqs = [np.asarray(p, np.int32) for p in prompts]
        if not reqs:
            return []
        budgets = (
            [int(m) for m in max_new]
            if max_new is not None
            else [self.scfg.max_new_tokens] * len(reqs)
        )
        assert len(budgets) == len(reqs)
        outs: List[List[int]] = [[] for _ in reqs]
        # one fixed cache length for every lane: the longest prompt plus the
        # largest budget (decode positions are per-slot, masked by pos),
        # rounded up to a power of two so varying traffic compiles O(log n)
        # decode/prefill programs instead of one per distinct workload mix
        # (same rationale as the n_p bucketing in data/pipeline.py)
        cache_len = max(len(r) for r in reqs) + max(max(budgets), 1)
        cache_len = max(64, 1 << (cache_len - 1).bit_length())
        # the admission queue is a standing length-sorted SortedView keyed
        # by prompt length with the request id as payload: seeded by one
        # cold service sort (install is free — the sort already ordered
        # it), popped per refill, folded into by mid-loop arrivals
        lengths = np.asarray([len(r) for r in reqs], np.int32)
        order = np.asarray(self.admission_order(lengths), np.int32)
        view = SortedView(p=self.sort_p, stats=self.capacity_stats)
        view.install(lengths[order], (order,))
        self._admission_view = view

        def next_rid() -> Optional[int]:
            # zero-budget requests retire instantly with an empty stream —
            # they never occupy a slot or emit a prefill-sampled token
            while view.n:
                _, (rid,) = view.pop_min()
                rid = int(rid)
                if budgets[rid] > 0:
                    return rid
            return None

        def admit_arrivals(new_prompts) -> None:
            # mid-loop arrivals fold into the standing view: Δ-sized device
            # work against the queue's sorted remainder, never a resort
            rids: List[int] = []
            for pr in new_prompts:
                pr = np.asarray(pr, np.int32)
                if len(pr) + self.scfg.max_new_tokens > cache_len:
                    raise ValueError(
                        f"arriving prompt of {len(pr)} tokens (+ budget "
                        f"{self.scfg.max_new_tokens}) exceeds the serving "
                        f"cache_len {cache_len}"
                    )
                reqs.append(pr)
                budgets.append(self.scfg.max_new_tokens)
                outs.append([])
                rids.append(len(reqs) - 1)
            if rids:
                view.fold(
                    np.asarray([len(reqs[r]) for r in rids], np.int32),
                    (np.asarray(rids, np.int32),),
                )

        def admit(rid: int, k: jax.Array):
            cache, logits = self._prefill_one(reqs[rid], cache_len)
            return cache, self._sample(logits, k)[0]

        # double-buffered admission: one (rid, cache, first-token) prefill
        # kept launched-but-unconsumed ahead of the decode loop. The jitted
        # prefill call returns as soon as it is enqueued on the device, so
        # the prefill compute itself overlaps the decode steps that run
        # before the next slot retires. The rng stream for a prefetched
        # admission folds on the rid (the retiring slot is unknowable at
        # launch time); sampling-seed layout is not part of the engine's
        # contract (greedy decode is rng-independent).
        prefetched = None

        def prefetch_admission() -> None:
            nonlocal prefetched
            if prefetched is None:
                rid = next_rid()
                if rid is not None:
                    k = jax.random.fold_in(rng, 1000 + rid)
                    prefetched = (rid, *admit(rid, k))
                    self._admission_prefetches.inc()

        def take_admission():
            nonlocal prefetched
            if prefetched is None:
                prefetch_admission()  # cold path: nothing launched ahead
            out, prefetched = prefetched, None
            prefetch_admission()  # overlap the NEXT admission's prefill
            return out

        # initial fill: one prefill per slot, stacked into slot lanes
        caches, toks, slot_req = [], [], []
        while len(slot_req) < max(1, slots):
            rid = next_rid()
            if rid is None:
                break
            slot_req.append(rid)
            rng = jax.random.fold_in(rng, len(slot_req))
            cache, tok = admit(rid, rng)
            caches.append(cache)
            toks.append(tok)
        if not slot_req:  # every request had a zero budget
            return [np.asarray(t, np.int32) for t in outs]
        n_slots = len(slot_req)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
        tok = jnp.stack(toks)[:, None]  # (slots, 1) — batch-1 lanes
        prefetch_admission()  # first refill's prefill rides the decode loop

        step = 0
        while any(r is not None for r in slot_req):
            if arrivals is not None:
                new = arrivals(step)
                if new:
                    admit_arrivals(new)
                    prefetch_admission()
            # record the sampled token per lane; retire finished requests and
            # refill their slot from the queue. A freshly admitted request's
            # first token comes from its own prefill logits and is recorded
            # immediately (cascading, in case a 1-token budget or instant
            # EOS retires it before ever taking a decode step).
            tok_host = np.asarray(tok[:, 0])
            for s in range(n_slots):
                if slot_req[s] is None:
                    # a lane idled when the queue drained; arrivals may have
                    # refilled the view since — re-admit into the dead lane
                    adm = take_admission()
                    if adm is None:
                        continue
                    nxt, cache_s, tok_s = adm
                    slot_req[s] = nxt
                    self._refills.inc()
                    caches = jax.tree.map(
                        lambda full, one: full.at[s].set(one), caches, cache_s
                    )
                    tok = tok.at[s, 0].set(tok_s)
                    tval = int(tok_s)
                else:
                    tval = int(tok_host[s])
                while slot_req[s] is not None:
                    rid = slot_req[s]
                    outs[rid].append(tval)
                    done = (
                        tval == self.scfg.eos_id
                        or len(outs[rid]) >= budgets[rid]
                    )
                    if not done:
                        break
                    slot_req[s] = None
                    adm = take_admission()  # already launched, overlapped
                    if adm is None:
                        break
                    nxt, cache_s, tok_s = adm
                    slot_req[s] = nxt
                    self._refills.inc()
                    caches = jax.tree.map(
                        lambda full, one: full.at[s].set(one), caches, cache_s
                    )
                    tok = tok.at[s, 0].set(tok_s)
                    tval = int(tok_s)
            if not any(r is not None for r in slot_req):
                break
            # one vmapped decode step for every lane (retired-and-unrefilled
            # lanes keep decoding into scratch — their output is ignored)
            logits, caches = self._decode_slots(self.params, caches, tok)
            rng = jax.random.fold_in(rng, step)
            tok = self._sample(logits.reshape(n_slots, -1), rng)[:, None]
            step += 1

        def trim(t: List[int]) -> np.ndarray:
            if self.scfg.eos_id in t:
                t = t[: t.index(self.scfg.eos_id) + 1]
            return np.asarray(t, np.int32)

        return [trim(t) for t in outs]
