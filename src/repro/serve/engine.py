"""Batched serving engine: continuous-batching decode loop over a KV cache.

Single-host reference implementation of the serving driver the dry-run
lowers: ``prefill`` builds the cache for a batch of prompts, ``ServeEngine``
then steps all sequences in lockstep, sampling with serve/sampling.py and
retiring sequences on EOS (a retired slot keeps decoding into a scratch
token — the static-shape analogue of slot reuse; a production scheduler
refills retired slots from the admission queue between steps).

Admission ordering uses the BSP sort's overflow-safe driver
(:meth:`ServeEngine.admission_order`): queued requests are globally sorted
by prompt length so each admitted batch is length-homogeneous (minimal
padding waste). Production traffic is adversarial by nature — a burst of
identical lengths aims every key at one bucket — so the sort runs through
the capacity-escalation ladder and the engine keeps per-tier retry counters
(``capacity_stats``) for observability.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import TierStats
from repro.data import length_bucketed_order
from repro.models import Model
from repro.serve.sampling import sample


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 1.0
    top_k: int = 40
    top_p: float = 0.0
    eos_id: int = 2


class ServeEngine:
    def __init__(self, model: Model, params, serve_cfg: ServeConfig, mesh=None):
        self.model = model
        self.params = params
        self.scfg = serve_cfg
        self.mesh = mesh
        self.capacity_stats = TierStats()  # sort-driver retry counters
        self._decode = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t, None)
        )

    def admission_order(self, prompt_lengths, p: int = 8) -> np.ndarray:
        """Globally length-sorted admission order for a request queue.

        One balanced BSP sort over ``p`` simulated processors replaces the
        scheduler's gather-sort-scatter; the overflow-safe driver guarantees
        no request id is ever dropped even when every prompt has the same
        length (the all-keys-to-one-bucket adversarial case). Retry activity
        accumulates in ``self.capacity_stats``.
        """
        lengths = np.asarray(prompt_lengths, np.int32)
        return length_bucketed_order(lengths, p=p, stats=self.capacity_stats)

    def generate(self, prompts: jnp.ndarray, extras: Optional[Dict] = None, rng=None):
        """prompts: (B, S_prompt) int32 -> (B, max_new_tokens) int32."""
        rng = rng if rng is not None else jax.random.key(0)
        b, s = prompts.shape
        cache_len = s + self.scfg.max_new_tokens
        batch = {"tokens": prompts, **(extras or {})}
        cache, logits = self.model.prefill(self.params, batch, cache_len=cache_len)
        outs: List[jnp.ndarray] = []
        done = jnp.zeros((b,), bool)
        tok = sample(
            logits,
            rng,
            temperature=self.scfg.temperature,
            top_k=self.scfg.top_k,
            top_p=self.scfg.top_p,
        )
        for i in range(self.scfg.max_new_tokens):
            outs.append(jnp.where(done, self.scfg.eos_id, tok))
            done = done | (tok == self.scfg.eos_id)
            logits, cache = self._decode(self.params, cache, tok)
            rng = jax.random.fold_in(rng, i)
            tok = sample(
                logits,
                rng,
                temperature=self.scfg.temperature,
                top_k=self.scfg.top_k,
                top_p=self.scfg.top_p,
            )
        return jnp.stack(outs, axis=1)
