"""Token sampling for serving — top-k via the sorting machinery.

Distributed top-k over vocab-sharded logits follows the paper's
sample/splitter-select pattern: per-shard local top-k candidates (a bitonic
partial sort — the in-VMEM kernel on TPU), then one all-gather of k·p
candidates and a final k-selection — one balanced communication round of
o(V) words instead of gathering the full vocab row.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def top_k_logits(logits: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(values, indices) of the k largest per row. Uses jax top_k (which XLA
    lowers to a partial bitonic network — the same structure as our kernel);
    kernels/bitonic provides the explicit Pallas variant."""
    return lax.top_k(logits, k)


def sample(
    logits: jnp.ndarray,  # (B, V) fp32/bf16
    rng: jax.Array,
    *,
    temperature: float = 1.0,
    top_k: int = 0,
    top_p: float = 0.0,
) -> jnp.ndarray:
    lf = logits.astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(lf, axis=-1).astype(jnp.int32)
    lf = lf / temperature
    if top_k:
        vals, idx = top_k_logits(lf, top_k)
        if top_p:
            # nucleus within the top-k candidates (sorted descending already)
            probs = jax.nn.softmax(vals, axis=-1)
            cum = jnp.cumsum(probs, axis=-1)
            keep = cum - probs < top_p
            vals = jnp.where(keep, vals, -jnp.inf)
        choice = jax.random.categorical(rng, vals)
        return jnp.take_along_axis(idx, choice[:, None], axis=1)[:, 0].astype(jnp.int32)
    return jax.random.categorical(rng, lf, axis=-1).astype(jnp.int32)
