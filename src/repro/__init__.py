"""repro — BSP Sorting (Gerbessiotis & Siniolakis) as a first-class feature
of a multi-pod JAX training/serving framework. See README.md / DESIGN.md."""

__version__ = "1.0.0"
