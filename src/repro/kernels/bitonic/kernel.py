"""In-VMEM Batcher bitonic sorting network — Pallas TPU kernel.

The paper's Ph2 hot loop (85-90% of T3D runtime) is a scalar quicksort. The
TPU-native analogue is a *sorting network over full vector registers*: every
compare-exchange stage is a reshape + `jnp.where` on an (rows, width) VMEM
tile, so the VPU processes 8×128 lanes per cycle with zero data-dependent
control flow. Work is Θ(n lg² n) vs quicksort's Θ(n lg n) — the standard TPU
trade (DESIGN.md §7): the lg(n)/2 work inflation is paid for by lane
parallelism and the absence of branches.

Layout: width must be a power of two (callers pad with the dtype sentinel —
`ops.py` handles this) and ≥ 128 so the lane dimension stays MXU/VPU aligned.
The row dimension batches independent sorts (grid over row blocks).

The compare-exchange pairing `i ↔ i^j` is realized *without gathers* by
reshaping to (rows, width/2j, 2, j): partners sit in adjacent sublane groups,
and the per-group direction bit ((start & k) == 0) broadcasts along lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stage(x: jnp.ndarray, k: int, j: int) -> jnp.ndarray:
    """One compare-exchange substage (partner = index XOR j, region size k)."""
    r, w = x.shape
    g = w // (2 * j)
    x4 = x.reshape(r, g, 2, j)
    a, b = x4[:, :, 0, :], x4[:, :, 1, :]
    asc = (((jnp.arange(g) * 2 * j) & k) == 0)[None, :, None]
    swap = jnp.where(asc, a > b, a < b)
    na = jnp.where(swap, b, a)
    nb = jnp.where(swap, a, b)
    return jnp.stack([na, nb], axis=2).reshape(r, w)


def _stage_kv(keys, vals, k: int, j: int):
    r, w = keys.shape
    g = w // (2 * j)
    k4 = keys.reshape(r, g, 2, j)
    v4 = vals.reshape(r, g, 2, j)
    ka, kb = k4[:, :, 0, :], k4[:, :, 1, :]
    va, vb = v4[:, :, 0, :], v4[:, :, 1, :]
    asc = (((jnp.arange(g) * 2 * j) & k) == 0)[None, :, None]
    swap = jnp.where(asc, ka > kb, ka < kb)
    keys = jnp.stack([jnp.where(swap, kb, ka), jnp.where(swap, ka, kb)], 2).reshape(r, w)
    vals = jnp.stack([jnp.where(swap, vb, va), jnp.where(swap, va, vb)], 2).reshape(r, w)
    return keys, vals


def sort_network(x: jnp.ndarray) -> jnp.ndarray:
    """Full bitonic sort along the last axis (width = power of two)."""
    _, w = x.shape
    k = 2
    while k <= w:
        j = k // 2
        while j >= 1:
            x = _stage(x, k, j)
            j //= 2
        k *= 2
    return x


def merge_network(x: jnp.ndarray) -> jnp.ndarray:
    """Bitonic *merge* of a bitonic row (ascending run ++ descending run)."""
    _, w = x.shape
    j = w // 2
    while j >= 1:
        x = _stage(x, 2 * w, j)  # k > w ⇒ every region ascending
        j //= 2
    return x


def sort_network_kv(keys: jnp.ndarray, vals: jnp.ndarray):
    _, w = keys.shape
    k = 2
    while k <= w:
        j = k // 2
        while j >= 1:
            keys, vals = _stage_kv(keys, vals, k, j)
            j //= 2
        k *= 2
    return keys, vals


# ------------------------------------------------------------- pallas_call
def _sort_kernel(x_ref, o_ref):
    o_ref[...] = sort_network(x_ref[...])


def _sort_kv_kernel(k_ref, v_ref, ko_ref, vo_ref):
    ko, vo = sort_network_kv(k_ref[...], v_ref[...])
    ko_ref[...] = ko
    vo_ref[...] = vo


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def bitonic_sort_tiles(
    x: jnp.ndarray, *, block_rows: int = 8, interpret: bool = False
) -> jnp.ndarray:
    """Sort each row of (rows, width) independently; width a power of two.

    VMEM working set per grid step = 2 · block_rows · width · itemsize;
    the default (8, ≤16384) f32 tile is 1 MB — comfortably inside the
    ~16 MB/core v5e VMEM while leaving room for double buffering.
    """
    rows, width = x.shape
    assert width & (width - 1) == 0, "width must be a power of two"
    grid = (pl.cdiv(rows, block_rows),)
    return pl.pallas_call(
        _sort_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, width), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, width), x.dtype),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def bitonic_sort_kv_tiles(
    keys: jnp.ndarray,
    vals: jnp.ndarray,
    *,
    block_rows: int = 8,
    interpret: bool = False,
):
    rows, width = keys.shape
    assert width & (width - 1) == 0, "width must be a power of two"
    grid = (pl.cdiv(rows, block_rows),)
    spec = pl.BlockSpec((block_rows, width), lambda i: (i, 0))
    return pl.pallas_call(
        _sort_kv_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=(spec, spec),
        out_shape=(
            jax.ShapeDtypeStruct((rows, width), keys.dtype),
            jax.ShapeDtypeStruct((rows, width), vals.dtype),
        ),
        interpret=interpret,
    )(keys, vals)
