"""jit'd public wrappers for the bitonic Pallas kernel.

Handles padding to a power-of-two lane width (≥128), row batching, the
single-tile / multi-tile split (tiles sorted in-kernel, then merged with
rank merges), and CPU fallback to ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.types import sentinel_for

from . import kernel

#: widest single-tile sort: (8 rows, 16384 lanes) f32 = 1 MB VMEM blocks.
MAX_WIDTH = 16384
_SUPPORTED = (jnp.int32, jnp.uint32, jnp.float32, jnp.bfloat16)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pow2_at_least(n: int, floor: int = 128) -> int:
    w = floor
    while w < n:
        w *= 2
    return w


def supports(x: jnp.ndarray) -> bool:
    return x.ndim in (1, 2) and x.dtype in [jnp.dtype(d) for d in _SUPPORTED]


@jax.jit
def sort(x: jnp.ndarray) -> jnp.ndarray:
    """Sort along the last axis via the in-VMEM bitonic network.

    Widths ≤ MAX_WIDTH sort in one tile; larger rows are split into
    MAX_WIDTH tiles, kernel-sorted, and combined by a rank-merge tree.
    """
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    rows, n = x.shape
    sent = sentinel_for(x.dtype)
    if n <= MAX_WIDTH:
        w = _pow2_at_least(n)
        xp = jnp.pad(x, ((0, 0), (0, w - n)), constant_values=sent)
        out = kernel.bitonic_sort_tiles(xp, interpret=_interpret())[:, :n]
        return out[0] if squeeze else out

    # multi-tile: sort MAX_WIDTH tiles in-kernel, then merge pairs.
    w = _pow2_at_least(n, MAX_WIDTH)
    xp = jnp.pad(x, ((0, 0), (0, w - n)), constant_values=sent)
    t = w // MAX_WIDTH
    tiles = kernel.bitonic_sort_tiles(
        xp.reshape(rows * t, MAX_WIDTH), interpret=_interpret()
    ).reshape(rows, t, MAX_WIDTH)
    while tiles.shape[1] > 1:
        a, b = tiles[:, 0::2], tiles[:, 1::2]
        tiles = _rank_merge(a, b)
    out = tiles[:, 0, :n]
    return out[0] if squeeze else out


def _rank_merge(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Merge sorted runs pairwise: out position = own idx + rank in other."""
    *lead, m = a.shape
    ra = jax.vmap(jnp.searchsorted, (0, 0))(b.reshape(-1, m), a.reshape(-1, m))
    rb = jax.vmap(functools.partial(jnp.searchsorted, side="right"), (0, 0))(
        a.reshape(-1, m), b.reshape(-1, m)
    )
    i = jnp.arange(m)
    pos_a, pos_b = i + ra, i + rb
    flat = a.shape[0] * a.shape[1] if a.ndim == 3 else a.shape[0]
    out = jnp.zeros((flat, 2 * m), a.dtype)
    out = out.at[jnp.arange(flat)[:, None], pos_a].set(a.reshape(-1, m))
    out = out.at[jnp.arange(flat)[:, None], pos_b].set(b.reshape(-1, m))
    return out.reshape(*lead, 2 * m)


@jax.jit
def sort_kv(keys: jnp.ndarray, vals: jnp.ndarray):
    """Key-value sort along the last axis (single-tile widths only)."""
    squeeze = keys.ndim == 1
    if squeeze:
        keys, vals = keys[None, :], vals[None, :]
    rows, n = keys.shape
    if n > MAX_WIDTH:
        order = jnp.argsort(keys, axis=-1, stable=True)  # fallback
        out = jnp.take_along_axis(keys, order, -1), jnp.take_along_axis(vals, order, -1)
    else:
        w = _pow2_at_least(n)
        sent = sentinel_for(keys.dtype)
        kp = jnp.pad(keys, ((0, 0), (0, w - n)), constant_values=sent)
        vp = jnp.pad(vals, ((0, 0), (0, w - n)))
        ko, vo = kernel.bitonic_sort_kv_tiles(kp, vp, interpret=_interpret())
        out = ko[:, :n], vo[:, :n]
    return (out[0][0], out[1][0]) if squeeze else out


@jax.jit
def merge_bitonic(x: jnp.ndarray) -> jnp.ndarray:
    """Merge rows that are (ascending ++ descending) bitonic sequences."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    out = kernel.merge_network(x)  # pure jnp path; kernel variant in merge_path
    return out[0] if squeeze else out
