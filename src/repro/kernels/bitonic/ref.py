"""Pure-jnp oracle for the bitonic kernel."""
from __future__ import annotations

import jax.numpy as jnp


def sort(x: jnp.ndarray) -> jnp.ndarray:
    """Rowwise sort along the last axis."""
    return jnp.sort(x, axis=-1)


def sort_kv(keys: jnp.ndarray, vals: jnp.ndarray):
    """Rowwise key-value sort (ties may be permuted — bitonic is unstable,
    so oracles compare (key, value) pairs as multisets per row)."""
    order = jnp.argsort(keys, axis=-1, stable=True)
    return jnp.take_along_axis(keys, order, -1), jnp.take_along_axis(vals, order, -1)


def merge(x: jnp.ndarray) -> jnp.ndarray:
    """Merge of an (ascending ++ descending) bitonic row = full sort."""
    return jnp.sort(x, axis=-1)
