"""Merge of two sorted runs — Pallas TPU kernel (the paper's Ph6 hot loop).

Two sorted rows a, b of width W are merged by the *bitonic merge network*:
``concat(a, reverse(b))`` is a bitonic sequence, so lg(2W)+1 compare-exchange
substages produce the sorted 2W row. Each substage is one full-width
`jnp.where` on the VMEM tile — no gathers, no branches.

This replaces the GPU "merge path" diagonal-partition idea (which needs
per-thread binary searches — a scalar-unit pattern) with the TPU-idiomatic
network formulation: same O(W lg W) work per pair, all lane-parallel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitonic.kernel import _stage


def merge_rows(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Merge sorted rows a,b (R, W) -> sorted (R, 2W) via bitonic merge."""
    x = jnp.concatenate([a, b[:, ::-1]], axis=-1)  # bitonic rows
    _, w2 = x.shape
    j = w2 // 2
    while j >= 1:
        x = _stage(x, 2 * w2, j)  # k > width ⇒ ascending everywhere
        j //= 2
    return x


def _merge_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = merge_rows(a_ref[...], b_ref[...])


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def merge_sorted_tiles(
    a: jnp.ndarray, b: jnp.ndarray, *, block_rows: int = 8, interpret: bool = False
) -> jnp.ndarray:
    """Pairwise-merge rows of two (rows, width) sorted arrays.

    VMEM per grid step = 4 · block_rows · width · itemsize (two inputs, one
    double-width output); width must be a power of two ≥ 128.
    """
    rows, width = a.shape
    assert a.shape == b.shape
    assert width & (width - 1) == 0, "width must be a power of two"
    grid = (pl.cdiv(rows, block_rows),)
    in_spec = pl.BlockSpec((block_rows, width), lambda i: (i, 0))
    out_spec = pl.BlockSpec((block_rows, 2 * width), lambda i: (i, 0))
    return pl.pallas_call(
        _merge_kernel,
        grid=grid,
        in_specs=[in_spec, in_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((rows, 2 * width), a.dtype),
        interpret=interpret,
    )(a, b)
