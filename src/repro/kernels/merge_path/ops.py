"""jit'd public wrapper for the merge kernel (padding + CPU interpret)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import sentinel_for

from . import kernel

MAX_WIDTH = 8192


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pow2_at_least(n: int, floor: int = 128) -> int:
    w = floor
    while w < n:
        w *= 2
    return w


@jax.jit
def merge(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Merge sorted rows of a and b; returns sorted (rows, na+nb)."""
    squeeze = a.ndim == 1
    if squeeze:
        a, b = a[None, :], b[None, :]
    rows, na = a.shape
    _, nb = b.shape
    sent = sentinel_for(a.dtype)
    w = _pow2_at_least(max(na, nb))
    ap = jnp.pad(a, ((0, 0), (0, w - na)), constant_values=sent)
    bp = jnp.pad(b, ((0, 0), (0, w - nb)), constant_values=sent)
    out = kernel.merge_sorted_tiles(ap, bp, interpret=_interpret())[:, : na + nb]
    return out[0] if squeeze else out
