"""jit'd public wrappers for the merge kernel (padding + CPU interpret).

* :func:`merge` — whole-row merge; pads both rows to one power-of-two tile,
  so the full 2W row must fit a VMEM tile (width ≤ MAX_WIDTH).
* :func:`merge_partitioned` — the GPU "merge path" diagonal partition, TPU
  style: the output is cut into fixed TILE-wide spans, each span's (ia, ib)
  window start is solved from the key ranks on the host/XLA side (the
  scalar per-thread binary search the GPU scheme needs is exactly what the
  TPU hates), and the Pallas network kernel merges the bounded windows —
  VMEM per grid step stays O(TILE) for any row width. Used by the Ph6
  rank-merge tail for key-only pairs under ``merge_backend="pallas"``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import sentinel_for

from . import kernel

MAX_WIDTH = 8192
#: output span per merge-path grid step (power of two ≥ 128).
TILE = 1024


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pow2_at_least(n: int, floor: int = 128) -> int:
    w = floor
    while w < n:
        w *= 2
    return w


@jax.jit
def merge(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Merge sorted rows of a and b; returns sorted (rows, na+nb)."""
    squeeze = a.ndim == 1
    if squeeze:
        a, b = a[None, :], b[None, :]
    rows, na = a.shape
    _, nb = b.shape
    sent = sentinel_for(a.dtype)
    w = _pow2_at_least(max(na, nb))
    ap = jnp.pad(a, ((0, 0), (0, w - na)), constant_values=sent)
    bp = jnp.pad(b, ((0, 0), (0, w - nb)), constant_values=sent)
    out = kernel.merge_sorted_tiles(ap, bp, interpret=_interpret())[:, : na + nb]
    return out[0] if squeeze else out


@jax.jit
def merge_partitioned(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Merge sorted (rows, W) pairs via merge-path partition + network tiles.

    Value-identical to a stable rank merge of each row pair (same multiset
    in sorted order). The diagonal split for output span [d, d+T) follows
    from the a-first rank positions ``pos_a(i) = i + #{b_j < a_i}`` (strictly
    increasing in i): ``ia(d) = #{i : pos_a(i) < d}``; windows of T elements
    per side then provably cover the span, with out-of-range slots filled by
    the sentinel so they sort past every needed element.
    """
    rows, W = a.shape
    assert a.shape == b.shape
    T = min(TILE, _pow2_at_least(W))
    nt = -(-2 * W // T)
    sent = sentinel_for(a.dtype)
    pos_a = jnp.arange(W) + jax.vmap(jnp.searchsorted)(b, a)  # (rows, W)
    d = jnp.arange(nt) * T  # span starts
    ia = jax.vmap(lambda pa: jnp.searchsorted(pa, d, side="left"))(pos_a)
    ib = d[None, :] - ia  # (rows, nt); both ≥ 0 by construction
    t = jnp.arange(T)
    ga = ia[:, :, None] + t  # (rows, nt, T) window gather indices
    gb = ib[:, :, None] + t
    r = jnp.arange(rows)[:, None, None]
    aw = jnp.where(ga < W, a[r, jnp.clip(ga, 0, W - 1)], sent)
    bw = jnp.where(gb < W, b[r, jnp.clip(gb, 0, W - 1)], sent)
    spans = kernel.merge_sorted_tiles(
        aw.reshape(rows * nt, T), bw.reshape(rows * nt, T), interpret=_interpret()
    )[:, :T]  # first T of each window merge == output span [d, d+T)
    return spans.reshape(rows, nt * T)[:, : 2 * W]
