"""Pure-jnp oracle for the merge kernel."""
from __future__ import annotations

import jax.numpy as jnp


def merge(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Rowwise merge of two sorted arrays = sort of their concatenation."""
    return jnp.sort(jnp.concatenate([a, b], axis=-1), axis=-1)
