"""jit'd public wrapper for the splitter-rank kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import round_up, sentinel_for

from . import kernel

BLOCK = 2048


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@jax.jit
def splitter_ranks(x_sorted, split_keys, split_proc, split_idx, me):
    """Bucket boundaries (S,) of tagged splitters in a sorted (n,) run."""
    n = x_sorted.shape[0]
    block = min(BLOCK, round_up(n, 128))
    npad = round_up(n, block)
    sent = sentinel_for(x_sorted.dtype)
    xp = jnp.pad(x_sorted, (0, npad - n), constant_values=sent)
    ranks = kernel.splitter_ranks(
        xp,
        split_keys,
        split_proc.astype(jnp.int32),
        split_idx.astype(jnp.int32),
        jnp.asarray(me, jnp.int32),
        block=block,
        interpret=_interpret(),
    )
    # pad elements carry idx ≥ n; a real splitter can still tag idx ≥ n only
    # on its own (proc, idx) record, never here — but a padded x equal to a
    # splitter key with me<proc would count. Clamp to n for safety.
    return jnp.minimum(ranks, n)
