"""jit'd public wrappers for the splitter-rank kernel.

Two entry points share the one masked-count kernel:

* :func:`splitter_ranks` — tagged §5.1.1 bucket boundaries (Ph4);
* :func:`rank_in` — untagged searchsorted ranks (left/right) of queries in a
  sorted run, the rank computation of the Ph6 rank-merge tail
  (``core/merge._rank_merge_two`` under ``merge_backend="pallas"``). The
  side is encoded in the splitter *proc* tag: with ``me = 0`` a tag of -1
  makes the lexicographic comparator strictly-less (side="left") and +1
  makes it less-or-equal (side="right") — no kernel change needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.types import round_up, sentinel_for

from . import kernel

BLOCK = 2048


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@jax.jit
def splitter_ranks(x_sorted, split_keys, split_proc, split_idx, me):
    """Bucket boundaries (S,) of tagged splitters in a sorted (n,) run."""
    n = x_sorted.shape[0]
    block = min(BLOCK, round_up(n, 128))
    npad = round_up(n, block)
    sent = sentinel_for(x_sorted.dtype)
    xp = jnp.pad(x_sorted, (0, npad - n), constant_values=sent)
    ranks = kernel.splitter_ranks(
        xp,
        split_keys,
        split_proc.astype(jnp.int32),
        split_idx.astype(jnp.int32),
        jnp.asarray(me, jnp.int32),
        block=block,
        interpret=_interpret(),
    )
    # pad elements carry idx ≥ n; a real splitter can still tag idx ≥ n only
    # on its own (proc, idx) record, never here — but a padded x equal to a
    # splitter key with me<proc would count. Clamp to n for safety.
    return jnp.minimum(ranks, n)


@functools.partial(jax.jit, static_argnames=("side",))
def rank_in(data: jnp.ndarray, queries: jnp.ndarray, *, side: str = "left"):
    """Rank of each query in a sorted (n,) run — jnp.searchsorted semantics.

    side="left": #{i : data_i < q}; side="right": #{i : data_i <= q}.
    Sentinel pads (appended to reach the block multiple) can only contribute
    on side="right" for sentinel-valued queries; the final clamp to n undoes
    that, matching searchsorted over the unpadded run exactly.
    """
    if side not in ("left", "right"):
        raise ValueError(f"unknown side {side!r}")
    n = data.shape[0]
    block = min(BLOCK, round_up(n, 128))
    npad = round_up(n, block)
    sent = sentinel_for(data.dtype)
    xp = jnp.pad(data, (0, npad - n), constant_values=sent)
    s = queries.shape[0]
    tag = jnp.full((s,), 1 if side == "right" else -1, jnp.int32)
    ranks = kernel.splitter_ranks(
        xp,
        queries,
        tag,
        jnp.zeros((s,), jnp.int32),
        jnp.asarray(0, jnp.int32),
        block=block,
        interpret=_interpret(),
    )
    return jnp.minimum(ranks, n)
