"""Splitter-rank computation — Pallas TPU kernel (the paper's Ph4 partition).

Given a sorted run and p-1 (tagged) splitters, compute each splitter's rank,
i.e. the bucket boundaries of Fig. 1 step 9. A scalar binary search is a
gather-heavy pattern; the TPU-idiomatic formulation is a *masked count*:

    rank(q) = Σ_i [ (x_i, me, i) <  (q_key, q_proc, q_idx) ]

evaluated as a broadcast lexicographic compare of a (block,) data tile
against the (S,) splitter vector, reduced over the grid. O(n·S) vector work
replaces O(S·lg n) scalar work — the classic network-vs-scalar TPU trade,
and S = p-1 is small. The tagged comparator is §5.1.1's duplicate handling.

Grid iterates over data blocks; the output (1, S) rank block is revisited
every step and accumulated in place (init at step 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ranks_kernel(x_ref, sk_ref, sp_ref, si_ref, me_ref, o_ref, *, block: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (1, block)
    base = step * block
    idx = base + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
    me = me_ref[0, 0]
    sk, sp, si = sk_ref[...], sp_ref[...], si_ref[...]  # (1, S)
    # lexicographic (key, proc, idx) < (splitter key, proc, idx)
    xk = x[:, :, None]  # (1, block, 1)
    xi = idx[:, :, None]
    qk, qp, qi = sk[:, None, :], sp[:, None, :], si[:, None, :]  # (1, 1, S)
    less = (xk < qk) | ((xk == qk) & ((me < qp) | ((me == qp) & (xi < qi))))
    o_ref[...] += jnp.sum(less.astype(jnp.int32), axis=1)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def splitter_ranks(
    x_sorted: jnp.ndarray,
    split_keys: jnp.ndarray,
    split_proc: jnp.ndarray,
    split_idx: jnp.ndarray,
    me: jnp.ndarray,
    *,
    block: int = 2048,
    interpret: bool = False,
) -> jnp.ndarray:
    """Ranks of S tagged splitters in the local sorted run (n,) -> (S,) int32.

    Caller pads n to a multiple of ``block`` with the dtype sentinel; pad
    elements compare greater-or-equal to every real splitter, so they never
    contribute to a rank (their implicit idx also exceeds every tag).
    """
    n = x_sorted.shape[0]
    s = split_keys.shape[0]
    assert n % block == 0, "pad the run to a multiple of the block size"
    grid = (n // block,)
    return pl.pallas_call(
        functools.partial(_ranks_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, s), lambda i: (0, 0)),
            pl.BlockSpec((1, s), lambda i: (0, 0)),
            pl.BlockSpec((1, s), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, s), jnp.int32),
        interpret=interpret,
    )(
        x_sorted[None, :],
        split_keys[None, :],
        split_proc[None, :],
        split_idx[None, :],
        me.reshape(1, 1),
    )[0]
