"""Pure-jnp oracle for the splitter-rank kernel."""
from __future__ import annotations

import jax.numpy as jnp


def splitter_ranks(x_sorted, split_keys, split_proc, split_idx, me):
    """rank(q) = #{i : (x_i, me, i) < (q_key, q_proc, q_idx)} — dense count."""
    n = x_sorted.shape[0]
    i = jnp.arange(n, dtype=jnp.int32)[:, None]
    xk = x_sorted[:, None]
    qk, qp, qi = split_keys[None, :], split_proc[None, :], split_idx[None, :]
    less = (xk < qk) | ((xk == qk) & ((me < qp) | ((me == qp) & (i < qi))))
    return jnp.sum(less.astype(jnp.int32), axis=0)
