"""AdamW with global-norm clipping, cosine schedule and ZeRO-friendly state.

No optax in this environment — implemented directly. Optimizer state dtype
is configurable: the 398B jamba config uses bf16 m/v (with fp32 step math)
so the per-chip state budget fits 16 GB HBM at 256 chips (DESIGN.md §6 /
EXPERIMENTS.md §Dry-run memory table). State leaves inherit the parameter's
PartitionSpec, so m/v are 2-D sharded exactly like the weights (ZeRO-3
equivalent under GSPMD).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    state_dtype: str = "float32"  # "bfloat16" for the 398B config
    grad_accum_dtype: str = "float32"


def schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_state(cfg: OptConfig, params: Any) -> Dict:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    sq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)
    )
    return jnp.sqrt(sq)


def apply_updates(
    cfg: OptConfig, params: Any, grads: Any, state: Dict
) -> Tuple[Any, Dict, Dict]:
    """One AdamW step. Returns (params, state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.betas
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mf.astype(sdt), vf.astype(sdt)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
