from repro.optim.adamw import OptConfig, apply_updates, global_norm, init_state, schedule  # noqa: F401
from repro.optim import compress  # noqa: F401
