"""Gradient compression for the cross-pod (DCN) axis.

int8 block-quantized all-reduce with error feedback: the residual of each
quantization is fed back into the next step's gradient, so no gradient mass
is ever lost (standard EF-SGD argument — the compressor only needs to be
*contractive*, not unbiased). The quantizer therefore rounds to nearest,
whose rounding MSE is half that of stochastic rounding (1/12 vs 1/6 LSB²);
stochastic rounding remains available for EF-free uses, where per-step
unbiasedness is what matters instead.
Intended for the ``pod`` axis only — intra-pod ICI is fast enough for bf16.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize_int8(
    x: jnp.ndarray, rng: Optional[jax.Array] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise symmetric int8 quantization.

    Rounds to nearest by default; pass ``rng`` for stochastic rounding
    (unbiased per step, double the MSE — only worth it without error
    feedback downstream).
    """
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    y = blocks / scale
    if rng is not None:
        y = y + jax.random.uniform(rng, y.shape) - 0.5
    q = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_tree(grads: Any, errors: Any, rng: jax.Array):
    """Apply error feedback then quantize every leaf.

    Returns (quantized tree of (q, scale), new error tree). The EF buffer
    carries each step's exact residual, so nearest rounding is used (``rng``
    is accepted for signature stability but unused).
    """
    del rng
    leaves, treedef = jax.tree.flatten(grads)
    err_leaves = jax.tree.leaves(errors) if errors is not None else [0.0] * len(leaves)
    qs, new_errs = [], []
    for g, e in zip(leaves, err_leaves):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s, g.shape)
        qs.append((q, s))
        new_errs.append(corrected - deq)
    return jax.tree.unflatten(treedef, qs), jax.tree.unflatten(treedef, new_errs)


def decompress_tree(qtree: Any, like: Any) -> Any:
    return jax.tree.map(
        lambda qs, g: dequantize_int8(qs[0], qs[1], g.shape).astype(g.dtype),
        qtree,
        like,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2,
    )


def init_errors(grads_shape: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape)
