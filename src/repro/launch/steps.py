"""jit-wrapped prefill/decode step factories with explicit shardings.

(The train-step factory lives in repro/train/train_step.py; these are the
serving-side equivalents used by the dry-run and the serving driver.)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import Model, make_mesh_info
from repro.models import sharding as shd


def make_prefill_step(
    model: Model, mesh: Optional[Mesh], cache_len: int, batch_shapes=None
):
    cfg = model.cfg
    mesh_info = make_mesh_info(mesh, cfg)

    def fn(params, batch):
        return model.prefill(params, batch, mesh_info, cache_len=cache_len)

    if mesh is None:
        return jax.jit(fn)
    pshapes = model.param_shapes()
    pspecs = shd.sanitize_specs(
        mesh, shd.param_specs(cfg, pshapes, mesh.shape["model"]), pshapes
    )
    bspecs = shd.batch_specs(cfg, mesh, "prefill")
    bspecs.pop("labels", None)
    if batch_shapes is not None:
        bspecs = shd.sanitize_specs(
            mesh, {k: bspecs[k] for k in batch_shapes}, batch_shapes
        )
    to_s = lambda t: shd.to_shardings(mesh, t)
    return jax.jit(fn, in_shardings=(to_s(pspecs), to_s(bspecs)))


def make_decode_step(model: Model, mesh: Optional[Mesh], batch: int, cache_len: int):
    cfg = model.cfg
    mesh_info = make_mesh_info(mesh, cfg)

    def fn(params, cache, token):
        return model.decode_step(params, cache, token, mesh_info)

    if mesh is None:
        return jax.jit(fn)
    pshapes = model.param_shapes()
    pspecs = shd.sanitize_specs(
        mesh, shd.param_specs(cfg, pshapes, mesh.shape["model"]), pshapes
    )
    cshapes = model.cache_shapes(batch, cache_len)
    cspecs = shd.sanitize_specs(mesh, shd.cache_specs(cfg, mesh, cshapes), cshapes)
    dp = shd.dp_axes(mesh)
    tok_spec = shd.sanitize_specs(
        mesh, P(dp), jax.ShapeDtypeStruct((batch,), jnp.int32)
    )
    to_s = lambda t: shd.to_shardings(mesh, t)
    return jax.jit(
        fn,
        in_shardings=(to_s(pspecs), to_s(cspecs), to_s(tok_spec)),
        out_shardings=(None, to_s(cspecs)),
        donate_argnums=(1,),
    )
