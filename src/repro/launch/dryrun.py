import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST precede every other import (jax locks the device
count at first init). 512 placeholder CPU devices stand in for 2 pods × 256
v5e chips; the compile proves the distribution config is coherent — sharding
mismatches, compile-time OOM and unsupported collectives all fail here.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Per cell this records: compile wall-time, per-device memory analysis,
cost_analysis (FLOPs / bytes), and the collective-bytes breakdown parsed
from the post-SPMD HLO — the §Roofline inputs.
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import SHAPES, all_archs, get_arch  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import make_decode_step, make_prefill_step  # noqa: E402
from repro.models import Model  # noqa: E402
from repro.optim import OptConfig  # noqa: E402
from repro.roofline.analysis import analyze_compiled  # noqa: E402
from repro.train import make_train_step  # noqa: E402


def opt_shapes(params_shapes, opt_cfg: OptConfig):
    dt = jnp.dtype(opt_cfg.state_dtype)
    z = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return {
        "m": jax.tree.map(z, params_shapes),
        "v": jax.tree.map(z, params_shapes),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def opt_config_for(cfg) -> OptConfig:
    # >100B params: bf16 optimizer state to fit the 16 GB/chip budget
    big = cfg.param_count() > 1e11
    return OptConfig(
        state_dtype="bfloat16" if big else "float32",
        grad_accum_dtype="bfloat16" if big else "float32",
    )


def lower_cell(arch_name: str, shape_name: str, *, multi_pod: bool):
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    runnable, reason = cfg.runnable(shape)
    if not runnable:
        return {"status": "skipped", "reason": reason}
    if shape.kind != "train" and cfg.param_sharding == "dp":
        # the pure-DP training policy (§Perf A2) is wrong for serving
        # (batch ≤ 32): serve with TP weights instead.
        cfg = dataclasses.replace(cfg, param_sharding="1d")

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg)
    pshapes = model.param_shapes()

    if shape.kind == "train":
        ocfg = opt_config_for(cfg)
        batch = model.input_specs(shape)
        fn = make_train_step(model, ocfg, mesh, batch_shapes=batch)
        args = (pshapes, opt_shapes(pshapes, ocfg), batch)
    elif shape.kind == "prefill":
        batch = model.input_specs(shape)
        fn = make_prefill_step(
            model, mesh, cache_len=shape.seq_len, batch_shapes=batch
        )
        args = (pshapes, batch)
    else:  # decode
        specs = model.input_specs(shape)
        fn = make_decode_step(
            model, mesh, batch=shape.global_batch, cache_len=shape.seq_len
        )
        args = (pshapes, specs["cache"], specs["token"])

    t0 = time.time()
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    info = analyze_compiled(compiled, mesh=mesh, cfg=cfg, shape=shape)
    info.update(
        {
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
        }
    )
    return info


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    print(
        f"mesh: {dict(mesh.shape)} over {len(jax.devices())} host devices "
        f"({'multi-pod' if args.multi_pod else 'single-pod'})"
    )

    cells = []
    if args.all:
        for a in all_archs():
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    results = {}
    for arch_name, shape_name in cells:
        key = f"{arch_name}|{shape_name}|{'2x16x16' if args.multi_pod else '16x16'}"
        print(f"=== {key} ===", flush=True)
        try:
            info = lower_cell(arch_name, shape_name, multi_pod=args.multi_pod)
        except Exception as e:  # a dry-run failure is a bug in our system
            info = {
                "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:],
            }
        results[key] = info
        for k, v in info.items():
            if k not in ("trace", "collectives"):
                print(f"  {k}: {v}")
        if "collectives" in info:
            print(f"  collectives: {json.dumps(info['collectives'])}")
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=2)

    n_ok = sum(1 for r in results.values() if r["status"] == "ok")
    n_skip = sum(1 for r in results.values() if r["status"] == "skipped")
    n_err = len(results) - n_ok - n_skip
    print(f"\n=== dry-run summary: {n_ok} ok, {n_skip} skipped, {n_err} errors ===")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
