"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — smoke tests and benchmarks must keep
seeing one CPU device; only launch/dryrun.py sets the 512-placeholder-device
XLA flag before first jax init.

Axes: ``pod`` (cross-pod DCN, pure DP), ``data`` (intra-pod DP + FSDP/ZeRO
weight sharding), ``model`` (TP / EP / decode sequence sharding).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np

    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)} — run via "
            "launch/dryrun.py which forces 512 host devices"
        )
    # single-pod mesh under the 512-device dry-run process: take one pod
    return jax.sharding.Mesh(np.array(devices[:n]).reshape(shape), axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, elastic re-meshing)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def host_device_mesh(n: int, axis: str = "data"):
    """Small single-axis mesh over host CPU devices (distributed tests)."""
    import numpy as np

    return jax.sharding.Mesh(np.array(jax.devices()[:n]), (axis,))
