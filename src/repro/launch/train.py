"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 100 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt [--resume]

Wires together every substrate: config registry, model, AdamW, stateless-
seeded data pipeline, checkpoint/restart, straggler monitoring and elastic
re-mesh planning. On a real cluster the mesh comes from
``make_production_mesh``; on this CPU container it runs single-device with
the same code path (mesh=None).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.data import synthetic_batch
from repro.models import Model
from repro.optim import OptConfig
from repro.train import checkpoint, elastic, init_all, make_train_step


def train(
    cfg,
    *,
    steps: int,
    batch: int,
    seq: int,
    ckpt_dir: str | None,
    ckpt_every: int = 50,
    resume: bool = False,
    mesh=None,
    opt_cfg: OptConfig | None = None,
    log_every: int = 10,
):
    model = Model(cfg)
    oc = opt_cfg or OptConfig(total_steps=steps, warmup_steps=max(steps // 20, 1))
    params, opt = init_all(model, oc, jax.random.key(0))
    start = 0
    if resume and ckpt_dir and checkpoint.latest_step(ckpt_dir) is not None:
        start = checkpoint.latest_step(ckpt_dir)
        state = checkpoint.restore(ckpt_dir, start, {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        print(f"[train] resumed from step {start}")

    step_fn = make_train_step(model, oc, mesh)
    shape = ShapeConfig("cli", seq, batch, "train")
    monitor = elastic.StragglerMonitor()
    losses = []
    for step in range(start, steps):
        data = synthetic_batch(cfg, shape, step)
        with elastic.StepTimer() as t:
            params, opt, metrics = step_fn(params, opt, data)
            jax.block_until_ready(metrics["loss"])
        if monitor.record(t.seconds):
            print(f"[train] step {step}: straggler threshold tripped — a real "
                  f"cluster driver would re-mesh via elastic.plan_remesh here")
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == steps - 1:
            toks = batch * seq / t.seconds
            print(
                f"[train] step {step:5d} loss {losses[-1]:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"lr {float(metrics['lr']):.2e} {toks:,.0f} tok/s",
                flush=True,
            )
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            checkpoint.save(ckpt_dir, step + 1, {"params": params, "opt": opt})
    if ckpt_dir:
        checkpoint.save(ckpt_dir, steps, {"params": params, "opt": opt})
    return params, opt, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--reduced", action="store_true", help="tiny same-family config")
    args = ap.parse_args()
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    train(
        cfg,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        ckpt_dir=args.ckpt_dir,
        resume=args.resume,
    )


if __name__ == "__main__":
    main()
