"""Segment-aware w.h.p. pair-capacity bound for striped fused batches.

Why the classic bound fails fused batches — and what restores it
----------------------------------------------------------------
The whp pair capacity (``SortConfig.pair_cap``, Claim 5.1 scale) assumes
each lane's run is a value-representative ~n/p share of the input, so each
(src, dst) routing cell carries ~n/p² keys. PR 3's *contiguous* segment
packing breaks that structurally: a lane's run spans only a couple of
segments, and because the fused sorted order is segment-major, the lane's
whole run routes to the destination covering its own global position range
(max pair load ≈ n_per_proc — measured, not just theorized; see
tests/test_planner.py). That is why multi-segment batches were pinned to
the ``exact`` tier.

The *striped* layout (``core/segmented.pack_segments(layout="striped")``)
gives every lane ~1/p of every segment, making lanes representative again.
What remains — and what this module bounds — are the second-order
concentrations the classic bound never had to face:

* **small segments**: a segment that fits inside one routing bucket
  contributes its whole per-lane chunk ``m̂_s = ⌈m_s/p⌉`` to a single
  (src, dst) cell, granularity the n/p² term ignores;
* **duplicates**: a value block sorts contiguously ordered by source
  (lane, idx) — the §5.1.1 tag order — so a lane's copies of one value
  land in one bucket. A segment with top-value share δ_s can concentrate
  ``δ_s · m̂_s`` extra keys into a cell;
* **pads**: striped packing gives pads distinct interleaved composites, so
  the pad tail behaves like one perfectly-spread segment (δ = 0); the
  single-segment int32 path keeps constant sentinel pads, i.e. δ = 1.

The bound: slide a window of the whp bucket width
``W = ⌈(1 + 1/ω) · n_per_proc⌉`` over the segment extents of the fused
sorted order and take

    load(t) = Σ_s  m̂_s · min(1, overlap_s(t)/m_s + δ_s)

maximized over window positions t (piecewise linear in t, so evaluating
every breakpoint — overlap kinks at segment/window-edge alignments plus
the duplicate-clip kinks where the min saturates — is exact). The
returned capacity adds Chernoff-style slack ``ω·√load + ω`` for the
hypergeometric fluctuation of which values a lane's chunk drew. The
oversampling regulator ω is *the* tunable: it widens the window (more
splitter fluctuation tolerated → smaller failure probability) and scales
the slack, and :func:`solve_omega` picks it by minimizing routed volume
p·cap(ω) plus the Ph3 sample cost 2ω²·lg n the paper's analysis charges.

Validation: the Monte-Carlo fault-rate check in tests/test_planner.py
packs adversarial multi-segment batches (U/G/B/DD/zipf keys, zipf sizes)
and asserts the bound's observed starting-tier fault rate stays within the
planner's whp target.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import numpy as np

from .fingerprint import Fingerprint


#: above this segment count the exact O(R²) breakpoint scan hands over to
#: the O(R) conservative sweep — host-side planning must never rival the
#: sort it plans (a flood of tiny requests can put thousands of segments
#: in one batch)
MAX_EXACT_SCAN_SEGMENTS = 512


def _window_load_max_coarse(
    sizes: np.ndarray, dups: np.ndarray, p: int, width: float
) -> float:
    """O(R) upper bound on the exact window scan for huge segment counts.

    Per overlapped segment, ``m̂·min(1, ov/m + δ) ≤ ov/p + 1 + ⌈m/p⌉·δ``
    (since ``m̂ ≤ m/p + 1`` and ``ov ≤ m``), so any window's load is at
    most ``W/p + (#overlapped segments) + Σ ⌈m/p⌉·δ``. The overlap set
    only changes at segment enter/leave events, so one two-pointer sweep
    over segments maximizes the count and dup-mass terms together. Looser
    than the exact scan (it charges every overlapped segment a full +1 of
    rotation granularity) but always ≥ it — a plan from this path is
    conservative, never unsound.
    """
    m = sizes.astype(np.float64)
    ends = np.cumsum(m)
    starts = ends - m
    dup_mass = np.ceil(m / p) * dups
    best, j, count, dmass = 0.0, 0, 0, 0.0
    # windows whose LEFTMOST overlapped segment is i: the right edge can
    # reach up to ends[i] + width (left edge just inside segment i), so
    # the maximal overlap set is every j with starts[j] < ends[i] + width
    for i in range(len(m)):
        while j < len(m) and starts[j] < ends[i] + width:
            count += 1
            dmass += dup_mass[j]
            j += 1
        best = max(best, count + dmass)
        count -= 1
        dmass -= dup_mass[i]
    return width / p + best


def _window_load_max(
    sizes: np.ndarray, dups: np.ndarray, p: int, width: int
) -> float:
    """Max over window positions of Σ m̂_s·min(1, overlap/m_s + δ_s).

    The load is piecewise linear in the window start t, so its maximum sits
    at a breakpoint. Per segment those are: the four overlap kinks (window
    edge meets a segment edge — t ∈ {start−W, end−W, start, end}) and the
    two duplicate-clip kinks where ``overlap/m + δ`` saturates at 1
    (overlap = (1−δ)·m on the entering and leaving flank). Evaluating every
    breakpoint makes the scan exact; a starts/ends-only candidate set
    undersizes the bound on dup-heavy mixes (caught in review by brute
    force, now pinned in tests). Beyond ``MAX_EXACT_SCAN_SEGMENTS`` the
    O(R²) scan hands over to the O(R) conservative sweep.
    """
    if len(sizes) > MAX_EXACT_SCAN_SEGMENTS:
        total = float(sizes.sum())
        return _window_load_max_coarse(
            sizes, dups, p, float(min(width, total))
        )
    m = sizes.astype(np.float64)
    ends = np.cumsum(m)
    starts = ends - m
    m_hat = np.ceil(m / p)
    total = float(ends[-1])
    width = float(min(width, total))
    clip = (1.0 - np.minimum(dups, 1.0)) * m  # overlap where the min clips
    raw = np.concatenate(
        [
            starts, ends, starts - width, ends - width,
            starts + clip - width, ends - clip,
        ]
    )
    # the dup term applies only to OVERLAPPED segments (a duplicate block
    # concentrates inside its segment's extent, not everywhere), which
    # makes the load jump at ov = 0 boundaries — evaluate an epsilon inside
    # each breakpoint too, so the supremum of an open piece is not missed
    eps = max(total, 1.0) * 1e-9
    cand = np.unique(
        np.clip(np.concatenate([raw, raw - eps, raw + eps]), 0.0, total - width)
    )
    best = 0.0
    for t in cand:
        ov = np.clip(np.minimum(ends, t + width) - np.maximum(starts, t), 0.0, None)
        term = m_hat * np.minimum(1.0, ov / m + dups)
        load = float(np.where(ov > 0.0, term, 0.0).sum())
        best = max(best, load)
    return best


def segment_aware_pair_cap(
    sizes: Sequence[int],
    p: int,
    n_per_proc: int,
    *,
    omega: Optional[float] = None,
    dup_fractions: Optional[Sequence[float]] = None,
    pad_dup: float = 0.0,
) -> int:
    """Per-(src, dst) capacity bound for a striped-packed fused batch.

    ``sizes``/``dup_fractions`` describe the real segments; the
    ``p·n_per_proc − Σsizes`` pad tail is appended as one more segment with
    top-value share ``pad_dup`` (0.0 for the striped distinct-pad lift, 1.0
    for the single-segment constant int32 sentinel). Returns keys, not
    bytes; unaligned — ``SortConfig.pair_cap`` handles pad_align and the
    exact-tier clamp.
    """
    n = p * n_per_proc
    if omega is None:
        omega = max(1.0, math.sqrt(math.log2(max(n, 2))))
    sizes = [int(s) for s in sizes]
    dups = (
        list(dup_fractions)
        if dup_fractions is not None
        else [0.0] * len(sizes)
    )
    if len(dups) != len(sizes):
        raise ValueError("dup_fractions must align with sizes")
    pad = n - sum(sizes)
    if pad < 0:
        raise ValueError(f"batch of {sum(sizes)} keys exceeds n={n}")
    seg = [(s, d) for s, d in zip(sizes, dups) if s > 0]
    if pad > 0:
        seg.append((pad, float(pad_dup)))
    if not seg:
        return 0
    arr = np.asarray([s for s, _ in seg], np.int64)
    dar = np.asarray([d for _, d in seg], np.float64)
    width = int(math.ceil((1.0 + 1.0 / omega) * n_per_proc))
    load = _window_load_max(arr, dar, p, width)
    cap = load + omega * math.sqrt(load) + omega
    return int(math.ceil(cap))


def solve_omega(
    sizes: Sequence[int],
    p: int,
    n_per_proc: int,
    *,
    dup_fractions: Optional[Sequence[float]] = None,
    pad_dup: float = 0.0,
    grid: Tuple[float, ...] = (0.5, 1.0, 2.0, 4.0),
) -> Tuple[float, int]:
    """Pick the oversampling regulator the bound solves best under.

    Cost model per lane: routed volume ``p · cap(ω)`` (the p pair cells)
    plus the randomized Ph3 sample ``2·ω²·lg n`` the paper charges
    (Fig. 2/3 step 1). The grid spans ω₀·{½,1,2,4} around the paper's
    default ω₀ = √(lg n) — a fixed menu, so planner-chosen configs stay a
    bounded set for the executor registry. Returns ``(omega, cap_keys)``.
    """
    n = p * n_per_proc
    omega0 = max(1.0, math.sqrt(math.log2(max(n, 2))))
    best = None
    for mult in grid:
        om = max(1.0, omega0 * mult)
        cap = segment_aware_pair_cap(
            sizes, p, n_per_proc,
            omega=om, dup_fractions=dup_fractions, pad_dup=pad_dup,
        )
        cost = p * cap + 2.0 * om * om * math.log2(max(n, 2))
        if best is None or cost < best[0]:
            best = (cost, om, cap)
    return best[1], best[2]


def planned_cap_for(fp: Fingerprint, *, single_segment: bool = False) -> Tuple[float, int]:
    """(omega, pair cap) for a fingerprinted batch; pad regime from layout."""
    return solve_omega(
        fp.sizes,
        fp.p,
        fp.n_per_proc,
        dup_fractions=fp.dup_fractions,
        # single-segment batches keep the raw-int32 path whose pads are the
        # constant sentinel (fully concentrated); striped multi-segment
        # batches get the distinct interleaved pad lift (fully spread)
        pad_dup=1.0 if single_segment else 0.0,
    )
