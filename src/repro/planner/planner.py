"""CapacityPlanner — fingerprint buckets → (starting tier, oversampling),
adapted by observed traffic.

The planner closes the loop the paper's analysis opens: the whp bounds say
what capacity *should* suffice, the :class:`repro.core.TierStats` counters
say what actually did. Per fingerprint bucket (:func:`fingerprint.bucket_key`)
the planner keeps a **rung offset** over the analytic plan:

    rung 0   start at the segment-aware planned capacity (capacity.py)
    rung 1   the same bound ×2 (the ladder's planned2 scale, pre-applied)
    rung 2   start at exact — the PR 3 rule, now the *learned* last resort

A bucket whose empirical starting-tier fault rate exceeds ``fault_target``
is promoted one rung (its whp story is empirically false — stop paying the
wasted attempt); a bucket that stays clean for ``probe_after`` consecutive
batches is probed one rung down (maybe the traffic got tamer). Promotion
and probing reset the bucket's counters so the new rung is judged on its
own evidence.

History persists as JSON (``path=``), so a restarted service starts where
traffic left off: the acceptance test shows a fresh planner re-loading a
fault-ridden bucket's history starts it at the promoted rung.

Writes are atomic (tmp file + rename) and **merge-on-save**: before
writing, :meth:`save` re-reads the current file and folds in what other
processes learned since this planner loaded — union of buckets, the
*higher* rung on conflict (the capacity-safe direction), and the other
side's counter deltas (disk minus the snapshot taken at load) accumulated
onto same-rung entries. Several services sharing one history path
therefore pool their traffic instead of last-write-wins clobbering each
other; the residual race window (read → rename without a lock) can lose
at most one save's worth of *observations*, never whole buckets.

The planner also exposes the generic primitives (:meth:`rung_for` /
:meth:`observe`) that ``bsp_sort_safe`` and ``moe_ep_safe`` use as an
optional policy: the same bucket→rung learning over their own capacity
ladders, with the bucket keyed by shape + algorithm only (no segment
structure to exploit there).

Planned capacities are quantized to eighths of ``n_per_proc`` (≥ one
pad_align step), so across arbitrary traffic the executor registry sees at
most ~8 planned route configs per (p, n_per_proc) shape — the compiled-
callable cache stays O(log n buckets × tiers); asserted by the soak test.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import warnings
from typing import Dict, Optional, Sequence

import numpy as np

from repro import obs
from repro.core.types import round_up

from .capacity import planned_cap_for
from .fingerprint import Fingerprint, bucket_key, fingerprint_arrays

#: planner rungs over the analytic plan (see module docstring)
N_RUNGS = 3


#: route="radix" is picked when the estimated busiest range-bucket share is
#: within this factor of the perfect 1/p (see fingerprint.radix_share) —
#: balanced-enough integer keys skip the splitter superstep entirely.
RADIX_SKEW = 3.0

#: route="delta" is picked when the sampled in-order adjacent-pair share
#: (fingerprint.sampled_sortedness) is at least this high: ~0.9 means
#: roughly ≤5% of keys are out of place, where the fold's Δ-sized device
#: work beats every full-ladder route. Shuffled streams score ~0.5 and
#: never qualify. A wrong verdict costs only speed — the delta route is
#: byte-identical to the ladder by construction.
DELTA_SORTED_MIN = 0.90

#: near-sorted batches below this size take the ladder anyway — the fold's
#: fixed host split + merge overhead dominates tiny sorts.
DELTA_MIN_KEYS = 512


@dataclasses.dataclass(frozen=True)
class PlanDecision:
    """One batch's dispatch plan, also the record() correlation token."""

    bucket: str  # fingerprint bucket the learning is keyed by
    layout: str  # packing layout ("striped" / "contiguous")
    pair_capacity: str  # starting tier mode: "planned" | "whp" | "exact"
    pair_cap_override: Optional[int]  # planned capacity (keys), quantized
    omega: Optional[float]  # solved oversampling regulator
    rung: int  # learned rung this plan started at
    # distribution route: "sample" (splitter pipeline, capacity fields
    # above apply), "radix" (count-then-distribute — the launch driver
    # sizes the single rung from the true counts, so the capacity fields
    # are moot and retries are impossible by construction), or "delta"
    # (near-sorted single-segment batch: only the out-of-place Δ routes
    # through the h-relation, then one rank merge — repro.delta).
    route: str = "sample"

    @property
    def start_tier(self) -> str:
        return self.route if self.route in ("radix", "delta") else self.pair_capacity


def _quantize_cap(cap: int, n_per_proc: int, pad_align: int = 8) -> int:
    """Round up to an eighth-of-n_per_proc step (bounded distinct values)."""
    step = max(pad_align, n_per_proc // 8)
    return min(n_per_proc, round_up(cap, step))


class CapacityPlanner:
    def __init__(
        self,
        path: Optional[str] = None,
        *,
        fault_target: float = 0.05,
        min_attempts: int = 8,
        probe_after: int = 32,
    ) -> None:
        self.path = path
        self.fault_target = float(fault_target)
        self.min_attempts = int(min_attempts)
        self.probe_after = int(probe_after)
        #: bucket -> {"rung", "attempts", "faults", "clean"}
        self.history: Dict[str, Dict[str, int]] = {}
        # telemetry — registry counters under this planner's instance label;
        # the legacy attribute names are read-only property views below
        self.label = obs.next_instance("planner")
        reg = obs.metrics()
        self._plans = reg.counter("planner.plans", planner=self.label)
        self._radix_plans = reg.counter("planner.radix_plans", planner=self.label)
        self._delta_plans = reg.counter("planner.delta_plans", planner=self.label)
        self._promotions = reg.counter("planner.promotions", planner=self.label)
        self._probes = reg.counter("planner.probes", planner=self.label)
        self._dirty = False  # unsaved observations (see save_if_dirty)
        #: disk snapshot at load/last save — the merge-on-save baseline for
        #: computing what OTHER processes observed since (see save)
        self._base: Dict[str, Dict[str, int]] = {}
        if path is not None and os.path.exists(path):
            # persistence is telemetry, not dispatch (mirrors the warn-only
            # save path): a corrupt/truncated/stale-format history must not
            # keep a service from coming up — start fresh and re-learn
            try:
                with open(path) as f:
                    self.load_json(f.read())
            except (OSError, ValueError, KeyError, TypeError) as e:
                warnings.warn(f"planner history at {path!r} unusable ({e}); "
                              "starting fresh")
                self.history = {}
        self._base = {k: dict(v) for k, v in self.history.items()}

    # ----------------------------------------------- legacy telemetry views
    @property
    def plans(self) -> int:
        """plan() calls."""
        return self._plans.value

    @property
    def radix_plans(self) -> int:
        """Plans routed count-then-distribute."""
        return self._radix_plans.value

    @property
    def delta_plans(self) -> int:
        """Plans routed to the near-sorted fold path."""
        return self._delta_plans.value

    @property
    def promotions(self) -> int:
        return self._promotions.value

    @property
    def probes(self) -> int:
        return self._probes.value

    # ------------------------------------------------------------ learning
    def _entry(self, bucket: str) -> Dict[str, int]:
        e = self.history.get(bucket)
        if e is None:
            e = self.history[bucket] = {
                "rung": 0, "attempts": 0, "faults": 0, "clean": 0
            }
        return e

    def rung_for(self, bucket: str, n_rungs: int = N_RUNGS) -> int:
        """The learned starting rung for ``bucket`` (clamped to the ladder)."""
        return min(self._entry(bucket)["rung"], max(0, n_rungs - 1))

    def observe(self, bucket: str, faulted: bool, n_rungs: int = N_RUNGS) -> None:
        """Feed one outcome: did the bucket's starting tier overflow?

        Promotion: empirical fault rate above ``fault_target`` after
        ``min_attempts`` observations — the wasted starting attempt costs a
        full route execution, so a rung that faults is strictly worse than
        its successor. Probe: ``probe_after`` consecutive clean runs above
        rung 0 — one batch risks one retry to rediscover the cheap regime.
        """
        e = self._entry(bucket)
        self._dirty = True
        e["attempts"] += 1
        if faulted:
            e["faults"] += 1
            e["clean"] = 0
        else:
            e["clean"] += 1
        if (
            e["attempts"] >= self.min_attempts
            and e["faults"] / e["attempts"] > self.fault_target
            and e["rung"] < n_rungs - 1
        ):
            e["rung"] += 1
            e["attempts"] = e["faults"] = e["clean"] = 0
            self._promotions.inc()
        elif e["clean"] >= self.probe_after and e["rung"] > 0:
            e["rung"] -= 1
            e["attempts"] = e["faults"] = e["clean"] = 0
            self._probes.inc()

    # ------------------------------------------------------------ planning
    def plan(
        self,
        arrays: Sequence[np.ndarray],
        p: int,
        *,
        n_per_proc: Optional[int] = None,
        min_n_per_proc: int = 8,
        fingerprint: Optional[Fingerprint] = None,
    ) -> PlanDecision:
        """Plan one batch: fingerprint → bound → learned rung → decision.

        Single-segment batches keep the contiguous raw-int32 hot path but
        still get a *planned* capacity (the bound prices their constant
        sentinel pad tail, which the classic whp bound ignores — a batch
        just past a pow2 boundary concentrates ~n_p/2 pads per lane).
        Multi-segment batches are planned for the striped layout. Either
        way, a bound at or above ``exact`` — or a bucket promoted to the
        top rung — degenerates to the PR 3 rule.
        """
        fp = fingerprint or fingerprint_arrays(
            arrays, p, n_per_proc=n_per_proc, min_n_per_proc=min_n_per_proc
        )
        single = fp.n_segments <= 1
        bucket = bucket_key(fp)
        rung = self.rung_for(bucket)
        self._plans.inc()
        layout = "contiguous" if single else "striped"
        if (
            single
            and fp.int_key
            and fp.n_keys >= DELTA_MIN_KEYS
            and fp.sorted_frac >= DELTA_SORTED_MIN
        ):
            # near-sorted stream: fold, don't resort. Checked before radix —
            # a sorted uniform stream is also perfectly range-balanced, but
            # the fold's Δ-sized work beats even the radix route's single
            # full-size rung. Capacity fields are moot (the Δ sort runs its
            # own Δ-sized exact rung) and retries are impossible, mirroring
            # the radix contract.
            self._delta_plans.inc()
            return PlanDecision(bucket, layout, "exact", None, None, rung,
                                route="delta")
        if fp.int_key and fp.radix_share <= min(1.0, RADIX_SKEW / p):
            # balanced integer keys: count-then-distribute. No oversampling
            # to solve and no capacity to plan — the route's launch path
            # reads the exact counts off the prepared boundaries and the
            # ladder is one rung, so there is nothing for the fault
            # feedback to learn either (observe() still records the clean
            # run, keeping the bucket's probe counters truthful).
            self._radix_plans.inc()
            return PlanDecision(bucket, layout, "exact", None, None, rung,
                                route="radix")
        if rung >= N_RUNGS - 1:
            return PlanDecision(bucket, layout, "exact", None, None, rung)
        omega, cap = planned_cap_for(fp, single_segment=single)
        cap = _quantize_cap(cap << rung, fp.n_per_proc)
        if cap >= fp.n_per_proc:
            return PlanDecision(bucket, layout, "exact", None, None, rung)
        return PlanDecision(bucket, layout, "planned", cap, omega, rung)

    def record(self, decision: PlanDecision, faulted: bool) -> None:
        """Feed a dispatched batch's outcome back.

        ``faulted`` means the *starting* tier's attempt overflowed (i.e. the
        escalation driver retried at least once) — exact starts cannot
        fault on the pair capacity but still count as clean evidence for
        the probe-down counter. Persistence is deferred: callers flush the
        accumulated observations with :meth:`save_if_dirty` (the service
        does so once per flush, not once per batch).
        """
        self.observe(decision.bucket, faulted)

    # --------------------------------------------------------- persistence
    def to_json(self) -> str:
        return json.dumps(
            {
                "version": 1,
                "fault_target": self.fault_target,
                "buckets": self.history,
            },
            indent=1,
            sort_keys=True,
        )

    def load_json(self, text: str) -> None:
        data = json.loads(text)
        if data.get("version") != 1:
            raise ValueError(f"unknown planner history version {data.get('version')!r}")
        self.history = {
            k: {f: int(v[f]) for f in ("rung", "attempts", "faults", "clean")}
            for k, v in data["buckets"].items()
        }

    def _merge_disk(self, path: str) -> None:
        """Fold another process's on-disk observations into ``history``.

        Disk buckets unknown to us are adopted; on a shared bucket the
        higher rung wins (capacity-safe), and when rungs agree the disk
        side's counter *deltas* since our load snapshot are accumulated (so
        observations this planner already loaded are not double-counted).
        """
        try:
            with open(path) as f:
                other = CapacityPlanner()
                other.load_json(f.read())
        except (OSError, ValueError, KeyError, TypeError):
            return  # absent/corrupt: nothing to merge, overwrite cleanly
        for bucket, disk in other.history.items():
            own = self.history.get(bucket)
            if own is None:
                self.history[bucket] = dict(disk)
                continue
            if disk["rung"] > own["rung"]:
                self.history[bucket] = dict(disk)
            elif disk["rung"] == own["rung"]:
                base = self._base.get(bucket, {})
                for f_ in ("attempts", "faults", "clean"):
                    own[f_] += max(0, disk[f_] - base.get(f_, 0))

    def save(self, path: Optional[str] = None) -> str:
        """Atomically write the history JSON (tmp + rename), merge-on-save."""
        path = path or self.path
        if path is None:
            raise ValueError("no path configured for planner persistence")
        if os.path.exists(path):
            self._merge_disk(path)
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".planner")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(self.to_json() + "\n")
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        self._dirty = False
        self._base = {k: dict(v) for k, v in self.history.items()}
        return path

    def save_if_dirty(self) -> bool:
        """Persist iff configured (``path``) and observations accumulated."""
        if self.path is None or not self._dirty:
            return False
        self.save()
        return True

    def telemetry(self) -> Dict[str, object]:
        return {
            "plans": self.plans,
            "radix_plans": self.radix_plans,
            "delta_plans": self.delta_plans,
            "buckets": len(self.history),
            "promotions": self.promotions,
            "probes": self.probes,
            "rungs": {k: v["rung"] for k, v in sorted(self.history.items())},
        }
