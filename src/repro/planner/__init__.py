"""Adaptive capacity planner — segment-aware oversampling bounds plus
traffic-learned tier selection for the BSP sort service.

Data flow (see README.md in this package):

    fingerprint.py   sort-free workload summary (sizes, lane segment
                     spread, sampled duplicate fractions, key dtype +
                     sampled range/balance) + bucket keys
    capacity.py      segment-aware w.h.p. pair-capacity bound for striped
                     fused batches; solves for the oversampling ratio
    planner.py       CapacityPlanner: bucket → (route, starting tier, ω)
                     with JSON-persisted fault-rate feedback; balanced
                     integer-key batches take route="radix"
                     (count-then-distribute, single exact-capacity rung);
                     near-sorted single-segment batches take route="delta"
                     (repro.delta fold — only the out-of-place Δ moves)

Consumers: ``repro.service.SortService`` (the ``pair_capacity="auto"``
resolution), and the optional ``planner=`` policy hooks of
``repro.core.bsp_sort_safe`` and ``repro.models.moe.moe_ep_safe``.
"""
from .capacity import planned_cap_for, segment_aware_pair_cap, solve_omega
from .fingerprint import (
    Fingerprint,
    bucket_key,
    fingerprint_arrays,
    lane_spread,
    radix_share,
    sampled_dup_fraction,
    sampled_range_bits,
    sampled_sortedness,
)
from .planner import CapacityPlanner, PlanDecision

__all__ = [
    "CapacityPlanner",
    "Fingerprint",
    "PlanDecision",
    "bucket_key",
    "fingerprint_arrays",
    "lane_spread",
    "planned_cap_for",
    "radix_share",
    "sampled_dup_fraction",
    "sampled_range_bits",
    "sampled_sortedness",
    "segment_aware_pair_cap",
    "solve_omega",
]
