"""Workload fingerprints — the cheap, sort-free summary a batch is planned by.

The capacity planner must decide a starting tier *before* sorting, from
quantities that cost o(n·log n) to compute:

* shape: total keys, processor lanes, the pow2 ``n_per_proc`` bucket;
* structure: segment count and per-segment sizes (known exactly from the
  request queue — no data inspection needed);
* **lane segment spread** — how many segments overlap each lane's run under
  the *contiguous* packing geometry. ``lane_spread_max == 1`` is the
  single-segment hot path; anything larger is the regime where contiguous
  packing value-clusters lanes and the planner switches to the striped
  layout (``core/segmented.pack_segments(layout="striped")``);
* **sampled duplicate fraction** per segment — the share of the segment
  occupied by its most frequent key value, estimated from a bounded sample.
  Duplicate blocks sort contiguously (ordered by source (lane, idx) under
  the stable pipeline), so a lane's copies of one value concentrate into
  one routing bucket; the segment-aware capacity bound
  (``planner.capacity``) inflates per-segment contributions by this
  fraction;
* **key dtype + sampled key-range shape** — whether the keys are integers
  (``int_key``), how many bits span the sampled value range
  (``key_range_bits``), and the estimated busiest-bucket share under
  range-normalized p-bucketing (``radix_share``). These drive the
  *route* decision: integer keys whose mass spreads evenly over their
  observed range (dense expert-id-like domains, uniform draws, fused
  multi-segment composites — their dense segment-id prefix dominates the
  bucketing) take the count-then-distribute ``route="radix"`` path and
  skip the splitter superstep entirely; skewed ranges (zipf heads) stay
  on the sample route whose splitters adapt to the mass.

Fingerprints quantize into **buckets** (:func:`bucket_key`): pow2 segment
count, coarse duplicate level, exact (p, n_per_proc) shape. Buckets are the
unit of traffic learning — the planner's fault history is kept per bucket,
so the key must be coarse enough to accumulate statistics and fine enough
that one rung fits all members.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.segmented import _pow2_n_per_proc, contiguous_lane_sizes

#: sample size per segment for the duplicate-fraction estimate
DUP_SAMPLE = 64

#: adjacent-pair sample size for the sortedness probe
SORT_SAMPLE = 256

#: quantization grain of the sortedness estimate (sixteenths) — routing
#: thresholds compare against a coarse grid, not a noisy raw fraction, so
#: two near-identical batches can't flap across the delta boundary
SORT_QUANT = 16


@dataclasses.dataclass(frozen=True)
class Fingerprint:
    """Sort-free workload summary of one (to-be-)fused batch."""

    n_keys: int
    p: int
    n_per_proc: int  # pow2 bucket the batch packs into
    sizes: Tuple[int, ...]  # per-segment lengths, submit order
    lane_spread_max: int  # segments overlapping the busiest contiguous lane
    lane_spread_mean: float
    dup_fractions: Tuple[float, ...]  # sampled per-segment top-value share
    int_key: bool = True  # integer key dtype (radix route applicability)
    key_range_bits: int = 31  # bits spanning the sampled value range
    radix_share: float = 1.0  # est. busiest range-bucket share (1.0 = worst)
    # sampled in-order adjacent-pair share, quantized to 1/SORT_QUANT
    # (1.0 = sorted, ~0.5 = shuffled). Probed only for single-segment int
    # batches — the delta fold route's applicability domain; fused batches
    # report 0.0 and never route delta.
    sorted_frac: float = 0.0

    @property
    def n_segments(self) -> int:
        return len(self.sizes)

    @property
    def dup_fraction(self) -> float:
        """Size-weighted mean duplicate fraction of the batch."""
        if not self.sizes or self.n_keys == 0:
            return 0.0
        w = np.asarray(self.sizes, np.float64)
        return float((w * np.asarray(self.dup_fractions)).sum() / w.sum())

    @property
    def pad_keys(self) -> int:
        return self.p * self.n_per_proc - self.n_keys


def _sampled(keys: np.ndarray, sample: int, seed: int) -> np.ndarray:
    """``min(len, sample)`` keys drawn by a deterministic rng."""
    n = int(keys.shape[0])
    if n <= sample:
        return np.asarray(keys)
    idx = np.random.default_rng(seed).choice(n, size=sample, replace=False)
    return np.asarray(keys)[idx]


def sampled_dup_fraction(
    keys: np.ndarray, sample: int = DUP_SAMPLE, seed: int = 0
) -> float:
    """Estimate the share of ``keys`` held by its most frequent value.

    Samples ``min(len, sample)`` keys (deterministic rng) and returns the
    top sampled value's frequency share — an upward-biased-enough estimate
    for capacity planning (the Monte-Carlo test in tests/test_planner.py
    checks the *bound built on it*, not the estimator in isolation).
    """
    pick = _sampled(keys, sample, seed)
    if pick.size == 0:
        return 0.0
    _, counts = np.unique(pick, return_counts=True)
    return float(counts.max() / pick.size)


def sampled_sortedness(
    keys: np.ndarray, sample: int = SORT_SAMPLE, seed: int = 0
) -> float:
    """Quantized estimate of the in-order adjacent-pair share of ``keys``.

    Samples ``min(n-1, sample)`` adjacent pairs (deterministic rng) and
    returns the fraction with ``keys[i] <= keys[i+1]``, snapped to the
    1/``SORT_QUANT`` grid. A sorted stream scores 1.0; a shuffled one
    ~0.5; a sorted run with Δ·n scattered updates ~1 − 2Δ (each displaced
    key breaks at most its two incident pairs) — so the probe doubles as a
    Δ-share estimate: Δ/n ≈ (1 − sorted_frac) / 2. Mis-estimation is a
    cost-only risk; the delta route is byte-identical to the ladder
    whatever the true sortedness.
    """
    k = np.asarray(keys).reshape(-1)
    m = int(k.shape[0]) - 1
    if m < 1:
        return 1.0
    if m <= sample:
        idx = np.arange(m)
    else:
        idx = np.random.default_rng(seed).choice(m, size=sample, replace=False)
    frac = float(np.mean(k[idx] <= k[idx + 1]))
    return round(frac * SORT_QUANT) / SORT_QUANT


def sampled_range_bits(samples: Sequence[np.ndarray]) -> int:
    """Bits spanning the global sampled key range (0 = single value)."""
    nonempty = [s for s in samples if s.size]
    if not nonempty:
        return 0
    lo = min(int(s.min()) for s in nonempty)
    hi = max(int(s.max()) for s in nonempty)
    return int(hi - lo).bit_length()


def radix_share(
    samples: Sequence[np.ndarray], sizes: Sequence[int], p: int
) -> float:
    """Estimated busiest-bucket share under range-normalized p-bucketing.

    This is the balance the ``route="radix"`` destination function
    (``core.sort_radix.radix_boundaries``) would achieve — 1/p is perfect,
    1.0 aims everything at one processor (still *correct* under radix, the
    capacity is exact either way, but the busiest proc serializes the
    merge). Single-segment batches estimate it from the sampled raw keys;
    fused multi-segment batches from the segment sizes alone — the
    composite's dense segment-id prefix dominates the range, so buckets are
    runs of ``⌈R/p⌉`` consecutive segments (a conservative estimate for
    small R, where the low key bits would subdivide further).
    """
    sizes = [int(s) for s in sizes]
    total = sum(sizes)
    if total == 0 or p <= 0:
        return 1.0
    if len(sizes) > 1:
        width = (len(sizes) - 1) // p + 1
        shares = np.zeros(p, np.float64)
        for i, s in enumerate(sizes):
            shares[min(i // width, p - 1)] += s
        return float(shares.max() / total)
    s = np.asarray(samples[0])
    if s.size == 0:
        return 1.0
    lo, hi = int(s.min()), int(s.max())
    width = (hi - lo) // p + 1
    b = (s.astype(np.int64) - lo) // width
    return float(np.bincount(b, minlength=p).max() / s.size)


def lane_spread(sizes: Sequence[int], p: int) -> Tuple[int, float]:
    """(max, mean) segments overlapping each lane under contiguous packing.

    Contiguous packing deals the submit-order concatenation into p
    even-share lanes; a lane "overlaps" every segment that contributes at
    least one key to it. This is the geometry that value-clusters lanes:
    spread ≈ R/p means each lane sees only a sliver of the batch's value
    range.
    """
    sizes = [int(s) for s in sizes if int(s) > 0]
    total = sum(sizes)
    if not sizes or p <= 0 or total == 0:
        return 0, 0.0
    bounds = np.cumsum([0] + sizes)  # segment extents in submit order
    spreads = []
    off = 0
    # the same lane deal pack_segments uses — shared so the fingerprint
    # can never drift from the actual contiguous packing geometry
    for c in contiguous_lane_sizes(total, p):
        if c == 0:
            spreads.append(0)
            continue
        lo = np.searchsorted(bounds, off, side="right") - 1
        hi = np.searchsorted(bounds, off + c - 1, side="right") - 1
        spreads.append(int(hi - lo + 1))
        off += c
    return int(max(spreads)), float(np.mean(spreads))


def fingerprint_arrays(
    arrays: Sequence[np.ndarray],
    p: int,
    *,
    n_per_proc: Optional[int] = None,
    min_n_per_proc: int = 8,
    sample: int = DUP_SAMPLE,
    seed: int = 0,
) -> Fingerprint:
    """Fingerprint a batch of ragged request arrays without sorting them."""
    sizes = tuple(int(np.asarray(a).shape[0]) for a in arrays)
    total = sum(sizes)
    n_p = n_per_proc or _pow2_n_per_proc(total, p, min_n_per_proc)
    smax, smean = lane_spread(sizes, p)
    picks = [
        _sampled(np.asarray(a).reshape(-1), sample, seed + i)
        for i, a in enumerate(arrays)
    ]
    dups = tuple(
        float(np.unique(s, return_counts=True)[1].max() / s.size)
        if s.size
        else 0.0
        for s in picks
    )
    int_key = all(
        np.issubdtype(np.asarray(a).dtype, np.integer) for a in arrays
    )
    return Fingerprint(
        n_keys=total,
        p=p,
        n_per_proc=n_p,
        sizes=sizes,
        lane_spread_max=smax,
        lane_spread_mean=smean,
        dup_fractions=dups,
        int_key=int_key,
        key_range_bits=sampled_range_bits(picks) if int_key else 31,
        radix_share=radix_share(picks, sizes, p) if int_key else 1.0,
        sorted_frac=(
            sampled_sortedness(np.asarray(arrays[0]).reshape(-1), seed=seed)
            if int_key and len(arrays) == 1
            else 0.0
        ),
    )


def _pow2_bucket(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length() if x > 0 else 0


def dup_level(frac: float) -> int:
    """Coarse duplicate regime: 0 = distinct-ish, 1 = mixed, 2 = heavy."""
    return 0 if frac < 0.05 else (1 if frac < 0.35 else 2)


def bucket_key(fp: Fingerprint) -> str:
    """The traffic-learning bucket this fingerprint falls into.

    Shape is exact (each (p, n_per_proc) is its own compiled program
    anyway); segment count rounds to a power of two; duplicates quantize to
    three levels. O(log n · log R · 3) distinct buckets across any traffic.
    """
    return (
        f"p{fp.p}/npp{fp.n_per_proc}"
        f"/segs{_pow2_bucket(fp.n_segments)}/dup{dup_level(fp.dup_fraction)}"
    )
