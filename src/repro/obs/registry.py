"""Process-wide metrics registry — counters, gauges and histograms.

One flat namespace replaces the telemetry that used to live as ad-hoc
attributes scattered over four modules (``TierStats`` dicts, the
dispatcher's ``launches``/``in_flight_peak``, the planner's
``radix_plans``/``promotions``, the serve engine's refill/prefetch
counters). Every metric is keyed by a dotted name plus sorted ``k=v``
labels::

    dispatch.launches{svc=svc0}        counter
    service.request_latency_s{svc=svc0} histogram
    sort.tier_attempts{tier=whp}        counter

Naming conventions (see ``src/repro/obs/README.md``):

* names are ``<subsystem>.<noun>``, lower_snake, units suffixed
  (``_s`` seconds, ``_bytes``, bare = count);
* instance-scoped metrics (several services in one process) carry an
  ``svc=``/``planner=``/``engine=`` label from :func:`repro.obs.next_instance`,
  so per-instance attribute views stay exact while ``snapshot()`` sees the
  whole process;
* per-category tallies (tier names, pow2 buckets, flush triggers) are one
  counter per label value, re-assembled into the legacy dicts by the
  owners' thin property views.

The registry is plain Python over the GIL — metric updates are dict lookups
plus an integer add, cheap enough for per-request paths. ``snapshot()``
returns a flat JSON-able dict; ``reset()`` zeroes values but keeps
registrations (an owner's cached handle stays valid).
"""
from __future__ import annotations

import collections
from typing import Deque, Dict, Iterable, List, Tuple

import numpy as np


class Counter:
    """Monotonic counter. ``value`` is a plain attribute — reads are free."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def _reset(self) -> None:
        self.value = 0

    def _snap(self):
        return self.value


class Gauge:
    """Last-written value; ``set_max`` keeps a high-water mark."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def set(self, v) -> None:
        self.value = v

    def set_max(self, v) -> None:
        if v > self.value:
            self.value = v

    def _reset(self) -> None:
        self.value = 0

    def _snap(self):
        return self.value


class Histogram:
    """Bounded-window histogram: lifetime count/total + recent raw values.

    The window (``deque(maxlen=...)``) bounds memory for long-lived serving
    processes, exactly like the latency deque it replaces; percentiles are
    computed over the window with ``np.quantile`` and memoized per
    observation count, so a soak loop polling telemetry between completions
    never rescans the window.
    """

    __slots__ = ("values", "count", "total", "_memo")

    def __init__(self, maxlen: int = 1 << 16) -> None:
        self.values: Deque[float] = collections.deque(maxlen=maxlen)
        self.count = 0  # lifetime observations (window may have dropped some)
        self.total = 0.0
        self._memo: Tuple[int, Dict] = (-1, {})

    def observe(self, v: float) -> None:
        self.values.append(float(v))
        self.count += 1
        self.total += float(v)

    def quantiles(self, qs: Iterable[float]) -> List[float]:
        arr = np.fromiter(self.values, np.float64)
        if not arr.size:
            return [float("nan") for _ in qs]
        return [float(x) for x in np.quantile(arr, list(qs))]

    def summary(self) -> Dict[str, float]:
        """{count, mean, p50, p99} over the window, memoized by count."""
        done, row = self._memo
        if done == self.count:
            return row
        row = {"count": self.count}
        if self.values:
            arr = np.fromiter(self.values, np.float64)
            p50, p99 = np.quantile(arr, [0.5, 0.99])
            row |= {
                "mean": float(arr.mean()),
                "p50": float(p50),
                "p99": float(p99),
            }
        self._memo = (self.count, row)
        return row

    def _reset(self) -> None:
        self.values.clear()
        self.count = 0
        self.total = 0.0
        self._memo = (-1, {})

    def _snap(self):
        return {k: round(v, 6) if isinstance(v, float) else v
                for k, v in self.summary().items()}


def metric_key(name: str, labels: Dict[str, object]) -> str:
    """Canonical flat key: ``name{k=v,...}`` with labels sorted by key."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Labeled counters/gauges/histograms with one snapshot()/reset().

    ``counter``/``gauge``/``histogram`` get-or-create (a kind clash on the
    same key raises — one name means one thing); ``collect`` re-assembles
    the per-label-value tallies the legacy dict attributes exposed.
    """

    def __init__(self) -> None:
        self._metrics: Dict[str, object] = {}
        #: key -> (name, labels) for collect()
        self._meta: Dict[str, Tuple[str, Dict[str, object]]] = {}

    def _get(self, kind, name: str, labels: Dict, **kw):
        key = metric_key(name, labels)
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = kind(**kw)
            self._meta[key] = (name, dict(labels))
        elif type(m) is not kind:
            raise TypeError(
                f"metric {key!r} already registered as {type(m).__name__}, "
                f"not {kind.__name__}"
            )
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, maxlen: int = 1 << 16, **labels) -> Histogram:
        return self._get(Histogram, name, labels, maxlen=maxlen)

    def collect(self, name: str, **fixed) -> List[Tuple[Dict[str, object], object]]:
        """Every metric named ``name`` whose labels include ``fixed``.

        Returns ``[(labels, metric), ...]`` — the owners' thin dict views
        (per-tier attempts, per-bucket batch counts) are one comprehension
        over this.
        """
        out = []
        for key, (n, labels) in self._meta.items():
            if n != name:
                continue
            if all(labels.get(k) == v for k, v in fixed.items()):
                out.append((labels, self._metrics[key]))
        return out

    def snapshot(self) -> Dict[str, object]:
        """Flat JSON-able dict of every metric (histograms as summaries)."""
        return {key: m._snap() for key, m in sorted(self._metrics.items())}

    def reset(self) -> None:
        """Zero every metric; registrations (and cached handles) survive."""
        for m in self._metrics.values():
            m._reset()
