"""Superstep spans — host-side tracing of the BSP sort/dispatch pipeline.

A :class:`Tracer` records *spans* (named intervals with labeled args) and
*points* (instant events: host syncs, distribution snapshots) from the
launch/wait boundaries of the sort drivers and the service dispatcher.
Everything the tracer touches is host-side Python: span bodies wrap jitted
*calls*, never traced code, so an untraced run's compiled programs are
byte-for-byte identical (``SortConfig.obs`` is excluded from the config's
equality/hash — see ``core/types.py``) and a traced run differs only in
host-side bookkeeping plus the explicit block-at-boundary syncs that make
span durations meaningful.

Span schema (one dict per span; see ``src/repro/obs/README.md``)::

    name  str   "prepare" | "route" | "queue" | "form" | "launch" |
                "flight" | ...
    cat   str   "sort" | "dispatch" | "moe" | ...
    tid   str   timeline lane ("sort0", "batch3", ...)
    t0    float perf_counter seconds at span start
    dur   float span length in seconds (>= 0)
    args  dict  JSON-able labels/measurements, notably for "route" spans:
                tier, rung, ok, h_words, supersteps, recv_max, recv_mean,
                imbalance, sync_s

``chrome_trace()`` exports the standard Chrome ``trace_event`` JSON
(load in chrome://tracing or Perfetto): spans become ``ph="X"`` complete
events on one row per ``tid``, points become ``ph="i"`` instants — the
dispatcher's queue→form→launch→flight rows make ``max_in_flight`` overlap
visually auditable. :func:`validate_chrome_trace` is the schema check CI
runs on the emitted file.
"""
from __future__ import annotations

import contextlib
import itertools
import json
import time
from typing import Dict, List, Optional

import numpy as np


def _jsonable(v):
    """Coerce span args to JSON-able types (numpy scalars/arrays included)."""
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return v


class Tracer:
    """Collects spans/points from the drivers; one instance per traced run.

    Passed as ``SortConfig(obs=...)`` / ``ServiceConfig(obs=...)`` — the
    config field is compare/hash-excluded, so a traced and an untraced
    config share every compiled program. ``clock`` is injectable for tests.
    """

    def __init__(self, clock=time.perf_counter) -> None:
        self._clock = clock
        self.t0 = clock()  # chrome-trace epoch
        self.spans: List[Dict] = []
        self.points: List[Dict] = []
        self._ids = itertools.count()

    def next_tid(self, prefix: str) -> str:
        """A fresh timeline-lane id (``sort0``, ``batch3``, ...)."""
        return f"{prefix}{next(self._ids)}"

    def now(self) -> float:
        """The tracer's clock — drivers capture launch timestamps with it."""
        return self._clock()

    def add_span(
        self,
        name: str,
        t_start: float,
        *,
        t_end: Optional[float] = None,
        cat: str = "sort",
        tid: str = "main",
        **args,
    ) -> None:
        """Record an interval whose start was captured earlier with :meth:`now`.

        The async drivers need this form: a route span opens at launch (in
        ``InFlightSort.__init__``) and closes at the overflow host-sync (in
        ``wait``) — two different stack frames, so the :meth:`span` context
        manager cannot bracket it. ``t_end`` pins the close to the sync
        itself, excluding any host-side count reads done after it.
        """
        end = self._clock() if t_end is None else t_end
        self.spans.append(
            {
                "name": name,
                "cat": cat,
                "tid": tid,
                "t0": t_start,
                "dur": max(0.0, end - t_start),
                "args": _jsonable(args),
            }
        )

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "sort", tid: str = "main", **args):
        """Record one interval; the yielded dict collects late-bound args."""
        extra: Dict = {}
        t0 = self._clock()
        try:
            yield extra
        finally:
            self.spans.append(
                {
                    "name": name,
                    "cat": cat,
                    "tid": tid,
                    "t0": t0,
                    "dur": max(0.0, self._clock() - t0),
                    "args": _jsonable({**args, **extra}),
                }
            )

    def point(self, name: str, cat: str = "sort", tid: str = "main", **args):
        """Record one instant event (host syncs, distribution snapshots)."""
        self.points.append(
            {
                "name": name,
                "cat": cat,
                "tid": tid,
                "t0": self._clock(),
                "args": _jsonable(args),
            }
        )

    # ------------------------------------------------------------- queries
    def route_spans(self) -> List[Dict]:
        """The per-rung route spans — the (g, L) fit's samples."""
        return [s for s in self.spans if s["name"] == "route"]

    # ------------------------------------------------------------- exports
    def chrome_trace(self) -> Dict:
        """Standard Chrome ``trace_event`` JSON (ts/dur in microseconds)."""
        tids = sorted(
            {e["tid"] for e in self.spans} | {e["tid"] for e in self.points}
        )
        tid_no = {t: i for i, t in enumerate(tids)}
        events: List[Dict] = [
            {
                "ph": "M",
                "pid": 0,
                "tid": tid_no[t],
                "name": "thread_name",
                "args": {"name": t},
            }
            for t in tids
        ]
        for s in self.spans:
            events.append(
                {
                    "ph": "X",
                    "pid": 0,
                    "tid": tid_no[s["tid"]],
                    "name": s["name"],
                    "cat": s["cat"],
                    "ts": (s["t0"] - self.t0) * 1e6,
                    "dur": s["dur"] * 1e6,
                    "args": s["args"],
                }
            )
        for p in self.points:
            events.append(
                {
                    "ph": "i",
                    "pid": 0,
                    "tid": tid_no[p["tid"]],
                    "name": p["name"],
                    "cat": p["cat"],
                    "ts": (p["t0"] - self.t0) * 1e6,
                    "s": "t",
                    "args": p["args"],
                }
            )
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1)
        return path

    def fit(self):
        """Least-squares (g, L) machine profile over the route spans."""
        from .profile import fit_gl

        return fit_gl(self.route_spans())

    def cost_report(self) -> Dict:
        """Fitted profile + per-superstep predicted-vs-measured rows."""
        from .profile import cost_report

        return cost_report(self)


def validate_chrome_trace(data: Dict) -> List[str]:
    """Schema check of an exported trace; returns problems (empty = valid)."""
    problems: List[str] = []
    events = data.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents missing or empty"]
    for i, e in enumerate(events):
        where = f"traceEvents[{i}]"
        ph = e.get("ph")
        if ph not in ("X", "i", "M"):
            problems.append(f"{where}: bad ph {ph!r}")
            continue
        for field in ("name", "pid", "tid"):
            if field not in e:
                problems.append(f"{where}: missing {field!r}")
        if ph == "M":
            continue
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < -1e-6:
            problems.append(f"{where}: bad ts {ts!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: bad dur {dur!r}")
    return problems


def validate_spans(tracer: "Tracer") -> List[str]:
    """Schema check of the raw span list; returns problems (empty = valid)."""
    problems: List[str] = []
    for i, s in enumerate(tracer.spans):
        where = f"spans[{i}]"
        for field in ("name", "cat", "tid", "t0", "dur", "args"):
            if field not in s:
                problems.append(f"{where}: missing {field!r}")
        if s.get("dur", 0) < 0:
            problems.append(f"{where}: negative dur")
        if not isinstance(s.get("args", {}), dict):
            problems.append(f"{where}: args not a dict")
        if s.get("name") == "route":
            for field in ("tier", "ok", "h_words", "supersteps"):
                if field not in s["args"]:
                    problems.append(f"{where}: route span missing {field!r}")
    return problems


def resolve_tracer(obj) -> Optional[Tracer]:
    """The tracer carried by a config-ish object, or None.

    Drivers call this on ``cfg.obs`` — any object with span()/point() duck-
    types, so tests can inject fakes.
    """
    if obj is None:
        return None
    if hasattr(obj, "span") and hasattr(obj, "point"):
        return obj
    return None
