"""repro.obs — the BSP cost-model observatory.

Three pieces, all host-side (no jax imports — nothing here can perturb a
compiled program):

* :class:`MetricsRegistry` (``registry.py``) — process-wide labeled
  counters/gauges/histograms with one ``snapshot()``/``reset()``; the
  scattered telemetry of ``TierStats``, the service dispatcher, the
  capacity planner and the serve engine now lives here, with the old
  attributes kept as thin property views.
* :class:`Tracer` (``trace.py``) — superstep spans recorded at the sort
  drivers' launch/wait boundaries and the dispatcher's
  queue→form→launch→flight pipeline, exported as Chrome ``trace_event``
  JSON. Off by default; enable per run via ``SortConfig(obs=tracer)`` /
  ``ServiceConfig(obs=tracer)``.
* the fitted machine profile (``profile.py``) — least-squares (g, L) over
  the traced h sizes and measured superstep walls, plus the per-run cost
  report (``w + g·h + L`` predicted vs measured) and the load-imbalance
  metric that tests the paper's balance claim.

``metrics()`` returns the process-wide default registry;
``next_instance("svc")`` hands out stable instance labels so several
services/planners in one process keep distinct metric keys.
"""
from __future__ import annotations

import itertools

from .profile import GLFit, cost_report, fit_gl, imbalance_of
from .registry import Counter, Gauge, Histogram, MetricsRegistry, metric_key
from .trace import (
    Tracer,
    resolve_tracer,
    validate_chrome_trace,
    validate_spans,
)

#: the process-wide default registry (one per process, like the default
#: SortExecutor) — owners cache metric handles from it at construction.
REGISTRY = MetricsRegistry()


def metrics() -> MetricsRegistry:
    return REGISTRY


_instance_ids = itertools.count()


def next_instance(prefix: str) -> str:
    """A process-unique instance label (``svc0``, ``planner1``, ...)."""
    return f"{prefix}{next(_instance_ids)}"


__all__ = [
    "Counter",
    "Gauge",
    "GLFit",
    "Histogram",
    "MetricsRegistry",
    "REGISTRY",
    "Tracer",
    "cost_report",
    "fit_gl",
    "imbalance_of",
    "metric_key",
    "metrics",
    "next_instance",
    "resolve_tracer",
    "validate_chrome_trace",
    "validate_spans",
]
