"""Fitted machine profile — estimate the paper's (g, L) from traced spans.

The BSP model charges a superstep ``w + g·h + L``: local work, per-word
communication gap, and barrier latency. The paper measures g and L with
dedicated microbenchmarks on the Cray T3D (§1.1, ``core/bsp.py`` carries
those constants); here we go the other way — *regress the machine out of a
traced run*. Every route span carries its measured wall time, its traced
h-relation size (words) and its superstep count, so over a run with varying
h the least-squares fit of

    wall_i  ≈  g · h_i  +  L · s_i

identifies an *effective* g (seconds per 32-bit word, including the local
routing work that scales with h — an upper bound on the wire gap) and an
effective L (per-superstep fixed cost: barrier + dispatch + the
h-independent work share). The per-span residual ``w_i = wall_i − g·h_i −
L·s_i`` is then the local-work estimate, making the cost report's
``pred_s = w + g·h + L·s`` decomposition exact in-sample while the *shares*
show whether a run was communication- or compute-dominated.

Interpretation guardrails (also in ``src/repro/obs/README.md``):

* the fit needs h to vary across spans (different sizes/mixes/rungs);
  with < 2 samples or constant h it returns ``ok=False`` and NaNs;
* g and L are clamped at 0 for reporting — tiny negative values are
  regression noise, not negative latency;
* ``r2`` is the fit's in-sample explanatory power; low r2 means the run
  was dominated by h-independent variance (compile, host work).

The load-imbalance metric (max/mean received keys per proc, from the same
route spans) directly tests the paper's balance claim: for balanced inputs
it must stay within the whp bound ``1 + theoretical_max_imbalance(cfg)``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class GLFit:
    """Least-squares (g, L) estimate over traced route spans."""

    g_s_per_word: float  # effective comm gap, seconds per 32-bit word
    l_s: float  # effective per-superstep fixed cost, seconds
    n_samples: int
    r2: float  # in-sample R^2 of wall ~ g*h + L*s
    ok: bool  # enough spread in h to identify g

    def predict_s(self, h_words: float, supersteps: float) -> float:
        return self.g_s_per_word * h_words + self.l_s * supersteps


def fit_gl(route_spans: Sequence[Dict]) -> GLFit:
    """Fit ``wall = g·h + L·s`` over route spans (see module docstring)."""
    rows = [
        (float(s["args"]["h_words"]), float(s["args"]["supersteps"]), float(s["dur"]))
        for s in route_spans
        if "h_words" in s.get("args", {}) and "supersteps" in s.get("args", {})
    ]
    if len(rows) < 2:
        return GLFit(float("nan"), float("nan"), len(rows), float("nan"), False)
    a = np.array([[h, ss] for h, ss, _ in rows], np.float64)
    b = np.array([w for _, _, w in rows], np.float64)
    if np.ptp(a[:, 0]) <= 0:  # constant h: g unidentifiable
        return GLFit(float("nan"), float("nan"), len(rows), float("nan"), False)
    sol, *_ = np.linalg.lstsq(a, b, rcond=None)
    pred = a @ sol
    ss_res = float(np.sum((b - pred) ** 2))
    ss_tot = float(np.sum((b - b.mean()) ** 2))
    r2 = 1.0 - ss_res / ss_tot if ss_tot > 0 else 1.0
    g, l = (max(0.0, float(v)) for v in sol)
    return GLFit(g, l, len(rows), r2, True)


def imbalance_of(counts: np.ndarray) -> float:
    """max/mean received keys per proc — the paper's balance metric."""
    counts = np.asarray(counts, np.float64)
    mean = counts.mean()
    if mean <= 0:
        return 1.0
    return float(counts.max() / mean)


def cost_report(tracer) -> Dict:
    """Per-run BSP cost report: fitted (g, L) + per-superstep rows.

    Each route span becomes one row comparing its measured wall against the
    fitted ``w + g·h + L·s`` (w = residual local-work share, clamped at 0);
    the header carries the fit and the worst load imbalance. JSON-able.
    """
    fit = fit_gl(tracer.route_spans())
    rows: List[Dict] = []
    worst_imb: Optional[float] = None
    for s in tracer.route_spans():
        args = s["args"]
        h = float(args.get("h_words", float("nan")))
        ss = float(args.get("supersteps", float("nan")))
        measured = float(s["dur"])
        comm = fit.predict_s(h, ss) if fit.ok else float("nan")
        w = max(0.0, measured - comm) if fit.ok else float("nan")
        imb = args.get("imbalance")
        if imb is not None:
            worst_imb = imb if worst_imb is None else max(worst_imb, imb)
        rows.append(
            {
                "tid": s["tid"],
                "tier": args.get("tier"),
                "rung": args.get("rung"),
                "h_words": h,
                "supersteps": ss,
                "measured_s": round(measured, 6),
                "pred_comm_s": round(comm, 6) if not math.isnan(comm) else None,
                "w_resid_s": round(w, 6) if not math.isnan(w) else None,
                "imbalance": imb,
                "recv_max": args.get("recv_max"),
                "recv_mean": args.get("recv_mean"),
            }
        )
    return {
        "fit": {
            "g_s_per_word": fit.g_s_per_word,
            "l_s": fit.l_s,
            "n_samples": fit.n_samples,
            "r2": fit.r2,
            "ok": fit.ok,
        },
        "max_imbalance": worst_imb,
        "supersteps": rows,
    }
