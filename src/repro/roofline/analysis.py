"""Roofline term derivation from the compiled dry-run artifact.

Three terms per (arch × shape × mesh), in seconds (§Roofline):

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = Σ collective_bytes_per_device / ICI_BW

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (we charge the aggregate of one link; multi-link overlap is a schedule
property the §Perf loop exploits, not an accounting assumption).

`cost_analysis` caveat (measured, see EXPERIMENTS.md §Dry-run notes): XLA
counts a `while` (scan-over-layers) body ONCE. We therefore scale
flops/bytes/collectives by the scan trip count parsed from the HLO when the
known-trip-count pattern is detectable, and always report the analytic
MODEL_FLOPS = 6·N_active·D alongside (their ratio flags both remat recompute
and undercounting).

Collective bytes are parsed from the post-SPMD optimized HLO text: per op we
take operand bytes × a schedule factor (ring algorithms):
    all-gather: (g-1)·operand   (operand = per-device shard; g = group size)
    reduce-scatter: operand·(g-1)/g
    all-reduce: 2·operand·(g-1)/g
    all-to-all / collective-permute: operand
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Optional

import numpy as np

# TPU v5e per-chip constants
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "bf16": 2,
    "f16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
    "c64": 8,
}

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*?\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")


def _shape_bytes(tok_dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(tok_dtype, 4)


def _split_computations(hlo_text: str) -> Dict[str, list]:
    """computation name -> list of op lines (flat text parse)."""
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if line.lstrip().startswith("ENTRY"):
                comps["__entry__"] = comps[cur]
                comps.setdefault("__entry_name__", []).append(cur)
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _execution_multipliers(comps: Dict[str, list]) -> Dict[str, float]:
    """Times each computation executes per step (while trip counts compose)."""
    entry = comps.get("__entry_name__", [None])[0]
    mult: Dict[str, float] = defaultdict(float)
    if entry is None:
        return defaultdict(lambda: 1.0)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    while order:
        c = order.pop(0)
        for line in comps.get(c, []):
            trip = 1.0
            tm = _TRIP_RE.search(line)
            if tm and " while(" in line:
                trip = float(tm.group(1))
            callees = []
            bm = _BODY_RE.search(line)
            if bm:
                callees.append((bm.group(1), trip))
            cm = _COND_RE.search(line)
            if cm:
                callees.append((cm.group(1), trip))
            am = _CALL_RE.search(line)
            if am:
                callees.append((am.group(1), 1.0))
            for name, t in callees:
                if name in comps:
                    mult[name] += mult[c] * t
                    if name not in seen:
                        seen.add(name)
                        order.append(name)
    return mult


def _line_collective_bytes(line: str, default_group: int):
    """Moved-bytes estimate from the op's RESULT type (operands print as
    bare names in optimized HLO). Ring-schedule factors per kind."""
    m = _COLL_RE.match(line)
    if not m or "-done(" in line:
        return None
    kind = m.group(2)
    shapes = _SHAPE_RE.findall(m.group(1))  # the result type segment
    result_bytes = sum(_shape_bytes(d, s) for d, s in shapes)
    g = default_group
    gm = _GROUPS_RE.search(line)
    gi = _GROUPS_IOTA_RE.search(line)
    if gm:
        ids = [x for x in gm.group(1).split(",") if x.strip() != ""]
        g = max(len(ids), 1)
    elif gi:
        g = max(int(gi.group(2)), 1)  # replica_groups=[n_groups,group_size]
    if g <= 1:
        return kind, 0.0
    if kind == "all-gather":
        moved = result_bytes * (g - 1) / g  # result = full gathered array
    elif kind == "all-reduce":
        moved = 2.0 * result_bytes * (g - 1) / g
    elif kind == "reduce-scatter":
        moved = result_bytes * (g - 1)  # result = 1/g of the input
    elif kind == "all-to-all":
        moved = result_bytes * (g - 1) / g
    else:  # collective-permute
        moved = result_bytes
    return kind, moved


def parse_collective_bytes(hlo_text: str, default_group: int) -> Dict[str, float]:
    """Per-device collective bytes by op kind, schedule-factored and scaled
    by while-loop trip counts (scan bodies execute L times, not once)."""
    comps = _split_computations(hlo_text)
    mult = _execution_multipliers(comps)
    out: Dict[str, float] = defaultdict(float)
    for name, lines in comps.items():
        if name.startswith("__entry"):
            continue
        f = mult.get(name, 1.0) or 1.0
        for line in lines:
            r = _line_collective_bytes(line, default_group)
            if r:
                out[r[0]] += r[1] * f
    return dict(out)


_DOT_RE = re.compile(r"=\s*(\S+)\s+dot\(")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OPERANDS_RE = re.compile(r"dot\(([^)]*)\)")


def _op_shapes(hlo_text: str) -> Dict[str, tuple]:
    """op name -> (dtype, dims list) from every definition line.

    Names are normalised without the ``%`` sigil — optimized dumps print
    typed operands (``dot(f32[128,128]{1,0} %Arg_0.1, ...)``) while the
    synthetic fixtures use bare ``%name``; both resolve through one map.
    """
    out = {}
    for line in hlo_text.splitlines():
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        rest = line[dm.end() :]
        sm = _SHAPE_RE.match(rest.strip())
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            out[dm.group(1)] = (sm.group(1), dims)
    return out


def _operand_names(arg_text: str) -> list:
    """Operand names from a ``dot(...)`` argument list.

    Splits only at bracket-depth-0 commas — shape dims (``f32[128,128]``)
    and layouts (``{1,0}``) contain commas of their own — then takes each
    operand's trailing token, ``%`` stripped.
    """
    parts, cur, depth = [], [], 0
    for ch in arg_text:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        elif ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
            continue
        cur.append(ch)
    parts.append("".join(cur))
    return [p.strip().split(" ")[-1].lstrip("%") for p in parts if p.strip()]


def parse_dot_stats(hlo_text: str) -> Dict[str, float]:
    """Exact per-device matmul FLOPs and HBM traffic from the optimized HLO.

    flops(dot) = 2 · prod(result dims) · prod(lhs contracting dims), each op
    scaled by its computation's execution multiplier (while trip counts).
    Operand shapes are resolved through a name→type map (operands print as
    bare names). bytes = operands + result of every dot — a lower-bound HBM
    traffic proxy for matmul-dominated graphs. This is the trip-correct
    counterpart of `cost_analysis`, which prices a while body once.
    """
    comps = _split_computations(hlo_text)
    mult = _execution_multipliers(comps)
    shapes_by_name = _op_shapes(hlo_text)
    flops = 0.0
    bytes_ = 0.0
    for name, lines in comps.items():
        if name.startswith("__entry"):
            continue
        f = mult.get(name, 1.0) or 1.0
        for line in lines:
            dm = _DOT_RE.search(line)
            if not dm:
                continue
            res = _SHAPE_RE.search(line.split("=", 1)[-1])
            if not res:
                continue
            res_dims = [int(d) for d in res.group(2).split(",") if d]
            res_n = float(np.prod(res_dims)) if res_dims else 1.0
            om = _OPERANDS_RE.search(line)
            lhs_shape = None
            op_bytes = _shape_bytes(res.group(1), res.group(2))
            if om:
                names = _operand_names(om.group(1))
                for i, nm in enumerate(names[:2]):
                    sh = shapes_by_name.get(nm)
                    if sh:
                        op_bytes += _shape_bytes(sh[0], ",".join(map(str, sh[1])))
                        if i == 0:
                            lhs_shape = sh[1]
            k = 1.0
            cm = _LHS_C_RE.search(line)
            if cm and lhs_shape:
                for c in cm.group(1).split(","):
                    if c != "" and int(c) < len(lhs_shape):
                        k *= lhs_shape[int(c)]
            flops += f * 2.0 * res_n * k
            bytes_ += f * op_bytes
    return {"dot_flops": flops, "dot_bytes": bytes_}


def scan_trip_factor(hlo_text: str) -> float:
    """Largest known trip count of any while loop (scan-over-layers)."""
    trips = [int(t) for t in _TRIP_RE.findall(hlo_text)]
    return float(max(trips)) if trips else 1.0


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N_active·D tokens for train, 2·N_active·D for
    inference (per generated/prefilled token)."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * n_active * tokens


def analyze_compiled(compiled, *, mesh, cfg, shape) -> Dict:
    n_dev = int(np.prod(list(mesh.shape.values())))
    info: Dict = {"devices": n_dev}

    # ---- memory analysis (per device)
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            info["mem_args_gb"] = round(ma.argument_size_in_bytes / 2**30, 3)
            info["mem_output_gb"] = round(ma.output_size_in_bytes / 2**30, 3)
            info["mem_temp_gb"] = round(ma.temp_size_in_bytes / 2**30, 3)
            info["mem_total_gb"] = round(
                (
                    ma.argument_size_in_bytes
                    + ma.output_size_in_bytes
                    + ma.temp_size_in_bytes
                )
                / 2**30,
                3,
            )
    except Exception as e:  # CPU backend may not implement it
        info["mem_note"] = f"memory_analysis unavailable: {type(e).__name__}"

    # ---- cost analysis
    flops = bytes_accessed = None
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        if ca:
            flops = float(ca.get("flops", 0.0))
            bytes_accessed = float(ca.get("bytes accessed", 0.0))
    except Exception as e:
        info["cost_note"] = f"cost_analysis unavailable: {type(e).__name__}"

    # ---- HLO text: collectives + scan trip correction
    try:
        text = compiled.as_text()
    except Exception:
        text = ""
    trip = scan_trip_factor(text)
    coll = parse_collective_bytes(text, default_group=mesh.shape.get("model", 1))
    coll_total = sum(coll.values())
    dots = parse_dot_stats(text)

    info["hlo_flops_per_dev"] = flops  # cost_analysis (while bodies ×1)
    info["hlo_bytes_per_dev"] = bytes_accessed
    info["dot_flops_per_dev"] = dots["dot_flops"]  # trip-corrected
    info["dot_bytes_per_dev"] = dots["dot_bytes"]
    info["scan_trip"] = trip
    info["collectives"] = {k: round(v / 2**20, 2) for k, v in coll.items()}
    info["collective_mb_per_dev"] = round(coll_total / 2**20, 2)

    mf = model_flops(cfg, shape)
    info["model_flops_total"] = mf
    per_dev_model = mf / n_dev

    # roofline terms (seconds)
    t_compute = max(dots["dot_flops"], flops or 0.0) / PEAK_FLOPS
    t_compute_model = per_dev_model / PEAK_FLOPS
    t_memory = max(dots["dot_bytes"], bytes_accessed or 0.0) / HBM_BW
    t_coll = coll_total / ICI_BW
    info["t_compute_s"] = t_compute
    info["t_compute_model_s"] = t_compute_model
    info["t_memory_s"] = t_memory
    info["t_collective_s"] = t_coll
    terms = {
        "compute": max(t_compute, t_compute_model),
        "memory": t_memory,
        "collective": t_coll,
    }
    info["dominant"] = max(terms, key=terms.get)
    if dots["dot_flops"]:
        info["useful_flops_ratio"] = round(per_dev_model / dots["dot_flops"], 4)
    # roofline fraction: useful work time over the achievable bound (sum of
    # terms — conservative no-overlap model; overlap is a §Perf lever)
    bound = t_compute + t_memory + t_coll
    if bound > 0:
        info["roofline_fraction"] = round(t_compute_model / bound, 4)
    return info
