from repro.roofline.analysis import (  # noqa: F401
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    analyze_compiled,
    model_flops,
    parse_collective_bytes,
)
