"""Delta sort — incremental sorted-view maintenance (ROADMAP item 3).

Near-sorted streams (admission queues, length buckets, leaderboards)
don't pay the full O(n log n) ladder: only the out-of-place Δ routes
through the fused h-relation, and one rank merge folds it into the
standing run. See ``fold.py`` for the composite-lift construction that
makes the result byte-identical to a cold sort, ``view.py`` for the
stateful ``SortedView`` (folds + §5.1.1 tombstones), and ``README.md``
for the lifecycle and cost model.
"""
from .fold import (
    InFlightDeltaSort,
    drop_positions,
    lift_positions,
    merge_sorted_runs,
    near_sorted_sort,
    near_sorted_sort_launch,
    split_sorted_run,
)
from .view import SortedView

__all__ = [
    "InFlightDeltaSort",
    "SortedView",
    "drop_positions",
    "lift_positions",
    "merge_sorted_runs",
    "near_sorted_sort",
    "near_sorted_sort_launch",
    "split_sorted_run",
]
