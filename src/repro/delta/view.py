"""SortedView — a standing sorted (key, payload) snapshot that folds Δs.

The view is the subsystem's stateful surface: a host-resident sorted run
of int32 keys plus any number of aligned 1-D payload arrays, maintained
incrementally. Two mutation routes, both byte-identical to a cold
``bsp_sort_safe`` of the concatenated history (the stability theorem in
``core/types.py``: every tier is stable and equal keys keep first-seen
order, so [sorted view ++ stably-sorted Δ] merged view-first-on-ties IS
the stable sort of the concatenation):

* ``fold`` — the Δ batch is stably sorted through the existing fused
  h-relation at a Δ-sized ``(p, Δ/p)`` layout (exact pair capacity: the
  capacity rung is bounded by Δ, not n, and can never retry), then
  rank-merged into the view with ``core/merge._rank_merge_two`` — one
  ``rank_in`` + gathers, payloads riding the same permutation. Cost
  O(Δ log Δ) device + O(n) merge vs the cold ladder's O(n log n).
* ``resort`` — concatenate and run the ordinary segmented ladder; taken
  when Δ is a large share of the result (``fold_max_share``) and folding
  would approach resort cost anyway.

Deletions and updates ride as **tombstones** reusing the §5.1.1 tag
trick: duplicate tombstone values are lifted to distinct (value,
occurrence) composites — ``occ = arange - searchsorted(t, t, 'left')`` —
so the k-th tombstone of value v targets the k-th live occurrence of v
in the view, found with two binary searches and applied as one masked
compaction (delete) or one scatter (update). Misses (tombstones for
absent keys) are counted, never fatal.

Robustness: every fold's merged run is validated for monotonicity; a
corrupted Δ (injected via ``repro.chaos`` fold corruption, or organic)
triggers a fallback resort from the preserved pre-fold state
(``delta.fold_fallback_resorts``) — the view is byte-identical to the
cold sort either way.

Observability: ``delta.folds`` / ``delta.resorts`` / ``delta.tombstones``
/ ``delta.tombstone_misses`` counters per view label in the unified
registry, and ``fold`` spans (cat="delta") with traced Δ/n share when a
tracer is attached — the inner Δ sort's route spans feed the (g, L)
machine fit like any other sort.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core import TierStats
from repro.core.api import SortExecutor
from repro.core.segmented import pack_segments, segmented_sort_safe

from .fold import merge_sorted_runs

__all__ = ["SortedView"]


class SortedView:
    """A sorted (key, payload) snapshot maintained by Δ folds.

    ``p``/``min_n_per_proc`` fix the mesh-sharded layout every device pass
    (Δ sort or resort) uses; ``executor``/``stats`` are shared with the
    owning service so compiled programs and retry telemetry pool. The view
    itself lives on host between folds — it is the *output* of a sort, and
    the device only ever sees Δ-sized work.
    """

    def __init__(
        self,
        *,
        p: int = 8,
        min_n_per_proc: int = 8,
        executor: Optional[SortExecutor] = None,
        stats: Optional[TierStats] = None,
        obs_handle=None,
        chaos_handle=None,
        label: Optional[str] = None,
        fold_max_share: float = 0.25,
        merge_backend: str = "xla",
    ) -> None:
        self.p = p
        self.min_n_per_proc = min_n_per_proc
        self.executor = executor
        self.stats = stats if stats is not None else TierStats()
        self.fold_max_share = fold_max_share
        self.merge_backend = merge_backend
        self.label = label if label is not None else obs.next_instance("view")
        self.keys = np.zeros(0, np.int32)
        self.payloads: List[np.ndarray] = []
        self._n_payloads: Optional[int] = None
        self.last_tier: Optional[str] = None
        self.last_n_per_proc = min_n_per_proc
        self._obs_handle = obs_handle
        self._tracer = obs.resolve_tracer(obs_handle)
        # chaos: fold-corruption injection (repro.chaos.FaultPlan or None);
        # imported lazily by the resolver at the service layer — the view
        # only calls corrupt_fold/next_fold, duck-typed like the tracer
        self._chaos_handle = chaos_handle
        reg = obs.metrics()
        self._folds = reg.counter("delta.folds", view=self.label)
        self._resorts = reg.counter("delta.resorts", view=self.label)
        self._fold_fallbacks = reg.counter(
            "delta.fold_fallback_resorts", view=self.label
        )
        self._tombstones = reg.counter("delta.tombstones", view=self.label)
        self._tombstone_misses = reg.counter(
            "delta.tombstone_misses", view=self.label
        )

    # ------------------------------------------------------------ basics
    @property
    def n(self) -> int:
        return int(self.keys.size)

    def _coerce(self, delta_keys, delta_payloads):
        arr = np.asarray(delta_keys, np.int32).reshape(-1)
        pls = [np.asarray(v) for v in delta_payloads]
        if self._n_payloads is None:
            self._n_payloads = len(pls)
            if not self.payloads:
                self.payloads = [np.zeros(0, v.dtype) for v in pls]
        elif len(pls) != self._n_payloads:
            raise ValueError(
                f"view carries {self._n_payloads} payload(s), "
                f"fold brought {len(pls)}"
            )
        return arr, pls

    def install(self, keys, payloads: Sequence[np.ndarray] = ()) -> None:
        """Adopt an already-sorted snapshot without a device pass.

        For callers that just ran the batch path (e.g. the serve engine's
        admission sort) and hold its output: installing is free and the
        view takes over from there with folds/tombstones.
        """
        arr, pls = self._coerce(keys, payloads)
        if arr.size and np.any(arr[1:] < arr[:-1]):
            raise ValueError("install requires sorted keys")
        self.keys = arr
        self.payloads = pls

    def clone(self) -> "SortedView":
        """Copy of the snapshot sharing executor/stats/label (same family)."""
        c = SortedView(
            p=self.p, min_n_per_proc=self.min_n_per_proc,
            executor=self.executor, stats=self.stats,
            obs_handle=self._obs_handle, chaos_handle=self._chaos_handle,
            label=self.label,
            fold_max_share=self.fold_max_share,
            merge_backend=self.merge_backend,
        )
        c.keys = self.keys.copy()
        c.payloads = [np.array(v) for v in self.payloads]
        c._n_payloads = self._n_payloads
        c.last_tier = self.last_tier
        c.last_n_per_proc = self.last_n_per_proc
        return c

    # -------------------------------------------------------------- fold
    def _device_sort(self, arr: np.ndarray):
        """Stably sort a host batch through the fused h-relation (exact)."""
        packed = pack_segments(
            [arr], self.p, min_n_per_proc=self.min_n_per_proc
        )
        res = segmented_sort_safe(
            packed, stats=self.stats, executor=self.executor,
            pair_capacity="exact", obs=self._obs_handle,
        )
        return res.keys[0], res.order[0], res

    def fold(self, delta_keys, delta_payloads: Sequence[np.ndarray] = (),
             *, route: Optional[str] = None) -> str:
        """Merge a Δ batch in; returns the route taken (``fold``/``resort``).

        Output state is byte-identical either way — ``route`` (and the
        ``fold_max_share`` auto-decision it overrides) is purely a cost
        choice. The first fold into an empty view is charged as a resort
        (there is no standing run to rank against yet).
        """
        arr, pls = self._coerce(delta_keys, delta_payloads)
        dn, n = int(arr.size), self.n
        if route is None:
            route = (
                "fold"
                if n and dn <= self.fold_max_share * (n + dn)
                else "resort"
            )
        if route not in ("fold", "resort"):
            raise ValueError(f"unknown fold route {route!r}")
        t0 = self._tracer.now() if self._tracer is not None else 0.0
        if route == "resort":
            cat_k = np.concatenate([self.keys, arr])
            cat_v = [
                np.concatenate([old, new])
                for old, new in zip(self.payloads, pls)
            ]
            if cat_k.size:
                k, order, res = self._device_sort(cat_k)
                self.keys = k
                self.payloads = [cv[order] for cv in cat_v]
                self.last_tier = res.tier
                self.last_n_per_proc = res.n_per_proc
            self._resorts.inc()
        else:
            fell_back = False
            if dn:
                dk, dorder, res = self._device_sort(arr)
                dvs = [v[dorder] for v in pls]
                ch = self._chaos_handle
                if ch is not None and ch.corrupt_fold(ch.next_fold()):
                    # injected corruption: clobber the sorted Δ run the way
                    # a bad fold input would look (reversed run) — the
                    # validation below must catch it
                    dk = dk[::-1].copy()
                merged, vout = merge_sorted_runs(
                    self.keys, dk, self.payloads, dvs,
                    backend=self.merge_backend,
                )
                if merged.size and np.any(merged[1:] < merged[:-1]):
                    # merged run is not sorted: a corrupted fold input
                    # (injected or organic) slipped through. The pre-fold
                    # state is still unmutated — fall back to a full
                    # resort of the concatenated history, so the view
                    # stays byte-identical to the cold sort either way.
                    fell_back = True
                    self._fold_fallbacks.inc()
                    if self._tracer is not None:
                        self._tracer.point(
                            "fold_corruption_fallback", cat="chaos",
                            tid="main", delta_n=dn, view_n=n,
                        )
                    cat_k = np.concatenate([self.keys, arr])
                    cat_v = [
                        np.concatenate([old, new])
                        for old, new in zip(self.payloads, pls)
                    ]
                    k, order, res = self._device_sort(cat_k)
                    self.keys = k
                    self.payloads = [cv[order] for cv in cat_v]
                    self.last_tier = res.tier
                    self.last_n_per_proc = res.n_per_proc
                    self._resorts.inc()
                    route = "resort"
                else:
                    self.keys = merged
                    self.payloads = vout
                    self.last_n_per_proc = res.n_per_proc
            if not fell_back:
                self.last_tier = "delta"
                self._folds.inc()
        if self._tracer is not None:
            self._tracer.add_span(
                "fold", t0, cat="delta", tid="main", route=route,
                delta_n=dn, view_n=n,
                share=round(dn / max(n + dn, 1), 4),
            )
        return route

    # -------------------------------------------------------- tombstones
    def _targets(self, t: np.ndarray):
        """View indices hit by sorted tombstone values (§5.1.1 occurrence tags)."""
        base = np.searchsorted(self.keys, t, side="left")
        hi = np.searchsorted(self.keys, t, side="right")
        occ = np.arange(t.size) - np.searchsorted(t, t, side="left")
        tgt = base + occ
        ok = tgt < hi
        return tgt, ok

    def delete(self, keys) -> int:
        """Tombstone-delete: k-th tombstone of v removes the k-th live v.

        Returns the number of keys actually removed; tombstones with no
        remaining occurrence count as misses (``delta.tombstone_misses``).
        """
        t = np.sort(np.asarray(keys, np.int32).reshape(-1))
        if t.size == 0:
            return 0
        t0 = self._tracer.now() if self._tracer is not None else 0.0
        tgt, ok = self._targets(t)
        removed = tgt[ok]
        if removed.size:
            mask = np.ones(self.n, bool)
            mask[removed] = False
            self.keys = self.keys[mask]
            self.payloads = [v[mask] for v in self.payloads]
        self._tombstones.inc(int(removed.size))
        self._tombstone_misses.inc(int(t.size - removed.size))
        if self._tracer is not None:
            self._tracer.add_span(
                "tombstone", t0, cat="delta", tid="main", op="delete",
                hits=int(removed.size), misses=int(t.size - removed.size),
            )
        return int(removed.size)

    def update(self, keys, payloads: Sequence[np.ndarray]) -> int:
        """Tombstone-update: rewrite payloads in place, positions untouched.

        Same occurrence-tagged targeting as :meth:`delete`; an update
        never moves a key, so stable order (and fold byte-identity going
        forward) is preserved. Returns the hit count.
        """
        t_in = np.asarray(keys, np.int32).reshape(-1)
        pls = [np.asarray(v) for v in payloads]
        if len(pls) != (self._n_payloads or 0):
            raise ValueError(
                f"view carries {self._n_payloads or 0} payload(s), "
                f"update brought {len(pls)}"
            )
        if t_in.size == 0:
            return 0
        perm = np.argsort(t_in, kind="stable")
        t = t_in[perm]
        tgt, ok = self._targets(t)
        hits = int(np.count_nonzero(ok))
        if hits:
            self.payloads = [
                v if v.flags.writeable else v.copy() for v in self.payloads
            ]
            for v, nv in zip(self.payloads, pls):
                v[tgt[ok]] = nv[perm][ok]
        self._tombstones.inc(hits)
        self._tombstone_misses.inc(int(t.size - hits))
        return hits

    def pop_min(self) -> Tuple[int, Tuple]:
        """Remove and return the front (min-key) entry and its payloads."""
        if self.n == 0:
            raise IndexError("pop_min from an empty SortedView")
        k = int(self.keys[0])
        vals = tuple(v[0] for v in self.payloads)
        self.keys = self.keys[1:]
        self.payloads = [v[1:] for v in self.payloads]
        self._tombstones.inc()
        return k, vals
