"""Fold machinery — rank-merging a Δ batch into a standing sorted run.

The paper's §5.1.1 tag trick makes every comparison a total order by
lifting keys to composites whose low bits carry a position tag. The same
lift turns *incremental* sorting into a closed-form fold: for a stream
that is already sorted except for Δ out-of-place keys,

    comp = (key + 2^31) << 31 | original_position        (int64)

is strictly increasing over the in-place subsequence, distinct everywhere,
and ordered exactly like the stable sort of the raw stream — so merging
the kept run with the Δ run on composites reproduces the full stable
argsort *by construction* (the low bits of the merged sequence ARE the
argsort). No tie-breaking argument is needed and byte-identity with a cold
``bsp_sort_safe`` of the whole stream is structural, not probabilistic.

Three pieces live here:

* :func:`split_sorted_run` — O(n) host scan extracting the out-of-place Δ
  from a near-sorted stream. Two vectorized passes: drop elements that
  break order with a neighbour (catches scattered updates *before* they
  can poison a running max), then a record-high filter on the remainder
  (catches rotated blocks / appended tails). The kept subsequence is
  non-decreasing by construction; the split quality only affects *cost*
  (a pessimal split degenerates to Δ ≈ n and the fold still sorts
  correctly), never correctness.
* :func:`merge_sorted_runs` — the jitted rank-merge tail
  (:func:`repro.core.merge._rank_merge_two` — one ``rank_in`` + gathers,
  payload-generic). Runs are padded to power-of-two widths so the compile
  cache stays O(log n) across arbitrary view growth; the output width is
  quantized in eighth-of-run steps so pad slots, not valid keys, absorb
  the rounding.
* :func:`near_sorted_sort_launch` — the planner-routed request path: split
  the stream, route ONLY the Δ composites through the fused h-relation (a
  ``(p, Δ/p)`` layout — every capacity rung is Δ-sized by construction,
  and the exact pair capacity makes retries impossible), then one rank
  merge against the kept run. Work: O(n) host scan + O(Δ log Δ) device
  sort + O((n+Δ) log n) rank merge — versus the cold ladder's full
  O(n log n) device sort plus its collectives.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro import obs
from repro.core import TierStats
from repro.core.api import (
    InFlightSort,
    SortExecutor,
    bsp_sort_safe_launch,
    gathered_output,
)
from repro.core.merge import _rank_merge_two
from repro.core.segmented import SegmentedResult, _pow2_n_per_proc, contiguous_lane_sizes
from repro.core.types import SortConfig, round_up, sentinel_for

#: low bits of the fold composite holding the original position; the
#: (biased) int32 key sits above. 31 + 32 bits keeps the composite inside
#: positive int64 with the int64-max sentinel strictly past every real
#: composite (positions are bounded by the int32 index space anyway).
POS_BITS = 31
POS_MASK = (np.int64(1) << POS_BITS) - 1
_KEY_BIAS = np.int64(1) << 31


def lift_positions(keys: np.ndarray, pos: np.ndarray) -> np.ndarray:
    """(int32 key, position) -> order-preserving int64 fold composites.

    Strictly increasing in lexicographic (key, pos) — i.e. ordered exactly
    like the stable sort of ``keys`` — and all distinct, so a merge of two
    lifted runs needs no tie-break rule at all.
    """
    k = np.asarray(keys, np.int64)
    p = np.asarray(pos, np.int64)
    assert p.size == 0 or int(p.max()) < (1 << POS_BITS) - 1
    return ((k + _KEY_BIAS) << POS_BITS) | p


def drop_positions(comp: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Invert :func:`lift_positions`: composites -> (int32 keys, positions)."""
    comp = np.asarray(comp, np.int64)
    keys = ((comp >> POS_BITS) - _KEY_BIAS).astype(np.int32)
    return keys, (comp & POS_MASK).astype(np.int32)


def split_sorted_run(keys: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Index split of a stream into (kept sorted run, out-of-place Δ).

    Pass 1 drops every element that violates order with a neighbour —
    scattered in-place updates (a huge value planted early) are removed
    *here*, before they can become the running max and condemn everything
    after them. Pass 2 keeps the record highs of the remainder, which
    handles the patterns pass 1 is blind to (a rotated leading block is
    locally sorted but globally displaced). ``keys[kept]`` is always
    non-decreasing; for the appended/scattered/rotated families the Δ side
    is O(true Δ) (at most ~2 extractions per displaced key).
    """
    k = np.asarray(keys).reshape(-1)
    n = k.shape[0]
    if n == 0:
        e = np.zeros(0, np.int64)
        return e, e.copy()
    prev_ok = np.empty(n, bool)
    prev_ok[0] = True
    np.greater_equal(k[1:], k[:-1], out=prev_ok[1:])
    next_ok = np.empty(n, bool)
    next_ok[-1] = True
    np.less_equal(k[:-1], k[1:], out=next_ok[:-1])
    idx = np.flatnonzero(prev_ok & next_ok)
    sub = k[idx]
    if sub.size:
        kept_idx = idx[sub >= np.maximum.accumulate(sub)]  # record highs
    else:
        kept_idx = idx
    mask = np.zeros(n, bool)
    mask[kept_idx] = True
    return kept_idx.astype(np.int64), np.flatnonzero(~mask).astype(np.int64)


def _pow2_width(n: int, floor: int = 8) -> int:
    return max(floor, 1 << max(0, int(n) - 1).bit_length())


@functools.lru_cache(maxsize=None)
def _merge_fn(wa: int, wb: int, w_out: int, nv: int, backend: str):
    """Jitted two-run rank merge at fixed padded widths (+ nv payloads)."""

    def f(ka, ca, kb, cb, *vals):
        sent = sentinel_for(ka.dtype)
        out, vout, cnt = _rank_merge_two(
            ka, ca, kb, cb, sent, vals[:nv], vals[nv:], backend=backend,
            w_out=w_out,
        )
        return (out, *vout, cnt)

    return jax.jit(f)


def merge_sorted_runs(
    a_keys: np.ndarray,
    b_keys: np.ndarray,
    a_vals: Sequence[np.ndarray] = (),
    b_vals: Sequence[np.ndarray] = (),
    *,
    backend: str = "xla",
) -> Tuple[np.ndarray, List[np.ndarray]]:
    """Stable merge of two sorted host runs (a first on ties) + payloads.

    One jitted rank computation on the keys; every payload rides the same
    gather (`core/merge` semantics). Inputs are padded to pow2 widths and
    the output width is quantized (eighth-of-run steps), so the jit cache
    holds O(log n) programs however the view grows. int64 runs (the fold
    composites) enter under ``enable_x64`` — the repo otherwise runs
    32-bit, and an unscoped transfer would truncate them.
    """
    a = np.asarray(a_keys).reshape(-1)
    b = np.asarray(b_keys).reshape(-1)
    assert a.dtype == b.dtype and len(a_vals) == len(b_vals)
    na, nb = int(a.size), int(b.size)
    if nb == 0:
        return a.copy(), [np.asarray(v).copy() for v in a_vals]
    if na == 0:
        return b.copy(), [np.asarray(v).copy() for v in b_vals]
    wa, wb = _pow2_width(na), _pow2_width(nb)
    step = max(64, wa // 8)
    w_out = min(wa + wb, round_up(na + nb, step))
    # host-side pad value; the jnp sentinel_for would truncate int64 when
    # built outside the enable_x64 scope
    if np.issubdtype(a.dtype, np.integer):
        sent = np.iinfo(a.dtype).max
    else:
        sent = np.inf
    ka = np.full(wa, sent, a.dtype)
    ka[:na] = a
    kb = np.full(wb, sent, b.dtype)
    kb[:nb] = b
    vals = []
    for av, bv in zip(a_vals, b_vals):
        av, bv = np.asarray(av), np.asarray(bv)
        pa = np.zeros((wa,) + av.shape[1:], av.dtype)
        pa[:na] = av
        pb = np.zeros((wb,) + bv.shape[1:], bv.dtype)
        pb[:nb] = bv
        vals += [pa, pb]
    # interleaved (a, b) pairs -> (a..., b...) argument order
    va, vb = vals[0::2], vals[1::2]
    fn = _merge_fn(wa, wb, w_out, len(va), backend)
    scope = enable_x64 if a.dtype == np.int64 else contextlib.nullcontext
    with scope():
        out = fn(
            jnp.asarray(ka), np.int32(na), jnp.asarray(kb), np.int32(nb),
            *[jnp.asarray(v) for v in va], *[jnp.asarray(v) for v in vb],
        )
    merged = np.asarray(out[0])[: na + nb]
    vout = [np.asarray(v)[: na + nb] for v in out[1:-1]]
    return merged, vout


def sort_delta_comps_launch(
    comp: np.ndarray,
    p: int,
    *,
    min_n_per_proc: int = 8,
    executor: Optional[SortExecutor] = None,
    stats: Optional[TierStats] = None,
    obs_handle=None,
) -> Tuple[Optional[InFlightSort], int]:
    """Launch the Δ composites through the fused h-relation (non-blocking).

    The Δ batch gets its own ``(p, n_p)`` layout sized to Δ — every
    capacity rung of the launched sort is Δ-bounded, not n-bounded — and
    runs at the *exact* pair capacity, so the fold can never retry (the
    bench table's zero-retry identity column). Pads are the int64
    sentinel; the composites are distinct and strictly below it, so the
    valid Δ prefix of the gathered output is exact. Returns
    ``(flight | None, n_per_proc)``.
    """
    dn = int(comp.size)
    if dn == 0:
        return None, min_n_per_proc
    n_p = _pow2_n_per_proc(dn, p, min_n_per_proc)
    rows = np.full((p, n_p), np.iinfo(np.int64).max, np.int64)
    off = 0
    for k, c in enumerate(contiguous_lane_sizes(dn, p)):
        rows[k, :c] = comp[off : off + c]
        off += c
    cfg = SortConfig(
        p=p, n_per_proc=n_p, algorithm="iran", pair_capacity="exact",
        obs=obs_handle,
    )
    with enable_x64():
        x = jnp.asarray(rows)
    flight = bsp_sort_safe_launch(
        x, cfg, stats=stats, executor=executor, scope=enable_x64
    )
    return flight, n_p


@dataclasses.dataclass
class InFlightDeltaSort:
    """A dispatched near-sorted request awaiting its Δ sort + fold.

    The host split is done and the Δ composites' (Δ-sized) sort is in the
    device queue; :meth:`wait` syncs on it, rank-merges the Δ run into the
    kept run, and unlifts composites back to (keys, stable argsort). API-
    compatible with ``InFlightSegmentedSort`` from the dispatcher's side.
    """

    comp_kept: np.ndarray  # lifted kept run, strictly increasing
    n_delta: int
    flight: Optional[InFlightSort]  # None when the stream was fully sorted
    stats: TierStats
    n_per_proc: int  # the Δ sort's pow2 bucket
    tracer: Optional[object] = None
    t_launched: float = 0.0
    backend: str = "xla"

    def done(self) -> bool:
        return self.flight is None or self.flight.done()

    def wait(self) -> SegmentedResult:
        if self.flight is not None:
            res, _, _ = self.flight.wait()
            d = gathered_output(res)[: self.n_delta]
        else:
            d = np.zeros(0, np.int64)
        merged, _ = merge_sorted_runs(
            self.comp_kept, d, backend=self.backend
        )
        keys, order = drop_positions(merged)
        if self.tracer is not None:
            n = keys.size
            self.tracer.add_span(
                "fold",
                self.t_launched,
                cat="delta",
                tid="main",
                delta_n=self.n_delta,
                view_n=n - self.n_delta,
                share=round(self.n_delta / max(n, 1), 4),
                route="delta",
            )
        return SegmentedResult(
            keys=[keys],
            order=[order],
            stats=self.stats,
            tier="delta",
            n_per_proc=self.n_per_proc,
        )


def near_sorted_sort_launch(
    keys: np.ndarray,
    p: int,
    *,
    min_n_per_proc: int = 8,
    executor: Optional[SortExecutor] = None,
    stats: Optional[TierStats] = None,
    obs_handle=None,
    backend: str = "xla",
) -> InFlightDeltaSort:
    """Launch the delta route for one near-sorted request (non-blocking).

    Split → lift → Δ-sized fused sort of the out-of-place composites →
    (at :meth:`InFlightDeltaSort.wait`) one rank merge. The result is
    byte-identical — keys AND stable argsort — to the cold ladder's,
    whatever the split extracted: the composites are distinct and ordered
    like the stable sort, so identity is structural.
    """
    arr = np.asarray(keys, np.int32).reshape(-1)
    stats = stats if stats is not None else TierStats()
    kept_idx, delta_idx = split_sorted_run(arr)
    comp_kept = lift_positions(arr[kept_idx], kept_idx)
    comp_delta = lift_positions(arr[delta_idx], delta_idx)
    tracer = obs.resolve_tracer(obs_handle)
    flight, n_p = sort_delta_comps_launch(
        comp_delta, p, min_n_per_proc=min_n_per_proc, executor=executor,
        stats=stats, obs_handle=obs_handle,
    )
    return InFlightDeltaSort(
        comp_kept=comp_kept,
        n_delta=int(delta_idx.size),
        flight=flight,
        stats=stats,
        n_per_proc=n_p,
        tracer=tracer,
        t_launched=tracer.now() if tracer is not None else 0.0,
        backend=backend,
    )


def near_sorted_sort(keys: np.ndarray, p: int, **kw) -> SegmentedResult:
    """Blocking wrapper over :func:`near_sorted_sort_launch`."""
    return near_sorted_sort_launch(keys, p, **kw).wait()
