from repro.train.train_step import init_all, make_train_step, train_step  # noqa: F401
from repro.train import checkpoint, elastic  # noqa: F401
