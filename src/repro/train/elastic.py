"""Elastic scaling, straggler mitigation and capacity-fault retry.

Host-level control plane (pure Python — no jax state):

* ``StragglerMonitor`` — per-step wall-time EWMA; a step exceeding
  ``threshold ×`` the EWMA marks the step slow. After ``patience``
  consecutive slow steps the driver is told to re-mesh without the slow
  hosts (on Cloud TPU the set of live hosts comes from the coordination
  service; here it is injected for tests).
* ``plan_remesh`` — given surviving device count, pick the largest
  (data × model) grid that preserves the model axis (TP degree must not
  change — parameter layout is tied to it) and shrinks data-parallelism;
  global batch is preserved via gradient-accumulation factor.
* ``retry_capacity`` — the BSP routing layers surface ``overflow`` flags
  (a sort may not drop keys); the driver retries the step with the next
  capacity tier (1.25× ladder) up to the exactness tier n/p.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Tuple


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 2.0
    patience: int = 3
    ewma: float = 0.0
    alpha: float = 0.1
    slow_streak: int = 0
    steps: int = 0

    def is_slow(self, seconds: float) -> bool:
        """Pure check: would this wall time count as a straggler now?

        Unlike :meth:`record` this neither advances the warmup nor moves
        the EWMA — callers that want to *count* slow events separately
        from the re-mesh signal (e.g. the service dispatcher's
        ``svc.straggler_flights``) check first, then record.
        """
        if self.steps <= 3 or self.ewma == 0:  # warmup: nothing to compare
            return False
        return seconds > self.threshold * self.ewma

    def record(self, seconds: float) -> bool:
        """Returns True if the driver should consider re-meshing."""
        self.steps += 1
        if self.steps <= 3:  # warmup
            self.ewma = seconds if self.ewma == 0 else self.ewma
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * seconds
            return False
        slow = seconds > self.threshold * self.ewma
        self.slow_streak = self.slow_streak + 1 if slow else 0
        if not slow:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * seconds
        return self.slow_streak >= self.patience


def plan_remesh(
    n_devices: int, model_axis: int, old_data_axis: int, global_batch: int
) -> Tuple[Tuple[int, int], int]:
    """((data, model), accumulation_factor) for the surviving device count.

    The model axis is pinned (weight layout); data parallelism shrinks to
    the largest power-of-two that fits; the lost throughput is recovered by
    gradient accumulation so the *global batch is invariant* across
    elasticity events (loss curves stay comparable).
    """
    if n_devices < model_axis:
        raise ValueError(
            f"cannot preserve model axis {model_axis} with {n_devices} devices"
        )
    data = n_devices // model_axis
    # largest power of two ≤ data
    d = 1
    while d * 2 <= data:
        d *= 2
    accum = max(1, old_data_axis // d)
    if global_batch % (d * accum):
        accum = old_data_axis // d  # keep divisibility; caller validates
    return (d, model_axis), accum


def retry_capacity(
    run_step: Callable[[float], Tuple[object, bool]],
    *,
    tiers: Optional[List[float]] = None,
) -> object:
    """Run ``run_step(capacity_factor)`` → (result, overflow); escalate
    through the capacity ladder until clean. The last tier is exact (no
    overflow is possible at pair_cap = n/p — Lemma 5.1's regime)."""
    tiers = tiers or [1.0, 1.25, 1.5625, float("inf")]
    for cf in tiers:
        result, overflow = run_step(cf)
        if not overflow:
            return result
    raise RuntimeError("capacity escalation exhausted (unreachable: last tier exact)")


@dataclasses.dataclass
class StepTimer:
    t0: float = 0.0

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
        return False
