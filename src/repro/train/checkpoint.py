"""Checkpointing + restart (fault tolerance substrate).

Design (DESIGN.md §6):
* every K steps the host gathers the (addressable shards of the) pytree and
  writes one ``.npz`` per save plus a JSON manifest carrying step, config
  name, tree structure, and a SHA-256 of the payload;
* writes are atomic (tmp file + ``os.replace``) so a crash mid-save never
  corrupts the latest checkpoint;
* ``latest_step`` / ``restore`` implement the restart path; the data
  pipeline is stateless-seeded (step → batch) so restart is bit-exact;
* a bounded ``keep`` window garbage-collects old saves.

On a real cluster the gather becomes a per-host shard dump (same manifest
format, one npz per host) — the single-host form here is the degenerate
case of that layout.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

_BF16 = np.dtype(ml_dtypes.bfloat16)


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _to_saveable(arr: np.ndarray) -> np.ndarray:
    """bf16 has no npz codec — persist as a uint16 bit view."""
    return arr.view(np.uint16) if arr.dtype == _BF16 else arr


def _from_saved(raw: np.ndarray, want: np.dtype) -> np.ndarray:
    if np.dtype(want) == _BF16:
        return raw.view(_BF16) if raw.dtype == np.uint16 else raw.astype(_BF16)
    return raw.astype(want) if raw.dtype != want else raw


def save(path: str, step: int, tree: Any, *, keep: int = 3, extra: Optional[Dict] = None) -> str:
    os.makedirs(path, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": _to_saveable(np.asarray(l)) for i, l in enumerate(leaves)}
    tmp_fd, blob = tempfile.mkstemp(dir=path, suffix=".tmp.npz")
    os.close(tmp_fd)
    np.savez(blob, **arrays)  # name ends in .npz → written in place
    with open(blob, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()
    final = os.path.join(path, f"ckpt_{step:08d}.npz")
    os.replace(blob, final)
    manifest = {
        "step": step,
        "sha256": digest,
        "treedef": str(treedef),
        "nleaves": len(leaves),
        "extra": extra or {},
    }
    mtmp = final + ".manifest.tmp"
    with open(mtmp, "w") as f:
        json.dump(manifest, f, indent=2)
    os.replace(mtmp, final.replace(".npz", ".json"))
    _gc(path, keep)
    return final


def _gc(path: str, keep: int) -> None:
    steps = sorted(all_steps(path))
    for s in steps[:-keep] if keep > 0 else []:
        for suffix in (".npz", ".json"):
            p = os.path.join(path, f"ckpt_{s:08d}{suffix}")
            if os.path.exists(p):
                os.remove(p)


def all_steps(path: str):
    if not os.path.isdir(path):
        return []
    out = []
    for f in os.listdir(path):
        if f.startswith("ckpt_") and f.endswith(".npz"):
            out.append(int(f[5:13]))
    return sorted(out)


def latest_step(path: str) -> Optional[int]:
    steps = all_steps(path)
    return steps[-1] if steps else None


def restore(path: str, step: int, like: Any, *, verify: bool = True) -> Any:
    """Restore into the structure (and shardings) of ``like``."""
    blob = os.path.join(path, f"ckpt_{step:08d}.npz")
    man = blob.replace(".npz", ".json")
    if verify and os.path.exists(man):
        with open(man) as f:
            manifest = json.load(f)
        with open(blob, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        if digest != manifest["sha256"]:
            raise IOError(f"checkpoint {blob} integrity check failed")
    data = np.load(blob)
    leaves, treedef = _flatten(like)
    new_leaves = []
    for i, l in enumerate(leaves):
        want = getattr(l, "dtype", None) or np.asarray(l).dtype
        arr = _from_saved(data[f"leaf_{i}"], want)
        if hasattr(l, "sharding"):
            arr = jax.device_put(arr, l.sharding)
        new_leaves.append(arr)
    return jax.tree.unflatten(treedef, new_leaves)
