"""Training step factory: loss → grad → (optional accumulation) → AdamW.

Microbatch gradient accumulation is a ``lax.scan`` over batch splits (the
per-microbatch graph is the unit XLA's latency-hiding scheduler overlaps
with the gradient all-reduce of the previous microbatch). Optional int8+EF
compression decorates the cross-pod gradient reduction.

``make_train_step`` binds shardings for params/opt-state/batch so the same
function serves the real trainer and the 512-device dry-run.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import Model, make_mesh_info
from repro.models import sharding as shd
from repro.optim import OptConfig, apply_updates, init_state


def make_loss_fn(model: Model, mesh: Optional[Mesh]):
    mesh_info = make_mesh_info(mesh, model.cfg)

    def loss_fn(params, batch):
        loss, aux = model.train_loss(params, batch, mesh_info)
        return loss, aux

    return loss_fn


def train_step(
    model: Model,
    opt_cfg: OptConfig,
    params: Any,
    opt_state: Dict,
    batch: Dict,
    mesh: Optional[Mesh] = None,
) -> Tuple[Any, Dict, Dict]:
    cfg = model.cfg
    loss_fn = make_loss_fn(model, mesh)
    mb = max(cfg.microbatches, 1)

    if mb == 1:
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
    else:
        adt = jnp.dtype(opt_cfg.grad_accum_dtype)

        def split(x):
            return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

        micro = jax.tree.map(split, batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)

        def body(carry, mb_batch):
            acc, loss_acc = carry
            (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb_batch
            )
            acc = jax.tree.map(lambda a, gg: a + gg.astype(adt), acc, g)
            return (acc, loss_acc + loss), aux

        (gsum, loss_sum), auxs = lax.scan(body, (zeros, 0.0), micro)
        grads = jax.tree.map(lambda g: (g / mb).astype(jnp.float32), gsum)
        loss = loss_sum / mb
        aux = jax.tree.map(lambda a: a[-1], auxs)

    new_params, new_state, metrics = apply_updates(opt_cfg, params, grads, opt_state)
    metrics["loss"] = loss
    for k, v in (aux or {}).items():
        metrics[f"aux_{k}"] = v
    return new_params, new_state, metrics


def make_train_step(
    model: Model, opt_cfg: OptConfig, mesh: Optional[Mesh], batch_shapes=None
):
    """jit-compiled train step with explicit in/out shardings.

    ``batch_shapes`` (optional ShapeDtypeStruct tree) lets the batch specs be
    divisibility-sanitized — e.g. global_batch 256 under the pure-DP policy
    on the 512-chip multi-pod mesh shards over ('pod','data') only.
    """
    cfg = model.cfg
    fn = functools.partial(train_step, model, opt_cfg, mesh=mesh)
    if mesh is None:
        return jax.jit(fn)

    pshapes = model.param_shapes()
    pspecs = shd.param_specs(cfg, pshapes, mesh.shape["model"])
    pspecs = shd.sanitize_specs(mesh, pspecs, pshapes)
    # ZeRO-1: optimizer state stays 2-D sharded even under the pure-DP
    # policy (the update runs on shards; params re-gather afterwards).
    ocfg_for_state = (
        dataclasses.replace(cfg, param_sharding="2d")
        if cfg.param_sharding == "dp"
        else cfg
    )
    sspecs = shd.sanitize_specs(
        mesh, shd.param_specs(ocfg_for_state, pshapes, mesh.shape["model"]), pshapes
    )
    opt_specs = {
        "m": sspecs,
        "v": sspecs,
        "step": P(),
    }
    bspecs = shd.batch_specs(cfg, mesh, "train")
    if batch_shapes is not None:
        bspecs = shd.sanitize_specs(
            mesh, {k: bspecs[k] for k in batch_shapes}, batch_shapes
        )
    to_s = lambda tree: shd.to_shardings(mesh, tree)
    return jax.jit(
        fn,
        in_shardings=(to_s(pspecs), to_s(opt_specs), to_s(bspecs)),
        out_shardings=(to_s(pspecs), to_s(opt_specs), None),
        donate_argnums=(0, 1),
    )


def init_all(model: Model, opt_cfg: OptConfig, rng: jax.Array):
    params = model.init(rng)
    return params, init_state(opt_cfg, params)
