"""granite-moe-1b-a400m — 32-expert top-8 MoE
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

24L, d_model=1024, 16 heads (GQA kv=8), d_ff=512 per expert, vocab=49155,
MoE 32e top-8 on every layer. EP dispatch: 2 experts per model shard, BSP
sort routing (the paper technique, first-class).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab=49155,
    moe_experts=32, moe_top_k=8,
    param_sharding="1d",
))
