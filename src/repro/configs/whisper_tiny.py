"""whisper-tiny — encoder-decoder audio backbone [arXiv:2212.04356; unverified].

4+4L, d_model=384, 6 heads, d_ff=1536, vocab=51865. The conv frontend is a
STUB per the brief: input_specs() provides precomputed (1500, 384) frame
embeddings. Decoder cross-attends to encoder output; decode shapes exercise
the decoder KV cache. Full attention ⇒ long_500k skipped.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865,
    enc_layers=4, enc_positions=1500,
    param_sharding="dp",  # §Perf A2 regime: replicate 61M, shard batch
))
