"""mixtral-8x22b — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088; hf].

56L, d_model=6144, 48 heads (GQA kv=8), d_ff=16384 per expert, vocab=32768,
MoE 8e top-2 every layer, SWA window 4096. E=8 < model-axis 16 ⇒ TP-MoE
path: experts replicated, FFN hidden dim TP-sharded, tokens grouped by the
BSP integer sort (grouped-GEMM dispatch). SWA ⇒ sub-quadratic ⇒ long_500k.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=32768,
    moe_experts=8, moe_top_k=2,
    sliding_window=4096,
    param_sharding="2d", microbatches=1,  # §Perf B2: fewer FSDP re-gathers
))
