"""xlstm-350m — sLSTM + mLSTM block stack [arXiv:2405.04517; unverified].

24 blocks, d_model=1024, 4 heads, vocab=50304, no FFN (d_ff=0 — xLSTM
blocks carry their own up/down projections). Every 8th block is sLSTM
(scalar memory, exponential gating); the rest mLSTM (matrix memory,
linear-attention-like). Attention-free ⇒ the sort technique is in-layer
inapplicable (DESIGN.md §Arch-applicability); sub-quadratic ⇒ long_500k runs.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    slstm_every=8,
    param_sharding="dp",  # §Perf A2 regime: replicate 0.3B, shard batch
))
