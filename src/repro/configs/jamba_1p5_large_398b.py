"""jamba-1.5-large-398b — Mamba+attention 1:7 hybrid MoE [arXiv:2403.19887; hf].

72L, d_model=8192, 64 heads (GQA kv=8), d_ff=24576, vocab=65536; MoE 16
experts top-2 on every second layer; attention every 8th layer (1:7
interleave), the rest Mamba (S6) blocks. Sub-quadratic ⇒ long_500k runs.

The BSP sort is first-class here twice: EP token dispatch (16 experts over
the 16-way model axis) and the Mamba-free attention layers' decode path.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab=65536,
    moe_experts=16, moe_top_k=2, moe_every=2,
    attn_period=8, mamba_d_state=16, mamba_expand=2, mamba_d_conv=4,
    param_sharding="2d", microbatches=2,  # §Perf C2: fewer FSDP re-gathers
))
