"""deepseek-7b — dense llama-arch LM [arXiv:2401.02954; hf].

30L, d_model=4096, 32 heads (GQA kv=32 ⇒ effectively MHA), d_ff=11008,
vocab=102400. BSP-sort technique applies outside the layer stack only
(data-pipeline bucketing, serving top-k) — see DESIGN.md §Arch-applicability.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab=102400,
    param_sharding="2d", microbatches=2,
))
