"""internvl2-76b — InternViT + InternLM2 VLM backbone [arXiv:2404.16821; unverified].

80L, d_model=8192, 64 heads (GQA kv=8), d_ff=28672, vocab=128256. Per the
brief the modality frontend is a STUB: input_specs() provides precomputed
patch embeddings (vision_tokens × d_model) prepended to the text sequence.
Full attention ⇒ long_500k skipped.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256,
    vision_tokens=256,
    param_sharding="2d", microbatches=4,
))
