"""Architecture + shape configuration schema.

One ``ArchConfig`` per assigned architecture (exact constants from the brief,
sources cited in each ``configs/<id>.py``), plus a ``reduced()`` variant used
by CPU smoke tests. ``ShapeConfig`` enumerates the four assigned input shapes;
``runnable()`` encodes the brief's skip rules (long_500k only for
sub-quadratic archs; decode only for archs with a decoder).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

# ----------------------------------------------------------------- shapes
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_every: int = 1  # MoE MLP every k-th layer (jamba: 2)
    # hybrid (jamba): attention layer every `attn_period` layers, else mamba
    attn_period: int = 0
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_d_conv: int = 4
    # sliding-window attention (mixtral)
    sliding_window: int = 0
    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_positions: int = 1500  # whisper audio frames after conv stub
    # vlm
    vision_tokens: int = 0  # stub patch embeddings prepended to the text
    # xlstm
    slstm_every: int = 0  # sLSTM block every k-th layer, else mLSTM
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # distribution policy
    param_sharding: str = "2d"  # "2d" = FSDP(data)×TP(model); "1d" = TP only
    remat: bool = True
    seq_shard_activations: bool = True  # Megatron-SP style residual sharding
    microbatches: int = 1

    # ------------------------------------------------------------ derived
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM/hybrid or sliding-window attention."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs autoregress (whisper via its decoder)

    def runnable(self, shape: ShapeConfig) -> Tuple[bool, str]:
        """(runs?, reason-if-skipped) per the brief's skip rules."""
        if shape.name == "long_500k" and not self.sub_quadratic:
            return False, (
                "long_500k skipped: pure full-attention arch (O(S^2) prefill "
                "and O(S) KV decode at 512k exceeds any quadratic budget); "
                "see DESIGN.md §Arch-applicability"
            )
        if shape.kind == "decode" and not self.has_decoder:
            return False, "decode skipped: encoder-only architecture"
        return True, ""

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "hybrid":
            return (i % self.attn_period) == self.attn_period // 2
        return self.family != "ssm"

    def is_moe_layer(self, i: int) -> bool:
        return self.moe_experts > 0 and (i % self.moe_every) == self.moe_every - 1

    # analytic parameter count (embedding included once)
    def param_count(self) -> int:
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        H, KV, hd = self.n_heads, self.n_kv_heads, self.hd
        total = V * D + D * V  # embed + lm head
        for i in range(L):
            if self.is_attn_layer(i):
                total += D * H * hd + 2 * D * KV * hd + H * hd * D
            elif self.family == "hybrid":  # mamba layer
                di = self.mamba_expand * D
                total += D * 2 * di + di * self.mamba_d_conv + di * (
                    2 * self.mamba_d_state + 1
                ) + di * D
            elif self.family == "ssm":  # xlstm block
                total += 4 * D * D + 2 * D * 2 * D
            if F:
                if self.is_moe_layer(i):
                    total += D * self.moe_experts + self.moe_experts * 3 * D * F
                else:
                    total += 3 * D * F
            total += 2 * D  # norms
        if self.enc_layers:
            for _ in range(self.enc_layers):
                total += 4 * D * D + 3 * D * F + 2 * D  # enc self-attn + mlp
            total += self.n_layers * (4 * D * D + D)  # decoder cross-attn
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k of experts)."""
        if not self.moe_experts:
            return self.param_count()
        D, F = self.d_model, self.d_ff
        dense = self.param_count()
        moe_layers = sum(self.is_moe_layer(i) for i in range(self.n_layers))
        unused = moe_layers * (self.moe_experts - self.moe_top_k) * 3 * D * F
        return dense - unused

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=max(2, min(4, self.n_layers)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) or 2,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            moe_experts=min(self.moe_experts, 4),
            moe_top_k=min(self.moe_top_k, 2),
            enc_layers=min(self.enc_layers, 2),
            enc_positions=min(self.enc_positions, 64) if self.enc_layers else self.enc_positions,
            vision_tokens=min(self.vision_tokens, 16),
            slstm_every=min(self.slstm_every, 2) if self.slstm_every else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            attn_period=self.attn_period,
            mamba_d_state=8,
            param_sharding="1d",
            microbatches=1,
        )


_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if not _REGISTRY:
        from . import ALL  # noqa: F401  (populates the registry)
    return _REGISTRY[name]


def all_archs() -> Dict[str, ArchConfig]:
    if not _REGISTRY:
        from . import ALL  # noqa: F401
    return dict(_REGISTRY)
