"""Assigned architecture configs (--arch <id>). Exact constants per brief."""
from .base import ArchConfig, ShapeConfig, SHAPES, all_archs, get_arch, register

from . import (  # noqa: F401  — importing populates the registry
    deepseek_7b,
    internlm2_20b,
    phi3_mini_3p8b,
    tinyllama_1p1b,
    jamba_1p5_large_398b,
    xlstm_350m,
    internvl2_76b,
    granite_moe_1b_a400m,
    mixtral_8x22b,
    whisper_tiny,
)

ALL = [
    deepseek_7b.CONFIG,
    internlm2_20b.CONFIG,
    phi3_mini_3p8b.CONFIG,
    tinyllama_1p1b.CONFIG,
    jamba_1p5_large_398b.CONFIG,
    xlstm_350m.CONFIG,
    internvl2_76b.CONFIG,
    granite_moe_1b_a400m.CONFIG,
    mixtral_8x22b.CONFIG,
    whisper_tiny.CONFIG,
]
