"""tinyllama-1.1b — small llama2-arch LM [arXiv:2401.02385; hf].

22L, d_model=2048, 32 heads, GQA kv=4, d_ff=5632, vocab=32000.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="tinyllama-1.1b", family="dense",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=5632, vocab=32000,
    param_sharding="dp", remat=False,  # §Perf A2/A3: pure-DP + no remat
))
