"""Chaos layer: deterministic seeded fault injection for the sort service.

    FaultPlan     — seeded schedule of injectable faults (capacity faults,
                    launch errors, poison rids, straggler delays, delta
                    fold corruption), threaded through SortConfig/
                    ServiceConfig hash-excluded like ``obs`` so faulted
                    configs share compiled programs.
    ChaosError    — the exception injected launch faults raise (recovered
                    by failsink bisection like any organic error).
    resolve_chaos — duck-typed handle resolution for the driver layers.

See plan.py for the injection points and the determinism contract.
"""
from .plan import ChaosError, FaultPlan, resolve_chaos

__all__ = ["ChaosError", "FaultPlan", "resolve_chaos"]
