"""FaultPlan — a deterministic, seeded schedule of injectable faults.

The recovery machinery (capacity-tier ladder, failsink bisection, the
delta view's resort fallback) only earns trust when it is *exercised*:
production faults are rare and irreproducible, so the chaos layer makes
them cheap and exactly repeatable. A :class:`FaultPlan` is threaded
through ``SortConfig``/``ServiceConfig`` the same hash/compare-excluded
way as ``obs`` — a faulted config and a clean one are EQUAL, share
executor-registry entries, and run the *same compiled programs*; every
injection is a host-side decision at a driver boundary:

* **capacity faults** — :meth:`fault_capacity` flips the host-read
  overflow decision of a non-terminal ladder rung in
  ``core.api.InFlightSort.wait``, forcing the whp→exact→allgather
  escalation exactly as a real oversampling fault would (the rung's
  device result is discarded; the next rung's result is byte-identical).
  The terminal rung is never faulted — innocents always complete.
* **launch faults** — :meth:`check_launch` raises :class:`ChaosError`
  from the dispatcher's plan/pack/launch path, exercising failsink
  bisection. ``poison_rids`` fault *every* dispatch containing the rid
  (terminal solo failure, the future carries a ``SortServiceError``
  naming it); ``transient_error_rate`` faults each distinct rid-set at
  most **once** (the retry/bisection recovers, innocents complete).
* **stragglers** — :meth:`straggle_delay` injects a host-side sleep at
  the flight's completion sync, feeding the dispatcher's
  ``train/elastic.StragglerMonitor`` wiring.
* **fold corruption** — :meth:`corrupt_fold` corrupts the sorted Δ run
  inside ``delta.SortedView.fold`` before the rank-merge; the view's
  post-merge monotonicity check catches it and falls back to a full
  resort from the preserved pre-fold state (byte-identity preserved).

Determinism: every rate-based decision is a pure hash of
``(seed, kind, key)`` — **independent of call order** — so a fixed seed
over a fixed workload injects the same faults on every run, which is what
lets the ``chaos`` bench table gate ``innocents_failed == 0`` and
``recovered_batches`` as exact-match identity fields. Explicit schedules
(``capacity_faults``, ``fail_batches``, ``straggle_flights``,
``corrupt_folds``) compose with the rates for targeted tests.

Injections are counted per kind in the process-wide metrics registry
(``chaos.injected{plan=<label>, kind=...}``); span/point emission rides
the *consumer's* tracer under ``cat="chaos"`` (the plan itself carries no
tracer — it must stay safe to share across services and sorts).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Dict, Optional, Sequence, Tuple

from repro import obs

__all__ = ["ChaosError", "FaultPlan", "resolve_chaos"]


class ChaosError(RuntimeError):
    """An injected (not organic) fault, raised from a driver boundary."""


def _draw(seed: int, kind: str, *key) -> float:
    """Uniform [0, 1) from a stable hash of (seed, kind, key).

    Order-independent by construction: the decision for a given key never
    depends on how many draws happened before it, so async scheduling
    cannot perturb the fault schedule.
    """
    h = hashlib.blake2b(
        repr((int(seed), kind) + tuple(key)).encode(), digest_size=8
    ).digest()
    return int.from_bytes(h, "big") / float(1 << 64)


@dataclasses.dataclass
class FaultPlan:
    """Seeded fault schedule; see the module docstring for the fault kinds.

    Rates are per-opportunity probabilities drawn deterministically from
    ``seed``; the explicit tuples force specific injection points (both
    compose). ``max_faults`` caps total injections across all kinds.
    """

    seed: int = 0
    # --- capacity faults: flip a non-terminal rung's overflow decision
    capacity_fault_rate: float = 0.0  # per (sort_seq, rung) opportunity
    capacity_fault_rungs: Tuple[int, ...] = (0,)  # rungs eligible for rate
    capacity_faults: Tuple[Tuple[int, int], ...] = ()  # explicit (sort, rung)
    # --- launch faults: raise ChaosError from the dispatch path
    poison_rids: Tuple[int, ...] = ()  # every dispatch with the rid faults
    transient_error_rate: float = 0.0  # per distinct rid-set, at most once
    fail_batches: Tuple[int, ...] = ()  # explicit batch launch seqs, once
    # --- stragglers: host-side sleep at the flight completion sync
    straggle_rate: float = 0.0  # per flight completion
    straggle_s: float = 0.0  # injected delay per straggled flight
    straggle_flights: Tuple[int, ...] = ()  # explicit flight seqs
    # --- delta fold corruption: corrupt the sorted Δ run pre-merge
    fold_corrupt_rate: float = 0.0  # per fold
    corrupt_folds: Tuple[int, ...] = ()  # explicit fold seqs
    max_faults: Optional[int] = None  # cap on total injections (None: off)

    def __post_init__(self) -> None:
        self.label = obs.next_instance("chaos")
        self._injected_total = 0
        self._fired_sets: set = set()  # rid-sets already transiently failed
        self._fired_batches: set = set()  # explicit batch seqs already fired
        self._sort_seq = itertools.count()
        self._batch_seq = itertools.count()
        self._flight_seq = itertools.count()
        self._fold_seq = itertools.count()

    # ----------------------------------------------------------- counting
    def _count(self, kind: str) -> None:
        self._injected_total += 1
        obs.metrics().counter(
            "chaos.injected", plan=self.label, kind=kind
        ).inc()

    def _budget_ok(self) -> bool:
        return self.max_faults is None or self._injected_total < self.max_faults

    @property
    def injected(self) -> Dict[str, int]:
        """kind -> injection count (view over the metrics registry)."""
        return {
            str(lbl["kind"]): c.value
            for lbl, c in obs.metrics().collect(
                "chaos.injected", plan=self.label
            )
        }

    @property
    def injected_total(self) -> int:
        return self._injected_total

    # --------------------------------------------------- sequence handles
    # The drivers key faults by *stable sequence numbers* they draw at the
    # relevant boundary; under FIFO single-threaded dispatch the sequences
    # are deterministic, and the hashed draws are order-independent anyway.
    def next_sort(self) -> int:
        return next(self._sort_seq)

    def next_batch(self) -> int:
        return next(self._batch_seq)

    def next_flight(self) -> int:
        return next(self._flight_seq)

    def next_fold(self) -> int:
        return next(self._fold_seq)

    # ------------------------------------------------------ fault queries
    def fault_capacity(self, sort_seq: int, rung: int) -> bool:
        """Force a capacity fault at (sort_seq, rung)? Called only for
        non-terminal rungs (the driver never faults the last rung)."""
        hit = (int(sort_seq), int(rung)) in self.capacity_faults or (
            rung in self.capacity_fault_rungs
            and self.capacity_fault_rate > 0
            and _draw(self.seed, "cap", sort_seq, rung)
            < self.capacity_fault_rate
        )
        if hit and self._budget_ok():
            self._count("capacity_fault")
            return True
        return False

    def check_launch(self, batch_seq: int, rids: Sequence[int]) -> None:
        """Raise :class:`ChaosError` if this dispatch should fault.

        Poison rids fault unconditionally (terminal once solo); explicit
        ``fail_batches`` and the transient rate fault each key at most
        once, so failsink recovery always converges.
        """
        poisoned = sorted(set(rids) & set(self.poison_rids))
        if poisoned and self._budget_ok():
            self._count("poison")
            raise ChaosError(
                f"injected poison fault (rid {poisoned[0]} in batch)"
            )
        if (
            batch_seq in self.fail_batches
            and batch_seq not in self._fired_batches
            and self._budget_ok()
        ):
            self._fired_batches.add(batch_seq)
            self._count("launch_error")
            raise ChaosError(f"injected launch fault (batch {batch_seq})")
        key = tuple(sorted(int(r) for r in rids))
        if (
            self.transient_error_rate > 0
            and key not in self._fired_sets
            and _draw(self.seed, "launch", key) < self.transient_error_rate
            and self._budget_ok()
        ):
            self._fired_sets.add(key)
            self._count("launch_error")
            raise ChaosError(
                f"injected transient launch fault (rids {list(key)})"
            )

    def straggle_delay(self, flight_seq: int) -> float:
        """Seconds of injected host delay before this flight's sync."""
        hit = flight_seq in self.straggle_flights or (
            self.straggle_rate > 0
            and _draw(self.seed, "straggle", flight_seq) < self.straggle_rate
        )
        if hit and self.straggle_s > 0 and self._budget_ok():
            self._count("straggle")
            return float(self.straggle_s)
        return 0.0

    def corrupt_fold(self, fold_seq: int) -> bool:
        """Corrupt this fold's sorted Δ run (pre-merge)?"""
        hit = fold_seq in self.corrupt_folds or (
            self.fold_corrupt_rate > 0
            and _draw(self.seed, "fold", fold_seq) < self.fold_corrupt_rate
        )
        if hit and self._budget_ok():
            self._count("fold_corruption")
            return True
        return False


def resolve_chaos(handle) -> Optional[FaultPlan]:
    """Duck-typed chaos resolution, mirroring ``obs.resolve_tracer``.

    Accepts a :class:`FaultPlan` (or anything exposing its query surface)
    or None. Config fields hold the handle as ``Optional[object]`` so the
    core layer never imports chaos at type level.
    """
    if handle is None:
        return None
    if hasattr(handle, "fault_capacity") and hasattr(handle, "check_launch"):
        return handle
    raise TypeError(
        f"chaos handle {handle!r} lacks the FaultPlan query surface"
    )
