"""Attention: chunked (flash-style) training/prefill path + KV-cache decode.

* ``flash_attention`` — online-softmax over KV chunks inside a scan over Q
  chunks: memory O(S·chunk) instead of O(S²), which is what lets the
  prefill_32k cells fit HBM. Supports causal and sliding-window masks and
  GQA head grouping. Pure jnp — the XLA fusion of the chunk body is already
  near the VPU/MXU roofline for this pattern; a Pallas variant is a §Perf
  lever, not a correctness need.
* ``decode_attention`` — one-token attention against a (S_max,) KV cache.
  The cache's sequence dim is sharded over the ``model`` mesh axis, so the
  partitioner lowers the softmax reduction to the flash-decode pattern:
  per-shard partial (max, sum, weighted-V) + tiny cross-shard all-reduces.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30
#: finite floor for the running max — keeps exp() arithmetic NaN-free on
#: fully-masked blocks without predicate `where` guards (whose saved pred
#: tensors otherwise materialize at full score shape in the backward pass).
M_FLOOR = -1e9


def _divisor_chunk(n: int, want: int) -> int:
    """Largest chunk ≤ want that divides n (whisper's 1500 frames etc.)."""
    c = min(want, n)
    while n % c:
        c -= 1
    return c


def _gqa_expand(q, kv_heads):
    """Group query heads over KV heads: (B,S,H,hd) -> (B,S,KV,rep,hd)."""
    b, s, h, hd = q.shape
    rep = h // kv_heads
    return q.reshape(b, s, kv_heads, rep, hd)


def reference_attention(q, k, v, *, causal=True, window=0, q_offset=0):
    """O(S²) oracle for tests."""
    b, sq, h, hd = q.shape
    _, sk, kvh, _ = k.shape
    qg = _gqa_expand(q, kvh)
    scores = jnp.einsum("bsgrh,btgh->bgrst", qg, k).astype(jnp.float32)
    scores /= jnp.sqrt(hd)
    qi = jnp.arange(sq)[:, None] + q_offset
    kj = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kj <= qi
    if window:
        mask &= qi - kj < window
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrst,btgh->bsgrh", probs, v)
    return out.reshape(b, sq, h, hd)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_chunk", "kv_chunk")
)
def flash_attention(
    q: jnp.ndarray,  # (B, S, H, hd)
    k: jnp.ndarray,  # (B, S, KV, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    b, s, h, hd = q.shape
    _, sk, kvh, _ = k.shape
    q_chunk = _divisor_chunk(s, q_chunk)
    kv_chunk = _divisor_chunk(sk, kv_chunk)
    nq, nk = s // q_chunk, sk // kv_chunk
    rep = h // kvh
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    qg = q.reshape(b, nq, q_chunk, kvh, rep, hd)
    kg = k.reshape(b, nk, kv_chunk, kvh, hd)
    vg = v.reshape(b, nk, kv_chunk, kvh, hd)

    def q_block(qi, qc):  # qc: (B, q_chunk, KV, rep, hd)
        m0 = jnp.full((b, kvh, rep, q_chunk), M_FLOOR, jnp.float32)
        l0 = jnp.zeros((b, kvh, rep, q_chunk), jnp.float32)
        o0 = jnp.zeros((b, kvh, rep, q_chunk, hd), jnp.float32)

        def kv_block(carry, inputs):
            m, l, o = carry
            kj, kc, vc = inputs
            sc = jnp.einsum("bqgrh,bkgh->bgrqk", qc, kc).astype(jnp.float32) * scale
            qpos = qi * q_chunk + jnp.arange(q_chunk)[:, None]
            kpos = kj * kv_chunk + jnp.arange(kv_chunk)[None, :]
            # arithmetic masking: penalty is a (q_chunk, kv_chunk) f32 added
            # with broadcasting — backward of (+) needs no saved predicate,
            # unlike where(mask, sc, -inf) whose pred tensor would be saved
            # at full (B,G,R,Q,K) score shape by remat (§Perf iteration 0).
            penalty = jnp.zeros((q_chunk, kv_chunk), jnp.float32)
            if causal:
                penalty += jnp.where(kpos <= qpos, 0.0, NEG_INF)
            if window:
                penalty += jnp.where(qpos - kpos < window, 0.0, NEG_INF)
            sc = sc + penalty
            # m floored at M_FLOOR ⇒ sc - m_new ≤ -1e29 on masked lanes ⇒
            # exp underflows to exactly 0.0; no NaN guards needed.
            m_new = jnp.maximum(m, sc.max(-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            o = o * corr[..., None] + jnp.einsum(
                "bgrqk,bkgh->bgrqh", p.astype(qc.dtype), vc
            ).astype(jnp.float32)
            return (m_new, l, o), None

        (m, l, o), _ = lax.scan(
            kv_block, (m0, l0, o0), (jnp.arange(nk), kg.swapaxes(0, 1), vg.swapaxes(0, 1))
        )
        o = o / jnp.maximum(l, 1e-30)[..., None]
        return o.transpose(0, 3, 1, 2, 4).reshape(b, q_chunk, h, hd).astype(q.dtype)

    out = lax.map(lambda args: q_block(*args), (jnp.arange(nq), qg.swapaxes(0, 1)))
    return out.swapaxes(0, 1).reshape(b, s, h, hd)


def decode_attention(
    q: jnp.ndarray,  # (B, 1, H, hd) — the new token's queries
    k_cache: jnp.ndarray,  # (B, S_max, KV, hd)
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,  # scalar — number of valid cache positions (inclusive)
    *,
    window: int = 0,
) -> jnp.ndarray:
    b, _, h, hd = q.shape
    _, sk, kvh, _ = k_cache.shape
    qg = _gqa_expand(q, kvh)[:, 0]  # (B, KV, rep, hd)
    scores = jnp.einsum("bgrh,btgh->bgrt", qg, k_cache).astype(jnp.float32)
    scores /= jnp.sqrt(hd)
    t = jnp.arange(sk)
    valid = t <= pos
    if window:
        valid &= pos - t < window
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrt,btgh->bgrh", probs, v_cache)
    return out.reshape(b, 1, h, hd)


def cache_update(
    k_cache: jnp.ndarray, v_cache: jnp.ndarray, k_new: jnp.ndarray, v_new: jnp.ndarray, pos
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Write the new token's K/V at position ``pos``.

    Uses a one-hot masked add rather than dynamic_update_slice so the
    sequence-sharded cache updates locally on the owning shard (no
    re-layout collectives under SPMD partitioning).
    """
    sk = k_cache.shape[1]
    onehot = (jnp.arange(sk) == pos)[None, :, None, None].astype(k_cache.dtype)
    k_cache = k_cache * (1 - onehot) + k_new * onehot
    v_cache = v_cache * (1 - onehot) + v_new * onehot
    return k_cache, v_cache
