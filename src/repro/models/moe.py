"""Mixture-of-Experts with BSP-sort token dispatch — the paper's technique
as a first-class framework feature (DESIGN.md §4).

Dispatching tokens to experts *is* steps 9-11 of SORT_DET_BSP: an integer
key sort (key = expert id) followed by a balanced all-to-all, with the
stable inverse permutation restoring token order exactly — the paper's
stability guarantee doing real work. Two paths:

* **EP** (experts ≥ model-axis size; granite 32e, jamba 16e): experts are
  sharded over the ``model`` axis. Inside a ``shard_map`` over
  (data-like axes × model), each shard stable-sorts its token records by
  expert id (the paper's Ph2/step-9 "set formation"), computes per-dest
  segment boundaries, and routes through ONE ``lax.all_to_all`` (expert ids
  and token rows byte-packed into a single send buffer — the fused
  h-relation of ``core/routing.pack_bytes``) with a
  capacity = (tokens/shard)·cf — the Claim 5.1-style w.h.p. bound with
  overflow *detected* and surfaced (``aux['overflow']``), never silently
  dropped. The reverse all_to_all + stable unsort is the combine.
* **TP grouped-GEMM** (experts < model axis; mixtral 8e): experts are
  replicated with their FFN hidden dim TP-sharded; tokens are *grouped* by
  the same stable integer sort into (E, capacity) blocks so each expert
  runs one dense GEMM (MegaBlocks-style), then scattered back.

Router aux losses (load-balance + z-loss) are returned for the trainer.

Capacity-tier ladder: a fixed ``capacity_factor`` is exactly the w.h.p.
pair-capacity guess of the sort's ``whp`` tier, and token drop is the same
retriable capacity fault as sort overflow. :func:`moe_ep_safe` runs EP
dispatch through the sort driver's ladder (whp → whp×2 → full) at host
level: the overflow flag escalates the capacity instead of silently
dropping tokens, with per-tier attempts recorded in a shared
:class:`repro.core.TierStats`. (Inside a jitted train step there is no host
sync, so the training path keeps the fixed-capacity body and surfaces
``aux['overflow']`` for the metrics loop.)

``moe_ep_safe(route="radix")`` drops the guesswork entirely: expert ids are
small dense integers, so a router-only counting pass (:func:`moe_ep_counts`)
yields the exact per-(src, dst) record counts, and the single dispatch runs
with a receive buffer bounded by the true maximum count — the MoE face of
``SortConfig(route="radix")``'s count-then-distribute h-relation. Zero
retries by construction, and never the ``full``-tier p·n worst case.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import obs as obs_mod
from repro.configs.base import ArchConfig
from repro.core import TierStats
from repro.core import routing
from repro.core.primitives import shard_map
from repro.models.layers import _dense, dtype_of


@dataclasses.dataclass(frozen=True)
class MoEMeshInfo:
    """How the MoE layer sees the mesh (None = single-device smoke path)."""

    mesh: object = None
    model_axis: str = "model"
    data_axes: tuple = ("data",)

    @property
    def model_size(self) -> int:
        return 1 if self.mesh is None else self.mesh.shape[self.model_axis]


def init_moe(rng, cfg: ArchConfig, layers: int, d_ff: int | None = None) -> Dict:
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    E = cfg.moe_experts
    ks = jax.random.split(rng, 4)
    dt = dtype_of(cfg)
    return {
        "router": _dense(ks[0], (layers, D, E), D, jnp.float32),
        "w_gate": _dense(ks[1], (layers, E, D, F), D, dt),
        "w_up": _dense(ks[2], (layers, E, D, F), D, dt),
        "w_down": _dense(ks[3], (layers, E, F, D), F, dt),
    }


def _router(x2d: jnp.ndarray, w: jnp.ndarray, top_k: int):
    """Top-k routing. x2d (T, D) -> (probs (T,k), experts (T,k), aux)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), w)
    probs_full = jax.nn.softmax(logits, axis=-1)
    probs, experts = lax.top_k(probs_full, top_k)
    probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-9)
    # Shazeer-style load-balance loss + router z-loss
    e = w.shape[-1]
    me = probs_full.mean(0)
    ce = jnp.zeros((e,)).at[experts.reshape(-1)].add(1.0) / max(
        experts.size, 1
    )
    aux_lb = e * jnp.sum(me * ce)
    aux_z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return probs, experts.astype(jnp.int32), {"lb_loss": aux_lb, "z_loss": aux_z}


def _expert_ffn(x, wg, wu, wd):
    g = jnp.einsum("td,df->tf", x, wg)
    u = jnp.einsum("td,df->tf", x, wu)
    return jnp.einsum("tf,fd->td", jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u, wd)


# -------------------------------------------------- TP grouped-GEMM path
def _grouped_gemm_moe(params: Dict, x2d: jnp.ndarray, cfg: ArchConfig, capacity_factor):
    """Core grouped-GEMM dispatch on a 2-D token block (paper step 9: stable
    integer sort by expert id → dense (E, C, D)·(E, D, F) GEMMs)."""
    T, D = x2d.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    probs, experts, aux = _router(x2d, params["router"], k)

    n = T * k
    # decode/small-batch regime: full capacity (no record may ever drop at
    # serving time — exactness is cheap when n is small); capacity-managed
    # at scale with the overflow flag surfaced.
    cap = n if n <= 512 else int(-(-n * capacity_factor // E))
    flat_e = experts.reshape(-1)  # record i = (token i//k, choice i%k)
    order = jnp.argsort(flat_e, stable=True)  # paper step 9
    sorted_e = flat_e[order]
    # position of each record within its expert block
    bounds = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    within = jnp.arange(n) - bounds[sorted_e]
    slot = sorted_e * cap + within
    ok = within < cap
    aux["overflow"] = jnp.any(~ok)
    slot = jnp.where(ok, slot, E * cap)  # dropped slots -> scratch row

    grouped = jnp.zeros((E * cap + 1, D), x2d.dtype).at[slot].set(x2d[order // k])
    grouped = grouped[:-1].reshape(E, cap, D)
    h = jnp.einsum("ecd,edf->ecf", grouped, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", grouped, params["w_up"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x2d.dtype) * u
    out_g = jnp.einsum("ecf,efd->ecd", h, params["w_down"]).reshape(E * cap, D)

    # combine: gather each record's output back, weight, segment-sum per token
    rec_out = jnp.where(ok[:, None], out_g[jnp.minimum(slot, E * cap - 1)], 0.0)
    y = jnp.zeros((T, D), x2d.dtype)
    y = y.at[order // k].add(
        (rec_out * probs.reshape(-1)[order][:, None]).astype(x2d.dtype)
    )
    return y, aux


def moe_tp(params: Dict, x: jnp.ndarray, cfg: ArchConfig, capacity_factor=1.25):
    """Grouped-GEMM MoE under plain pjit (single device / smoke path)."""
    *lead, D = x.shape
    y, aux = _grouped_gemm_moe(params, x.reshape(-1, D), cfg, capacity_factor)
    return y.reshape(*lead, D), aux


def moe_tp_sharded(
    params: Dict,
    x: jnp.ndarray,
    cfg: ArchConfig,
    mesh_info: MoEMeshInfo,
    capacity_factor=1.25,
):
    """Grouped-GEMM MoE under shard_map (§Perf iteration B1).

    Tokens stay local ((pod,data)×model sharded — same layout as the EP
    path); expert weights are replicated over experts with the FFN hidden
    dim TP-sharded, so the only collective is ONE psum of the (T_loc, D)
    combined output per layer (the row-parallel reduction), instead of the
    partitioner's full-batch gathers around the data-dependent scatter that
    plain pjit produced (205 s → ~2 s collective term on mixtral train_4k).
    """
    axis = mesh_info.model_axis
    all_axes = tuple(mesh_info.data_axes) + (axis,)

    def body(xl, router_w, wg, wu, wd):
        bl, sl, D = xl.shape
        lp = {"router": router_w, "w_gate": wg, "w_up": wu, "w_down": wd}
        y, aux = _grouped_gemm_moe(lp, xl.reshape(-1, D), cfg, capacity_factor)
        y = lax.psum(y, axis)  # row-parallel combine over the F shards
        ov = aux.pop("overflow")
        aux = {kk: lax.pmean(vv, all_axes) for kk, vv in aux.items()}
        aux["overflow"] = lax.pmax(ov.astype(jnp.int32), all_axes) > 0
        return y.reshape(bl, sl, D), aux

    dp = _dp_spec(mesh_info, x.shape[0])
    seq = axis if x.shape[1] % mesh_info.model_size == 0 else None
    return shard_map(
        body,
        mesh=mesh_info.mesh,
        in_specs=(
            P(dp, seq, None),
            P(),
            P(None, None, axis),
            P(None, None, axis),
            P(None, axis, None),
        ),
        out_specs=(P(dp, seq, None), P()),
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])


# --------------------------------------------------------- EP (a2a) path
def moe_ep(
    params: Dict,
    x: jnp.ndarray,
    cfg: ArchConfig,
    mesh_info: MoEMeshInfo,
    capacity_factor=1.25,
    pair_cap_override: Optional[int] = None,
):
    """Expert-parallel MoE via the BSP routing machinery under shard_map.

    x: (B, S, D) — B sharded over data axes, S sharded over the model axis
    (so all 256 devices hold distinct tokens), D replicated. Expert weights
    (E, D, F) sharded on E over the model axis.

    ``pair_cap_override`` pins the per-(src,dst) row capacity directly —
    the count-then-distribute ``route="radix"`` path of :func:`moe_ep_safe`
    host-reads the true per-destination counts first and passes their
    (quantized) maximum here, so the dispatch buffer is bounded by what the
    router actually routed instead of a ``capacity_factor`` guess.
    """
    p = mesh_info.model_size
    E, k = cfg.moe_experts, cfg.moe_top_k
    assert E % p == 0, "EP path requires experts divisible by the model axis"
    e_loc = E // p
    axis = mesh_info.model_axis
    all_axes = (
        tuple(mesh_info.data_axes) + (axis,) if mesh_info.mesh is not None else (axis,)
    )

    def body(xl, router_w, wg, wu, wd):
        # xl: (B_loc, S_loc, D); weights: router (D,E), wg/wu/wd (e_loc,D,F)..
        bl, sl, D = xl.shape
        x2d = xl.reshape(-1, D)
        t_loc = x2d.shape[0]
        probs, experts, aux = _router(x2d, router_w, k)

        n = t_loc * k
        if pair_cap_override is not None:
            pair_cap = min(int(pair_cap_override), n)
        else:
            pair_cap = int(-(-n * capacity_factor // p))
        cap = p * pair_cap

        # paper step 9: stable integer sort of records by expert id
        flat_e = experts.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        dest = sorted_e // e_loc  # destination shard (contiguous in sorted order)
        bounds = jnp.searchsorted(dest, jnp.arange(p + 1), side="left").astype(jnp.int32)
        counts = jnp.diff(bounds)
        # aux terms must leave the shard_map replicated: reduce over the mesh
        aux = {kk: lax.pmean(vv, all_axes) for kk, vv in aux.items()}
        aux["overflow"] = (
            lax.pmax(jnp.any(counts > pair_cap).astype(jnp.int32), all_axes) > 0
        )

        # paper steps 10-11: segment rows + ONE all_to_all (keys + payload
        # byte-packed into a single send buffer — the fused h-relation, same
        # helpers as core/routing's Ph5)
        tix = jnp.arange(pair_cap)[None, :]
        gidx = jnp.clip(bounds[:-1][:, None] + tix, 0, n - 1)
        valid = tix < counts[:, None]
        rows_e = jnp.where(valid, sorted_e[gidx], -1)  # (p, pair_cap)
        sorted_tok = x2d[order // k]  # record i ↔ token order[i]//k
        rows_x = jnp.where(valid[..., None], sorted_tok[gidx], 0).astype(xl.dtype)
        fused, metas = routing.pack_bytes([rows_e, rows_x], lead=2)
        recv_e, recv_x = routing.unpack_bytes(
            lax.all_to_all(fused, axis, 0, 0), metas, lead=2
        )

        # local expert compute (masked over e_loc experts; e_loc ≤ 2 in all
        # assigned configs — bounded FLOP inflation, see DESIGN.md §4)
        me = lax.axis_index(axis)
        flat_re = recv_e.reshape(cap)
        flat_rx = recv_x.reshape(cap, D)
        out = jnp.zeros_like(flat_rx)
        for e in range(e_loc):
            sel = flat_re == (me * e_loc + e)
            y_e = _expert_ffn(flat_rx, wg[e], wu[e], wd[e])
            out = jnp.where(sel[:, None], y_e, out)

        # reverse all_to_all: back to source order
        back = lax.all_to_all(out.reshape(p, pair_cap, D), axis, 0, 0)
        # un-segment: record at sorted position bounds[i]+t came back in row i
        sorted_out = jnp.zeros((n, D), xl.dtype)
        src_pos = jnp.where(valid, bounds[:-1][:, None] + tix, n)
        sorted_out = sorted_out.at[src_pos.reshape(-1)].add(
            back.reshape(-1, D), mode="drop"
        )
        # stable unsort (inverse of the step-9 permutation)
        rec_out = jnp.zeros((n, D), xl.dtype).at[order].set(sorted_out)
        w = probs.reshape(-1)[:, None].astype(xl.dtype)
        y = (rec_out * w).reshape(t_loc, k, D).sum(1)
        return y.reshape(bl, sl, D), aux

    if mesh_info.mesh is None:
        # single-device smoke path: p == 1, same code, dummy axis via vmap
        out, aux = jax.vmap(
            lambda xl: body(
                xl,
                params["router"],
                params["w_gate"],
                params["w_up"],
                params["w_down"],
            ),
            axis_name=axis,
        )(x[None])
        return out[0], jax.tree.map(lambda a: a[0], aux)

    dp = _dp_spec(mesh_info, x.shape[0])
    seq = axis if x.shape[1] % mesh_info.model_size == 0 else None
    return shard_map(
        body,
        mesh=mesh_info.mesh,
        in_specs=(
            P(dp, seq, None),
            P(),
            P(axis, None, None),
            P(axis, None, None),
            P(axis, None, None),
        ),
        out_specs=(P(dp, seq, None), P()),
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])


def _dp_spec(mesh_info: MoEMeshInfo, batch: int):
    """Batch sharding over the data axes, or replication when indivisible
    (e.g. the global_batch=1 long-context decode cell)."""
    n = 1
    for a in mesh_info.data_axes:
        n *= mesh_info.mesh.shape[a]
    return mesh_info.data_axes if batch % n == 0 else None


def moe_ep_counts(params: Dict, x: jnp.ndarray, cfg: ArchConfig, mesh_info: MoEMeshInfo):
    """Count-only routing pass for the radix EP route.

    Runs just the router (a (T, D)·(D, E) GEMM — a sliver of the FFN cost)
    and tallies records per destination shard, returning the replicated
    global maximum per-(src, dst) count as a scalar. This is the MoE
    analogue of the sort's count-then-distribute route: expert ids are
    small dense ints, so one counting pass yields the exact dispatch
    capacity and there is nothing to sample or to guess.
    """
    p = mesh_info.model_size
    E, k = cfg.moe_experts, cfg.moe_top_k
    assert E % p == 0, "EP path requires experts divisible by the model axis"
    e_loc = E // p
    axis = mesh_info.model_axis
    all_axes = (
        tuple(mesh_info.data_axes) + (axis,) if mesh_info.mesh is not None else (axis,)
    )

    def body(xl, router_w):
        x2d = xl.reshape(-1, xl.shape[-1])
        _, experts, _ = _router(x2d, router_w, k)
        dest = experts.reshape(-1) // e_loc
        counts = jnp.zeros((p,), jnp.int32).at[dest].add(1)
        return lax.pmax(counts.max(), all_axes)

    if mesh_info.mesh is None:
        return jax.vmap(lambda xl: body(xl, params["router"]), axis_name=axis)(
            x[None]
        )[0]
    dp = _dp_spec(mesh_info, x.shape[0])
    seq = axis if x.shape[1] % mesh_info.model_size == 0 else None
    return shard_map(
        body,
        mesh=mesh_info.mesh,
        in_specs=(P(dp, seq, None), P()),
        out_specs=P(),
    )(x, params["router"])


def moe_capacity_ladder(capacity_factor: float, p: int) -> tuple:
    """EP dispatch capacity tiers, mirroring ``SortConfig.tier_ladder``.

    ``whp``  — the configured guess (pair_cap = ⌈n·cf/p⌉);
    ``whp2`` — the same bound ×2 (squares the failure probability);
    ``full`` — pair_cap = n: the per-destination row can hold every record,
    so no routing pattern can overflow it and the ladder always terminates.
    """
    tiers = [("whp", float(capacity_factor)), ("whp2", 2.0 * capacity_factor)]
    if 2.0 * capacity_factor < p:
        tiers.append(("full", float(p)))
    else:  # whp2 already at/above full capacity — dedupe the terminal rung
        tiers[-1] = ("full", float(p))
    return tuple(tiers)


#: jitted EP dispatch callables keyed by (cfg, mesh_info, capacity_factor) —
#: all frozen/hashable, so each ladder rung compiles once per process.
_EP_JIT_CACHE: Dict[tuple, object] = {}


def _moe_ep_jitted(
    cfg: ArchConfig,
    mesh_info: MoEMeshInfo,
    capacity_factor: float,
    pair_cap: Optional[int] = None,
):
    key = (cfg, mesh_info, float(capacity_factor), pair_cap)
    fn = _EP_JIT_CACHE.get(key)
    if fn is None:
        fn = _EP_JIT_CACHE[key] = jax.jit(
            lambda p, x: moe_ep(
                p, x, cfg, mesh_info, capacity_factor, pair_cap_override=pair_cap
            )
        )
    return fn


def _moe_ep_counts_jitted(cfg: ArchConfig, mesh_info: MoEMeshInfo):
    key = ("counts", cfg, mesh_info)
    fn = _EP_JIT_CACHE.get(key)
    if fn is None:
        fn = _EP_JIT_CACHE[key] = jax.jit(
            lambda p, x: moe_ep_counts(p, x, cfg, mesh_info)
        )
    return fn


def moe_ep_safe(
    params: Dict,
    x: jnp.ndarray,
    cfg: ArchConfig,
    mesh_info: MoEMeshInfo,
    capacity_factor: float = 1.25,
    stats: Optional[TierStats] = None,
    planner=None,
    route: str = "sample",
    obs=None,
) -> Tuple[jnp.ndarray, Dict, TierStats]:
    """Overflow-safe EP dispatch: escalate the capacity tier on token drop.

    The host-side analogue of ``bsp_sort_safe`` for MoE routing: run the
    jitted EP layer at each rung of :func:`moe_capacity_ladder`, inspect the
    replicated ``aux['overflow']`` flag, and retry at the next capacity tier
    until no token was dropped. The terminal ``full`` rung sizes every
    (src, dst) row at n records, which cannot overflow. Use at serving /
    evaluation time (top-level calls with a host sync per layer); the jitted
    train step keeps the fixed-capacity :func:`moe_ep`.

    ``route="radix"`` replaces the guess-and-retry ladder with
    count-then-distribute: one cheap router-only pass
    (:func:`moe_ep_counts`) host-reads the true maximum per-(src, dst)
    record count, and the dispatch runs exactly once with the receive
    buffer bounded by that count (quantized to octave steps so the jit
    cache stays bounded). No ``capacity_factor`` guess, no ``whp`` rungs,
    no ``full``-tier p·n fallback — overflow is impossible by
    construction, so radix batches always report zero retries.

    ``planner`` (a :class:`repro.planner.CapacityPlanner`) is an optional
    traffic-learned policy over the same ladder: a model whose router
    keeps dropping tokens at the ``whp`` guess stops paying the doomed
    attempt and starts at the rung that empirically serves. (The radix
    route has a single rung, so the planner has nothing to learn there.)

    ``obs`` (a :class:`repro.obs.Tracer`) records per-attempt dispatch
    spans on a ``moe`` timeline lane — the counting-pass host sync and each
    rung's launch-to-decision wall — without touching the jitted programs.
    """
    stats = stats if stats is not None else TierStats()
    tracer = obs_mod.resolve_tracer(obs)
    tid = tracer.next_tid("moe") if tracer is not None else None
    if route == "radix":
        # one host sync: the true max records any (src, dst) pair carries
        t0 = tracer.now() if tracer is not None else 0.0
        pair_true = int(_moe_ep_counts_jitted(cfg, mesh_info)(params, x))
        if tracer is not None:
            tracer.add_span(
                "count", t0, cat="moe", tid=tid, pair_true=pair_true
            )
            tracer.point("host_sync", cat="moe", tid=tid, what="moe_counts")
        # quantize up to ~16 steps per octave: bounds distinct compiled
        # programs while staying within 1/16th of the exact bound
        step = max(8, 1 << max(0, pair_true.bit_length() - 4))
        qpair = -(-max(pair_true, 1) // step) * step
        t1 = tracer.now() if tracer is not None else 0.0
        y, aux = _moe_ep_jitted(cfg, mesh_info, 1.0, pair_cap=qpair)(params, x)
        overflow = bool(aux["overflow"])
        if tracer is not None:
            tracer.add_span(
                "dispatch", t1, cat="moe", tid=tid,
                tier="radix", ok=not overflow, pair_cap=qpair,
            )
        obs_mod.metrics().counter("moe.radix_dispatches").inc()
        if overflow:  # caps >= true counts: unreachable
            raise RuntimeError(
                "radix EP dispatch overflowed its counted capacity"
            )
        stats.record("radix", True)
        return y, aux, stats
    ladder = moe_capacity_ladder(capacity_factor, mesh_info.model_size)
    n_rungs, bucket = len(ladder), None
    if planner is not None and n_rungs > 1:
        bucket = (
            f"moe/{cfg.name}/ep{mesh_info.model_size}"
            f"/t{x.shape[0] * x.shape[1]}/cf{capacity_factor}"
        )
        ladder = ladder[planner.rung_for(bucket, n_rungs) :]
    faulted = False
    for tier, cf in ladder:
        t0 = tracer.now() if tracer is not None else 0.0
        y, aux = _moe_ep_jitted(cfg, mesh_info, cf)(params, x)
        ok = not bool(aux["overflow"])
        if tracer is not None:
            tracer.add_span(
                "dispatch", t0, cat="moe", tid=tid,
                tier=tier, ok=ok, capacity_factor=cf,
            )
        stats.record(tier, ok)
        if ok:
            if bucket is not None:
                planner.observe(bucket, faulted, n_rungs)
            return y, aux, stats
        faulted = True
    raise RuntimeError(
        "EP capacity escalation exhausted — unreachable: the full tier "
        "holds every record"
    )


def moe_ep_decode(params: Dict, x: jnp.ndarray, cfg: ArchConfig, mesh_info: MoEMeshInfo):
    """EP MoE for tiny token counts (decode): every shard evaluates its local
    experts on every token (cheap at T = batch), combined with one psum — no
    all_to_all, no capacity. The absolute extra FLOPs are O(B·E·D·F), dwarfed
    by the attention cache reads at decode time."""
    p = mesh_info.model_size
    E, k = cfg.moe_experts, cfg.moe_top_k
    e_loc = E // p
    axis = mesh_info.model_axis
    all_axes = tuple(mesh_info.data_axes) + (axis,)

    def body(xl, router_w, wg, wu, wd):
        bl, sl, D = xl.shape
        x2d = xl.reshape(-1, D)
        probs, experts, aux = _router(x2d, router_w, k)
        me = lax.axis_index(axis)
        y = jnp.zeros_like(x2d)
        for e in range(e_loc):
            ge = me * e_loc + e
            w_tok = (probs * (experts == ge)).sum(-1).astype(xl.dtype)  # (T,)
            y = y + w_tok[:, None] * _expert_ffn(x2d, wg[e], wu[e], wd[e])
        y = lax.psum(y, axis)
        aux = {kk: lax.pmean(vv, all_axes) for kk, vv in aux.items()}
        aux["overflow"] = jnp.zeros((), bool)
        return y.reshape(bl, sl, D), aux

    dp = _dp_spec(mesh_info, x.shape[0])
    return shard_map(
        body,
        mesh=mesh_info.mesh,
        in_specs=(
            P(dp, None, None),
            P(),
            P(axis, None, None),
            P(axis, None, None),
            P(axis, None, None),
        ),
        out_specs=(P(dp, None, None), P()),
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])
