from repro.models.lm import Model, make_mesh_info  # noqa: F401
from repro.models.moe import MoEMeshInfo  # noqa: F401
