"""Shared transformer building blocks (pure functions over param pytrees).

Params are plain nested dicts of jnp arrays; every per-layer leaf carries a
leading ``L`` dim so the layer stack lowers to one ``lax.scan`` (HLO size
independent of depth — essential for 512-device dry-run compiles).
Compute dtype is bf16 with fp32 accumulations in norms/softmax/loss.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def dtype_of(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ------------------------------------------------------------------- init
def _dense(rng, shape, scale_dim, dtype):
    return (jax.random.normal(rng, shape, jnp.float32) / jnp.sqrt(scale_dim)).astype(
        dtype
    )


def init_attn(rng, cfg: ArchConfig, layers: int) -> Dict:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(rng, 4)
    dt = dtype_of(cfg)
    return {
        "wq": _dense(ks[0], (layers, D, H * hd), D, dt),
        "wk": _dense(ks[1], (layers, D, KV * hd), D, dt),
        "wv": _dense(ks[2], (layers, D, KV * hd), D, dt),
        "wo": _dense(ks[3], (layers, H * hd, D), H * hd, dt),
    }


def init_mlp(rng, cfg: ArchConfig, layers: int, d_ff: int | None = None) -> Dict:
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(rng, 3)
    dt = dtype_of(cfg)
    return {
        "w_gate": _dense(ks[0], (layers, D, F), D, dt),
        "w_up": _dense(ks[1], (layers, D, F), D, dt),
        "w_down": _dense(ks[2], (layers, F, D), F, dt),
    }


# ---------------------------------------------------------------- normals
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def swiglu(x: jnp.ndarray, p: Dict) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


# -------------------------------------------------------------------- rope
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, :d]


# -------------------------------------------------------------------- loss
def next_token_loss(
    logits: jnp.ndarray, labels: jnp.ndarray, mask: jnp.ndarray | None = None
) -> jnp.ndarray:
    """Mean cross-entropy; logits (B, S, V) possibly vocab-sharded (the
    logsumexp reduction partitions cleanly), labels (B, S)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
