"""Whisper-style encoder-decoder backbone (audio frontend stubbed per brief).

``input_specs`` provides precomputed (enc_positions, d_model) frame
embeddings (the conv frontend stub); the encoder is bidirectional
self-attention; the decoder adds causal self-attention (KV-cached at decode)
and cross-attention whose K/V are computed once at prefill.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.layers import (
    _dense,
    dtype_of,
    init_attn,
    init_mlp,
    next_token_loss,
    rmsnorm,
    sinusoidal_positions,
)


def init_params(cfg: ArchConfig, rng: jax.Array) -> Dict:
    D, V, L, Le = cfg.d_model, cfg.vocab, cfg.n_layers, cfg.enc_layers
    dt = dtype_of(cfg)
    ks = jax.random.split(rng, 10)
    return {
        "embed": _dense(ks[0], (V, D), D, dt),
        "enc": {
            "attn_norm": jnp.ones((Le, D), dt),
            "mlp_norm": jnp.ones((Le, D), dt),
            **init_attn(ks[1], cfg, Le),
            **init_mlp(ks[2], cfg, Le),
        },
        "dec": {
            "attn_norm": jnp.ones((L, D), dt),
            "cross_norm": jnp.ones((L, D), dt),
            "mlp_norm": jnp.ones((L, D), dt),
            **init_attn(ks[3], cfg, L),
            **{
                f"x{k}": v
                for k, v in init_attn(ks[4], cfg, L).items()  # cross-attn
            },
            **init_mlp(ks[5], cfg, L),
        },
        "enc_final_norm": jnp.ones((D,), dt),
        "final_norm": jnp.ones((D,), dt),
        "lm_head": _dense(ks[6], (D, V), D, dt),
    }


def _attend(cfg, h, wq, wk, wv, wo, positions_q, kv=None, causal=True):
    b, s, D = h.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,de->bse", h, wq).reshape(b, s, H, hd)
    if kv is None:
        k = jnp.einsum("bsd,de->bse", h, wk).reshape(b, s, KV, hd)
        v = jnp.einsum("bsd,de->bse", h, wv).reshape(b, s, KV, hd)
    else:
        k, v = kv
    o = attn.flash_attention(q, k, v, causal=causal)
    return jnp.einsum("bse,ed->bsd", o.reshape(b, s, H * hd), wo), (k, v)


def encode(cfg: ArchConfig, params: Dict, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, T, D) precomputed stub embeddings."""
    x = frames.astype(dtype_of(cfg)) + sinusoidal_positions(
        frames.shape[1], cfg.d_model
    ).astype(dtype_of(cfg))

    def body(x, lp):
        h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
        o, _ = _attend(cfg, h, lp["wq"], lp["wk"], lp["wv"], lp["wo"], None, causal=False)
        x = x + o
        h2 = rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
        g = jnp.einsum("bsd,df->bsf", h2, lp["w_gate"])
        u = jnp.einsum("bsd,df->bsf", h2, lp["w_up"])
        y = jnp.einsum(
            "bsf,fd->bsd", jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u, lp["w_down"]
        )
        return x + y, None

    x, _ = lax.scan(body, x, params["enc"])
    return rmsnorm(x, params["enc_final_norm"], cfg.norm_eps)


def _dec_block(cfg, x, lp, enc_kv, causal=True):
    h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    o, self_kv = _attend(cfg, h, lp["wq"], lp["wk"], lp["wv"], lp["wo"], None, causal=causal)
    x = x + o
    hx = rmsnorm(x, lp["cross_norm"], cfg.norm_eps)
    o2, _ = _attend(cfg, hx, lp["xwq"], lp["xwk"], lp["xwv"], lp["xwo"], None, kv=enc_kv, causal=False)
    x = x + o2
    h2 = rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    g = jnp.einsum("bsd,df->bsf", h2, lp["w_gate"])
    u = jnp.einsum("bsd,df->bsf", h2, lp["w_up"])
    y = jnp.einsum(
        "bsf,fd->bsd", jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u, lp["w_down"]
    )
    return x + y, self_kv


def forward_train(cfg, params, tokens, labels, mesh_info=None, extras=None):
    extras = extras or {}
    frames = extras["frames"]  # (B, T, D) stub
    enc_out = encode(cfg, params, frames)
    b, s = tokens.shape
    x = params["embed"][tokens] + sinusoidal_positions(s, cfg.d_model).astype(
        dtype_of(cfg)
    )

    def body(x, lp):
        KV, hd = cfg.n_kv_heads, cfg.hd
        ek = jnp.einsum("btd,de->bte", enc_out, lp["xwk"]).reshape(
            b, enc_out.shape[1], KV, hd
        )
        ev = jnp.einsum("btd,de->bte", enc_out, lp["xwv"]).reshape(
            b, enc_out.shape[1], KV, hd
        )
        x, _ = _dec_block(cfg, x, lp, (ek, ev))
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["dec"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return next_token_loss(logits[:, :-1], labels[:, 1:]), {}


def prefill(cfg, params, tokens, mesh_info=None, extras=None, cache_len=None):
    """Encode frames, run the prompt through the decoder, build caches."""
    extras = extras or {}
    enc_out = encode(cfg, params, extras["frames"])
    b, s = tokens.shape
    cache_len = cache_len or s
    x = params["embed"][tokens] + sinusoidal_positions(s, cfg.d_model).astype(
        dtype_of(cfg)
    )

    def body(x, lp):
        KV, hd = cfg.n_kv_heads, cfg.hd
        t = enc_out.shape[1]
        ek = jnp.einsum("btd,de->bte", enc_out, lp["xwk"]).reshape(b, t, KV, hd)
        ev = jnp.einsum("btd,de->bte", enc_out, lp["xwv"]).reshape(b, t, KV, hd)
        x, (k, v) = _dec_block(cfg, x, lp, (ek, ev))
        pad = cache_len - s
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x, (kc, vc, ek, ev)

    x, (kc, vc, ek, ev) = lax.scan(body, x, params["dec"])
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
    return {
        "k": kc,
        "v": vc,
        "xk": ek,
        "xv": ev,
        "pos": jnp.full((), s - 1, jnp.int32),
    }, logits


def decode_step(cfg, params, cache, token, mesh_info=None):
    b = token.shape[0]
    pos = cache["pos"] + 1
    x = params["embed"][token][:, None, :]
    # learned-position stub: sinusoidal at pos
    posemb = sinusoidal_positions(cache["k"].shape[2], cfg.d_model)
    x = x + lax.dynamic_index_in_dim(posemb, pos, 0, keepdims=True).astype(x.dtype)

    def body(x, inputs):
        lp, kc, vc, ek, ev = inputs
        H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
        q = jnp.einsum("bsd,de->bse", h, lp["wq"]).reshape(b, 1, H, hd)
        k = jnp.einsum("bsd,de->bse", h, lp["wk"]).reshape(b, 1, KV, hd)
        v = jnp.einsum("bsd,de->bse", h, lp["wv"]).reshape(b, 1, KV, hd)
        kc, vc = attn.cache_update(kc, vc, k, v, pos)
        o = attn.decode_attention(q, kc, vc, pos)
        x = x + jnp.einsum("bse,ed->bsd", o.reshape(b, 1, H * hd), lp["wo"])
        hx = rmsnorm(x, lp["cross_norm"], cfg.norm_eps)
        qx = jnp.einsum("bsd,de->bse", hx, lp["xwq"]).reshape(b, 1, H, hd)
        ox = attn.decode_attention(
            qx, ek, ev, jnp.full((), ek.shape[1] - 1, jnp.int32)
        )
        x = x + jnp.einsum("bse,ed->bsd", ox.reshape(b, 1, H * hd), lp["xwo"])
        h2 = rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
        g = jnp.einsum("bsd,df->bsf", h2, lp["w_gate"])
        u = jnp.einsum("bsd,df->bsf", h2, lp["w_up"])
        y = jnp.einsum(
            "bsf,fd->bsd",
            jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u,
            lp["w_down"],
        )
        return x + y, (kc, vc)

    x, (kc, vc) = lax.scan(
        body, x, (params["dec"], cache["k"], cache["v"], cache["xk"], cache["xv"])
    )
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
    return logits, {"k": kc, "v": vc, "xk": cache["xk"], "xv": cache["xv"], "pos": pos}


def cache_shapes(cfg: ArchConfig, batch: int, cache_len: int):
    KV, hd, L = cfg.n_kv_heads, cfg.hd, cfg.n_layers
    dt = dtype_of(cfg)
    t = cfg.enc_positions
    return {
        "k": jax.ShapeDtypeStruct((L, batch, cache_len, KV, hd), dt),
        "v": jax.ShapeDtypeStruct((L, batch, cache_len, KV, hd), dt),
        "xk": jax.ShapeDtypeStruct((L, batch, t, KV, hd), dt),
        "xv": jax.ShapeDtypeStruct((L, batch, t, KV, hd), dt),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
