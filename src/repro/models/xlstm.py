"""xLSTM LM: mLSTM blocks with an sLSTM block every ``slstm_every`` layers.

Scan-over-layers is applied per block *kind* (two scans: the mLSTM stack
dominates). Attention-free ⇒ O(1)-state decode ⇒ long_500k runs.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import ssm
from repro.models.layers import _dense, dtype_of, next_token_loss, rmsnorm


def _layout(cfg: ArchConfig):
    ks = cfg.slstm_every or (cfg.n_layers + 1)
    slstm_ids = [i for i in range(cfg.n_layers) if (i + 1) % ks == 0]
    mlstm_ids = [i for i in range(cfg.n_layers) if (i + 1) % ks != 0]
    return mlstm_ids, slstm_ids


def init_params(cfg: ArchConfig, rng: jax.Array) -> Dict:
    D, V = cfg.d_model, cfg.vocab
    dt = dtype_of(cfg)
    mids, sids = _layout(cfg)
    ks = jax.random.split(rng, 4)
    return {
        "embed": _dense(ks[0], (V, D), D, dt),
        "mlstm": {
            "norm": jnp.ones((len(mids), D), dt),
            "norm2": jnp.ones((len(mids), D), dt),
            **ssm.init_mlstm(ks[1], cfg, len(mids)),
        },
        "slstm": {
            "norm": jnp.ones((len(sids), D), dt),
            "norm2": jnp.ones((len(sids), D), dt),
            **ssm.init_slstm(ks[2], cfg, len(sids)),
        },
        "final_norm": jnp.ones((D,), dt),
        "lm_head": _dense(ks[3], (D, V), D, dt),
    }


def _mlstm_block(cfg, x, lp, state=None):
    h = rmsnorm(x, lp["norm"], cfg.norm_eps)
    o, st = ssm.mlstm_core(
        {k: lp[k] for k in ("wq", "wk", "wv", "wo", "w_i", "w_f", "b_i", "b_f")},
        h,
        cfg,
        state,
    )
    x = x + o
    h2 = rmsnorm(x, lp["norm2"], cfg.norm_eps)
    x = x + ssm.xlstm_proj({"up": lp["up"], "down": lp["down"]}, h2)
    return x, st


def _slstm_block(cfg, x, lp, state=None):
    h = rmsnorm(x, lp["norm"], cfg.norm_eps)
    o, st = ssm.slstm_core(
        {k: lp[k] for k in ("w_zifo", "b_zifo", "wo")}, h, cfg, state
    )
    x = x + o
    h2 = rmsnorm(x, lp["norm2"], cfg.norm_eps)
    x = x + ssm.xlstm_proj({"up": lp["up"], "down": lp["down"]}, h2)
    return x, st


def _stack(cfg, params, x, states=None):
    """Run the interleaved stack; mLSTM scanned, sLSTM unrolled (few)."""
    mids, sids = _layout(cfg)
    new_m, new_s = [], []
    # interleave in true layer order; mLSTM params indexed by position in mids
    im = is_ = 0
    for i in range(cfg.n_layers):
        if i in sids:
            lp = jax.tree.map(lambda a: a[is_], params["slstm"])
            st = None if states is None else jax.tree.map(lambda a: a[is_], states["slstm"])
            x, stn = _slstm_block(cfg, x, lp, st)
            new_s.append(stn)
            is_ += 1
        else:
            lp = jax.tree.map(lambda a: a[im], params["mlstm"])
            st = None if states is None else jax.tree.map(lambda a: a[im], states["mlstm"])
            x, stn = _mlstm_block(cfg, x, lp, st)
            new_m.append(stn)
            im += 1
    pack = lambda lst: jax.tree.map(lambda *xs: jnp.stack(xs), *lst) if lst else ()
    return x, {"mlstm": pack(new_m), "slstm": pack(new_s)}


def forward_train(cfg, params, tokens, labels, mesh_info=None, extras=None):
    x = params["embed"][tokens]
    x, _ = _stack(cfg, params, x)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return next_token_loss(logits[:, :-1], labels[:, 1:]), {}


def prefill(cfg, params, tokens, mesh_info=None, extras=None, cache_len=None):
    b, s = tokens.shape
    x = params["embed"][tokens]
    x, states = _stack(cfg, params, x)
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
    states["pos"] = jnp.full((), s - 1, jnp.int32)
    return states, logits


def decode_step(cfg, params, cache, token, mesh_info=None):
    x = params["embed"][token][:, None, :]
    x, states = _stack(cfg, params, x, states=cache)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
    states["pos"] = cache["pos"] + 1
    return logits, states


def cache_shapes(cfg: ArchConfig, batch: int, cache_len: int):
    del cache_len  # O(1) state — the whole point of the SSM family
    mids, sids = _layout(cfg)
    D, H = cfg.d_model, cfg.n_heads
    hd = D // H
    nm, ns = len(mids), len(sids)
    f32 = jnp.float32
    return {
        "mlstm": (
            jax.ShapeDtypeStruct((nm, batch, H, hd, hd), f32),
            jax.ShapeDtypeStruct((nm, batch, H, hd), f32),
            jax.ShapeDtypeStruct((nm, batch, H), f32),
        ),
        "slstm": (
            jax.ShapeDtypeStruct((ns, batch, D), f32),
            jax.ShapeDtypeStruct((ns, batch, D), f32),
            jax.ShapeDtypeStruct((ns, batch, D), f32),
        ),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
