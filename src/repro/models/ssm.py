"""State-space / recurrent blocks: Mamba (S6) for jamba, mLSTM/sLSTM for xlstm.

Both families are linear in sequence length (constant-size recurrent state),
which is what qualifies jamba/xlstm for the long_500k cell. Training uses a
``lax.scan`` over time (an associative-scan variant is a §Perf lever);
decoding is a single recurrence step on a carried state — O(1) per token
regardless of context length.

Simplifications vs the reference implementations (documented per DESIGN.md):
Mamba keeps the S6 selective scan with low-rank Δ projection but omits
bidirectional/groups; sLSTM omits the recurrent gate matrices R (gates are
input-conditioned only); mLSTM follows the exponential-gating/stabilizer
formulation with per-head scalar gates.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.layers import _dense, dtype_of


# ------------------------------------------------------------------ mamba
def mamba_dims(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    di = cfg.mamba_expand * cfg.d_model
    return di, cfg.mamba_d_state, cfg.mamba_d_conv, max(cfg.d_model // 16, 1)


def init_mamba(rng, cfg: ArchConfig, layers: int) -> Dict:
    D = cfg.d_model
    di, N, dk, dtr = mamba_dims(cfg)
    ks = jax.random.split(rng, 8)
    dt = dtype_of(cfg)
    return {
        "in_proj": _dense(ks[0], (layers, D, 2 * di), D, dt),
        "conv_w": _dense(ks[1], (layers, dk, di), dk, dt),
        "conv_b": jnp.zeros((layers, di), dt),
        "w_xdbc": _dense(ks[2], (layers, di, dtr + 2 * N), di, dt),
        "w_dt": _dense(ks[3], (layers, dtr, di), dtr, jnp.float32),
        "b_dt": jnp.full((layers, di), -4.6, jnp.float32),  # softplus ≈ 0.01
        "A_log": jnp.tile(
            jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32))[None, None, :],
            (layers, di, 1),
        ),
        "D": jnp.ones((layers, di), jnp.float32),
        "out_proj": _dense(ks[4], (layers, di, D), di, dt),
    }


def _mamba_inner(p: Dict, x1, z, h0, cfg: ArchConfig):
    """Selective scan. x1 (B,S,di) post-conv, h0 (B,di,N). Returns y, h."""
    di, N, _, dtr = mamba_dims(cfg)
    A = -jnp.exp(p["A_log"])  # (di, N)
    xdbc = jnp.einsum("bsd,dr->bsr", x1, p["w_xdbc"]).astype(jnp.float32)
    dtr_part, B_part, C_part = jnp.split(xdbc, [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,rd->bsd", dtr_part, p["w_dt"]) + p["b_dt"])

    def step(h, inp):
        dt_t, b_t, c_t, x_t = inp  # (B,di) (B,N) (B,N) (B,di)
        da = jnp.exp(dt_t[:, :, None] * A[None])  # (B,di,N)
        h = da * h + (dt_t * x_t.astype(jnp.float32))[:, :, None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (
        dt.swapaxes(0, 1),
        B_part.swapaxes(0, 1),
        C_part.swapaxes(0, 1),
        x1.swapaxes(0, 1),
    )
    h, ys = lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1) + p["D"] * x1.astype(jnp.float32)  # (B,S,di)
    y = y.astype(x1.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x1.dtype)
    return y, h


def mamba_block(p: Dict, x: jnp.ndarray, cfg: ArchConfig, state=None):
    """x (B,S,D) -> (y (B,S,D), state). state = (h (B,di,N), conv (B,dk-1,di))."""
    b, s, D = x.shape
    di, N, dk, _ = mamba_dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    x1, z = jnp.split(xz, 2, axis=-1)
    if state is None:
        conv_st = jnp.zeros((b, dk - 1, di), x.dtype)
        h0 = jnp.zeros((b, di, N), jnp.float32)
    else:
        h0, conv_st = state
    # causal conv over time with carried left context
    xc = jnp.concatenate([conv_st, x1], axis=1)  # (B, S+dk-1, di)
    conv = sum(
        xc[:, i : i + s, :] * p["conv_w"][i][None, None, :] for i in range(dk)
    ) + p["conv_b"]
    x1 = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    y, h = _mamba_inner(p, x1, z, h0, cfg)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    new_conv = xc[:, -(dk - 1) :, :] if dk > 1 else conv_st
    return out, (h, new_conv)


def mamba_state_shape(cfg: ArchConfig, batch: int):
    di, N, dk, _ = mamba_dims(cfg)
    return ((batch, di, N), (batch, dk - 1, di))


# ------------------------------------------------------------------ xlstm
def init_mlstm(rng, cfg: ArchConfig, layers: int) -> Dict:
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.d_model // cfg.n_heads
    ks = jax.random.split(rng, 7)
    dt = dtype_of(cfg)
    return {
        "wq": _dense(ks[0], (layers, D, D), D, dt),
        "wk": _dense(ks[1], (layers, D, D), D, dt),
        "wv": _dense(ks[2], (layers, D, D), D, dt),
        "wo": _dense(ks[3], (layers, D, D), D, dt),
        "w_i": _dense(ks[4], (layers, D, H), D, jnp.float32),
        "w_f": _dense(ks[5], (layers, D, H), D, jnp.float32),
        "b_i": jnp.zeros((layers, H), jnp.float32),
        "b_f": jnp.full((layers, H), 3.0, jnp.float32),
        "up": _dense(ks[6], (layers, D, 2 * D), D, dt),
        "down": _dense(jax.random.fold_in(ks[6], 1), (layers, 2 * D, D), 2 * D, dt),
    }


def mlstm_core(p: Dict, x: jnp.ndarray, cfg: ArchConfig, state=None):
    """Matrix-memory LSTM with exponential gating + stabilizer.

    state = (C (B,H,hd,hd), n (B,H,hd), m (B,H)).
    """
    b, s, D = x.shape
    H = cfg.n_heads
    hd = D // H
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(b, s, H, hd)
    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(b, s, H, hd) / jnp.sqrt(hd)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(b, s, H, hd)
    log_i = (jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["w_i"]) + p["b_i"])
    log_f = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["w_f"]) + p["b_f"]
    )
    if state is None:
        C0 = jnp.zeros((b, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, H, hd), jnp.float32)
        m0 = jnp.full((b, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, li, lf = inp
        m_new = jnp.maximum(lf + m, li)
        i_ = jnp.exp(li - m_new)[..., None]
        f_ = jnp.exp(lf + m - m_new)[..., None]
        kf = kt.astype(jnp.float32)
        vf = vt.astype(jnp.float32)
        C = f_[..., None] * C + i_[..., None] * (vf[..., :, None] * kf[..., None, :])
        n = f_ * n + i_ * kf
        qf = qt.astype(jnp.float32)
        num = jnp.einsum("bhij,bhj->bhi", C, qf)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, qf)), 1.0)
        return (C, n, m_new), (num / den[..., None])

    xs = tuple(a.swapaxes(0, 1) for a in (q, k, v, log_i, log_f))
    (C, n, m), hs = lax.scan(step, (C0, n0, m0), xs)
    h = hs.swapaxes(0, 1).reshape(b, s, D).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", h, p["wo"])
    return out, (C, n, m)


def init_slstm(rng, cfg: ArchConfig, layers: int) -> Dict:
    D = cfg.d_model
    ks = jax.random.split(rng, 3)
    dt = dtype_of(cfg)
    return {
        "w_zifo": _dense(ks[0], (layers, D, 4 * D), D, jnp.float32),
        "b_zifo": jnp.zeros((layers, 4 * D), jnp.float32),
        "up": _dense(ks[1], (layers, D, 2 * D), D, dt),
        "down": _dense(ks[2], (layers, 2 * D, D), 2 * D, dt),
        "wo": _dense(jax.random.fold_in(ks[2], 1), (layers, D, D), D, dt),
    }


def slstm_core(p: Dict, x: jnp.ndarray, cfg: ArchConfig, state=None):
    """Scalar-memory LSTM with exponential gating. state = (c, n, m) (B,D)."""
    b, s, D = x.shape
    zifo = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["w_zifo"]) + p["b_zifo"]
    z, log_i, f_pre, o = jnp.split(zifo, 4, axis=-1)
    log_f = jax.nn.log_sigmoid(f_pre)
    if state is None:
        c0 = jnp.zeros((b, D), jnp.float32)
        n0 = jnp.zeros((b, D), jnp.float32)
        m0 = jnp.full((b, D), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state

    def step(carry, inp):
        c, n, m = carry
        zt, li, lf, ot = inp
        m_new = jnp.maximum(lf + m, li)
        i_ = jnp.exp(li - m_new)
        f_ = jnp.exp(lf + m - m_new)
        c = f_ * c + i_ * jnp.tanh(zt)
        n = f_ * n + i_
        h = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1.0)
        return (c, n, m_new), h

    xs = tuple(a.swapaxes(0, 1) for a in (z, log_i, log_f, o))
    (c, n, m), hs = lax.scan(step, (c0, n0, m0), xs)
    h = hs.swapaxes(0, 1).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", h, p["wo"])
    return out, (c, n, m)


def xlstm_proj(p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """Post-core up/down projection (replaces the FFN; d_ff=0 per spec)."""
    u = jnp.einsum("bsd,de->bse", x, p["up"])  # (.., 2D)
    h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", h, p["down"])
