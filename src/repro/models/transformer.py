"""Decoder-only transformer LM (families: dense, moe, vlm).

Scan-over-layers (HLO depth-independent), pre-norm GQA attention with RoPE,
SwiGLU or MoE MLP, optional sliding window (mixtral). The VLM family
receives stub patch embeddings (per the brief) overwriting the first
``vision_tokens`` positions.

Three entry points per the shape kinds: ``forward_train`` (full logits →
loss), ``prefill`` (build KV cache, last-position logits), ``decode_step``
(one token through the cache).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.layers import (
    _dense,
    dtype_of,
    init_attn,
    init_mlp,
    next_token_loss,
    rmsnorm,
    rope,
)


def init_params(cfg: ArchConfig, rng: jax.Array) -> Dict:
    L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab
    ks = jax.random.split(rng, 6)
    dt = dtype_of(cfg)
    layers = {
        "attn_norm": jnp.ones((L, D), dt),
        "mlp_norm": jnp.ones((L, D), dt),
        **init_attn(ks[0], cfg, L),
    }
    if cfg.moe_experts:
        layers.update(moe_mod.init_moe(ks[1], cfg, L))
    else:
        layers.update(init_mlp(ks[1], cfg, L))
    return {
        "embed": _dense(ks[2], (V, D), D, dt),
        "layers": layers,
        "final_norm": jnp.ones((D,), dt),
        "lm_head": _dense(ks[3], (D, V), D, dt),
    }


def _shard_residual(x, cfg: ArchConfig, mesh_info, *, seq_shard: bool):
    """Megatron-SP style: keep the residual stream sequence-sharded over the
    model axis between blocks (activation memory / lg p per device)."""
    if mesh_info is None or mesh_info.mesh is None:
        return x
    dp = mesh_info.data_axes
    seq = (
        mesh_info.model_axis
        if (seq_shard and cfg.seq_shard_activations and mesh_info.model_axis not in dp)
        else None
    )
    return lax.with_sharding_constraint(
        x, NamedSharding(mesh_info.mesh, P(dp, seq, None))
    )


def _attention_block(cfg, lp, h, positions, *, window, mesh_info=None):
    b, s, D = h.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,de->bse", h, lp["wq"]).reshape(b, s, H, hd)
    k = jnp.einsum("bsd,de->bse", h, lp["wk"]).reshape(b, s, KV, hd)
    v = jnp.einsum("bsd,de->bse", h, lp["wv"]).reshape(b, s, KV, hd)
    q, k, v = _head_shard(cfg, mesh_info, q, k, v)  # reshard ONCE per layer
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if s > 1:
        o = attn.flash_attention(q, k, v, causal=True, window=window)
    else:
        o = attn.reference_attention(q, k, v, causal=True, window=window)
    o = jnp.einsum("bse,ed->bsd", o.reshape(b, s, H * hd), lp["wo"])
    return o, (k, v)


def _head_shard(cfg, mesh_info, q, k, v):
    """Megatron-SP resharding point: with the residual sequence-sharded over
    the model axis, force q/k/v to full-sequence / head-sharded layout HERE,
    so the partitioner inserts one all-to-all per layer instead of
    resharding inside every flash kv-chunk iteration (§Perf iteration 1:
    395 GB → per-layer reshard on tinyllama train_4k)."""
    if mesh_info is None or mesh_info.mesh is None:
        return q, k, v
    dp = mesh_info.data_axes
    if mesh_info.model_axis in dp:  # dp policy: no TP resharding needed
        return q, k, v
    p = mesh_info.model_size
    mesh = mesh_info.mesh
    qs = "model" if q.shape[2] % p == 0 else None
    ks = "model" if k.shape[2] % p == 0 else None
    q = lax.with_sharding_constraint(q, NamedSharding(mesh, P(dp, None, qs, None)))
    k = lax.with_sharding_constraint(k, NamedSharding(mesh, P(dp, None, ks, None)))
    v = lax.with_sharding_constraint(v, NamedSharding(mesh, P(dp, None, ks, None)))
    return q, k, v


def _mlp_block(cfg, lp, h, mesh_info):
    if not cfg.moe_experts:
        g = jnp.einsum("bsd,df->bsf", h, lp["w_gate"])
        u = jnp.einsum("bsd,df->bsf", h, lp["w_up"])
        hh = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * u
        y = jnp.einsum("bsf,fd->bsd", hh, lp["w_down"])
        return y, {}
    mi = mesh_info if mesh_info is not None else moe_mod.MoEMeshInfo()
    moe_params = {k: lp[k] for k in ("router", "w_gate", "w_up", "w_down")}
    if mi.mesh is not None and mi.model_axis in mi.data_axes:
        return moe_mod.moe_tp(moe_params, h, cfg)  # dp policy: all-local
    if cfg.moe_experts >= mi.model_size and mi.mesh is not None and h.shape[1] > 1:
        return moe_mod.moe_ep(moe_params, h, cfg, mi)
    if cfg.moe_experts >= mi.model_size and mi.mesh is not None:
        return moe_mod.moe_ep_decode(moe_params, h, cfg, mi)
    if mi.mesh is not None:
        return moe_mod.moe_tp_sharded(moe_params, h, cfg, mi)
    return moe_mod.moe_tp(moe_params, h, cfg)


def _block_train(cfg: ArchConfig, mesh_info, x, lp, positions):
    x = _shard_residual(x, cfg, mesh_info, seq_shard=True)
    h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    o, _ = _attention_block(
        cfg, lp, h, positions, window=cfg.sliding_window, mesh_info=mesh_info
    )
    x = x + o
    h2 = rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    y, aux = _mlp_block(cfg, lp, h2, mesh_info)
    return x + y, aux


def _aux_zero(cfg):
    if cfg.moe_experts:
        return {
            "lb_loss": jnp.zeros(()),
            "z_loss": jnp.zeros(()),
            "overflow": jnp.zeros((), bool),
        }
    return {}


def _embed(cfg, params, tokens, extras):
    x = params["embed"][tokens]  # (B, S, D)
    if cfg.family == "vlm" and extras.get("patch_embeds") is not None:
        pe = extras["patch_embeds"].astype(x.dtype)  # (B, vt, D)
        vt = pe.shape[1]
        pad = jnp.zeros((pe.shape[0], x.shape[1] - vt, pe.shape[2]), x.dtype)
        mask = (jnp.arange(x.shape[1]) < vt)[None, :, None]
        x = jnp.where(mask, jnp.concatenate([pe, pad], axis=1), x)
    return x


def forward_train(
    cfg: ArchConfig,
    params: Dict,
    tokens: jnp.ndarray,
    labels: jnp.ndarray,
    mesh_info=None,
    extras: Optional[Dict] = None,
) -> Tuple[jnp.ndarray, Dict]:
    extras = extras or {}
    b, s = tokens.shape
    x = _embed(cfg, params, tokens, extras)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    block = functools.partial(_block_train, cfg, mesh_info)
    if cfg.remat:
        block = jax.checkpoint(block, static_argnums=())

    def scan_body(x, lp):
        x, aux = block(x, lp, positions)
        return x, aux

    x, auxs = lax.scan(scan_body, x, params["layers"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    mask = None
    if cfg.family == "vlm":
        mask = (jnp.arange(s) >= cfg.vision_tokens)[None, :] * jnp.ones((b, 1))
    loss = next_token_loss(logits[:, :-1], labels[:, 1:], None if mask is None else mask[:, 1:])
    aux = {k: (v.sum() if k != "overflow" else v.any()) for k, v in auxs.items()}
    if cfg.moe_experts:
        loss = loss + 0.01 * aux.get("lb_loss", 0.0) + 1e-3 * aux.get("z_loss", 0.0)
    return loss, aux


# ------------------------------------------------------------------ serve
def prefill(
    cfg: ArchConfig,
    params: Dict,
    tokens: jnp.ndarray,
    mesh_info=None,
    extras: Optional[Dict] = None,
    cache_len: Optional[int] = None,
) -> Tuple[Dict, jnp.ndarray]:
    """Run the prompt, build the KV cache. Returns (cache, last logits)."""
    extras = extras or {}
    b, s = tokens.shape
    cache_len = cache_len or s
    x = _embed(cfg, params, tokens, extras)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def scan_body(x, lp):
        x = _shard_residual(x, cfg, mesh_info, seq_shard=True)
        h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
        o, (k, v) = _attention_block(
            cfg, lp, h, positions, window=cfg.sliding_window, mesh_info=mesh_info
        )
        x = x + o
        h2 = rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
        y, _ = _mlp_block(cfg, lp, h2, mesh_info)
        pad = cache_len - s
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x + y, (kc, vc)

    x, (kcache, vcache) = lax.scan(scan_body, x, params["layers"])
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
    cache = {"k": kcache, "v": vcache, "pos": jnp.full((), s - 1, jnp.int32)}
    return cache, logits


def decode_step(
    cfg: ArchConfig,
    params: Dict,
    cache: Dict,
    token: jnp.ndarray,  # (B,) previous token
    mesh_info=None,
) -> Tuple[jnp.ndarray, Dict]:
    """One autoregressive step; cache['pos'] is the last filled position."""
    b = token.shape[0]
    pos = cache["pos"] + 1  # position of the new token
    x = params["embed"][token][:, None, :]  # (B,1,D)
    positions = jnp.broadcast_to(pos[None], (b, 1))

    def scan_body(x, inputs):
        lp, kc, vc = inputs
        h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
        H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        q = jnp.einsum("bsd,de->bse", h, lp["wq"]).reshape(b, 1, H, hd)
        k = jnp.einsum("bsd,de->bse", h, lp["wk"]).reshape(b, 1, KV, hd)
        v = jnp.einsum("bsd,de->bse", h, lp["wv"]).reshape(b, 1, KV, hd)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        kc, vc = attn.cache_update(kc, vc, k, v, pos)
        o = attn.decode_attention(q, kc, vc, pos, window=cfg.sliding_window)
        o = jnp.einsum("bse,ed->bsd", o.reshape(b, 1, H * hd), lp["wo"])
        x = x + o
        h2 = rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
        y, _ = _mlp_block(cfg, lp, h2, mesh_info)
        return x + y, (kc, vc)

    x, (kcache, vcache) = lax.scan(
        scan_body, x, (params["layers"], cache["k"], cache["v"])
    )
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
    return logits, {"k": kcache, "v": vcache, "pos": pos}


def cache_shapes(cfg: ArchConfig, batch: int, cache_len: int):
    KV, hd = cfg.n_kv_heads, cfg.hd
    dt = dtype_of(cfg)
    return {
        "k": jax.ShapeDtypeStruct((cfg.n_layers, batch, cache_len, KV, hd), dt),
        "v": jax.ShapeDtypeStruct((cfg.n_layers, batch, cache_len, KV, hd), dt),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
