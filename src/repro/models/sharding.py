"""Parameter / activation PartitionSpec assignment (DESIGN.md §6).

Policy ``2d``: FSDP over ``data`` × TP over ``model`` (weights 2-D sharded;
XLA inserts the per-layer all-gathers — ZeRO-3-style); policy ``1d``: TP
only. The ``pod`` axis is pure DP: parameters are never sharded over it;
gradients are all-reduced hierarchically across it.

Rules are name-based over the param pytree paths; per-layer leaves carry
1-2 leading stack dims which map to ``None``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

# trailing-dims spec by leaf name: (in-dim axis, out-dim axis) semantics.
_MATMUL_RULES = {
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),
    "xwq": ("data", "model"),
    "xwk": ("data", "model"),
    "xwv": ("data", "model"),
    "xwo": ("model", "data"),
    "w_gate": ("data", "model"),
    "w_up": ("data", "model"),
    "w_down": ("model", "data"),
    "in_proj": ("data", "model"),
    "out_proj": ("model", "data"),
    "up": ("data", "model"),
    "down": ("model", "data"),
    "w_zifo": ("data", "model"),
    "w_xdbc": ("model", None),
    "w_dt": (None, "model"),
}
_VECTOR_RULES = {  # 1 trailing dim
    "conv_b": ("model",),
    "b_dt": ("model",),
    "D": ("model",),
}
_MATRIX_RULES = {  # non-matmul 2-trailing-dim leaves
    "conv_w": (None, "model"),
    "A_log": ("model", None),
}


def _path_names(path) -> list:
    return [getattr(k, "key", getattr(k, "idx", None)) for k in path]


def param_specs(
    cfg: ArchConfig, params_tree: Any, model_axis_size: int = 16
) -> Any:
    """PartitionSpec tree matching ``params_tree`` (arrays or ShapeDtypeStructs)."""
    if cfg.param_sharding == "dp":
        # pure data parallelism: replicated weights, every mesh axis shards
        # the batch; optimizer state stays 2-D sharded (ZeRO-1) — see
        # make_train_step. §Perf iteration A2: the right regime for ≲4B
        # archs where TP=16 makes activation collectives dominate.
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: P(*([None] * len(leaf.shape))), params_tree
        )
    fsdp = "data" if cfg.param_sharding == "2d" else None

    def fix(ax):
        return fsdp if ax == "data" else ax

    def spec_for(path, leaf) -> P:
        names = _path_names(path)
        name = names[-1]
        ndim = len(leaf.shape)
        if name == "embed":
            return P("model", fsdp)
        if name == "lm_head":
            return P(fsdp, "model")
        if name in ("router",):
            return P(*([None] * ndim))
        is_moe_leaf = (
            name in ("w_gate", "w_up", "w_down")
            and cfg.moe_experts
            and "dense" not in names  # hybrid's dense-MLP stacks are not MoE
            and ("moe" in names or ndim >= 4)
        )
        if is_moe_leaf:
            # MoE expert tensors (..., E, D, F) / (..., E, F, D)
            lead = [None] * (ndim - 3)
            if cfg.moe_experts >= model_axis_size:  # EP: experts over model
                if name == "w_down":
                    return P(*lead, "model", None, fsdp)
                return P(*lead, "model", fsdp, None)
            # TP: experts replicated, F sharded
            if name == "w_down":
                return P(*lead, None, "model", fsdp)
            return P(*lead, None, fsdp, "model")
        if name in _MATMUL_RULES and ndim >= 2:
            a, b = _MATMUL_RULES[name]
            return P(*([None] * (ndim - 2)), fix(a), fix(b))
        if name in _MATRIX_RULES and ndim >= 2:
            a, b = _MATRIX_RULES[name]
            return P(*([None] * (ndim - 2)), fix(a), fix(b))
        if name in _VECTOR_RULES and ndim >= 1:
            (a,) = _VECTOR_RULES[name]
            return P(*([None] * (ndim - 1)), fix(a))
        return P(*([None] * ndim))  # norms, biases, gates

    return jax.tree_util.tree_map_with_path(spec_for, params_tree)


def dp_axes(mesh: Optional[Mesh], cfg: Optional[ArchConfig] = None):
    if mesh is None:
        return ("data",)
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if cfg is not None and cfg.param_sharding == "dp":
        axes = axes + ("model",)  # the model axis becomes extra DP
    return axes


def batch_specs(cfg: ArchConfig, mesh: Mesh, kind: str) -> Dict[str, P]:
    dp = dp_axes(mesh, cfg)
    specs = {"tokens": P(dp, None), "labels": P(dp, None)}
    if cfg.family == "vlm":
        specs["patch_embeds"] = P(dp, None, None)
    if cfg.family == "audio":
        specs["frames"] = P(dp, None, None)
    if kind == "decode":
        specs = {"token": P(dp)}
    return specs


def cache_specs(cfg: ArchConfig, mesh: Mesh, cache_tree: Any) -> Any:
    """KV caches: batch over DP axes, *sequence over the model axis* (the
    flash-decode layout — see models/attention.py); recurrent states: batch
    over DP, channel dim over model."""
    dp = dp_axes(mesh)

    def spec_for(path, leaf) -> P:
        names = _path_names(path)
        name = names[0] if names else None
        ndim = len(leaf.shape)
        if name in ("k", "v", "xk", "xv"):  # (L, B, S, KV, hd)
            return P(None, dp, "model", None, None)
        if name == "mamba":  # (blocks, slots, B, di, N) / (blocks, slots, B, dk-1, di)
            if ndim == 5:
                idx = getattr(path[-1], "idx", 0)
                if idx == 0:
                    return P(None, None, dp, "model", None)
                return P(None, None, dp, None, "model")
            return P(*([None] * ndim))
        if name in ("mlstm", "slstm"):
            return P(None, dp, *([None] * (ndim - 2)))
        if name == "pos":
            return P()
        return P(*([None] * ndim))

    return jax.tree_util.tree_map_with_path(spec_for, cache_tree)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def sanitize_specs(mesh: Optional[Mesh], spec_tree: Any, shape_tree: Any) -> Any:
    """Drop spec axes whose mesh size does not divide the dim (pjit requires
    exact divisibility for explicit in_shardings): uneven vocabularies
    (49155, 51865), batch=1 decode cells, GQA kv-heads < model axis, etc.
    fall back to replication on that dim — correctness-neutral, and the
    roofline table shows the cost."""
    if mesh is None:
        return spec_tree

    def fit(dim, entry):
        """Largest prefix of a (possibly multi-axis) entry that divides dim."""
        if entry is None or dim % _axis_size(mesh, entry) == 0:
            return entry
        if isinstance(entry, (tuple, list)):
            for cut in range(len(entry) - 1, 0, -1):
                sub = tuple(entry[:cut])
                if dim % _axis_size(mesh, sub) == 0:
                    return sub if len(sub) > 1 else sub[0]
        return None

    def fix(spec, leaf):
        if not isinstance(spec, P):
            return spec
        dims = leaf.shape
        entries = list(spec) + [None] * (len(dims) - len(spec))
        return P(*(fit(d, e) for d, e in zip(dims, entries)))

    return jax.tree.map(
        fix, spec_tree, shape_tree, is_leaf=lambda x: isinstance(x, P)
    )


def to_shardings(mesh: Optional[Mesh], spec_tree: Any) -> Any:
    if mesh is None:
        return None
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
