"""Unified model API over the four family implementations.

    model = Model(cfg)
    params = model.init(rng)
    loss, aux = model.train_loss(params, batch, mesh_info)
    cache, logits = model.prefill(params, tokens, ...)
    logits, cache = model.decode_step(params, cache, token)
    specs = model.input_specs(shape)      # ShapeDtypeStructs for the dry-run

``input_specs`` provides every input as a ShapeDtypeStruct (weak-type
correct, shardable, no allocation) — the modality frontends (audio frames /
vision patches) appear here as precomputed embeddings per the brief.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec, hybrid, transformer, xlstm
from repro.models.layers import dtype_of
from repro.models.moe import MoEMeshInfo

_FAMILY_MODS = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "hybrid": hybrid,
    "ssm": xlstm,
    "audio": encdec,
}


@dataclasses.dataclass
class Model:
    cfg: ArchConfig

    @property
    def mod(self):
        return _FAMILY_MODS[self.cfg.family]

    # ------------------------------------------------------------- params
    def init(self, rng: jax.Array) -> Dict:
        return self.mod.init_params(self.cfg, rng)

    def param_shapes(self, rng: Optional[jax.Array] = None) -> Any:
        """ShapeDtypeStruct tree without allocating (for the dry-run)."""
        rng = rng if rng is not None else jax.random.key(0)
        return jax.eval_shape(lambda r: self.mod.init_params(self.cfg, r), rng)

    # -------------------------------------------------------------- steps
    def train_loss(self, params, batch: Dict, mesh_info=None) -> Tuple[Any, Dict]:
        extras = {
            k: v for k, v in batch.items() if k not in ("tokens", "labels")
        }
        return self.mod.forward_train(
            self.cfg, params, batch["tokens"], batch["labels"], mesh_info, extras
        )

    def prefill(self, params, batch: Dict, mesh_info=None, cache_len=None):
        extras = {k: v for k, v in batch.items() if k != "tokens"}
        return self.mod.prefill(
            self.cfg, params, batch["tokens"], mesh_info, extras, cache_len
        )

    def decode_step(self, params, cache, token, mesh_info=None):
        return self.mod.decode_step(self.cfg, params, cache, token, mesh_info)

    def cache_shapes(self, batch: int, cache_len: int):
        return self.mod.cache_shapes(self.cfg, batch, cache_len)

    # ------------------------------------------------------------- specs
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this cell."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        dt = dtype_of(cfg)
        if shape.kind == "train":
            specs = {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
            if cfg.family == "vlm":
                specs["patch_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.vision_tokens, cfg.d_model), dt
                )
            if cfg.family == "audio":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.enc_positions, cfg.d_model), dt
                )
            return specs
        if shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
            if cfg.family == "vlm":
                specs["patch_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.vision_tokens, cfg.d_model), dt
                )
            if cfg.family == "audio":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.enc_positions, cfg.d_model), dt
                )
            return specs
        # decode: one new token against a seq_len cache
        return {
            "token": jax.ShapeDtypeStruct((B,), i32),
            "cache": self.cache_shapes(B, S),
        }


def make_mesh_info(mesh, cfg: ArchConfig) -> Optional[MoEMeshInfo]:
    if mesh is None:
        return None
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if cfg.param_sharding == "dp":
        dp = dp + ("model",)  # model axis repurposed as extra DP
    return MoEMeshInfo(mesh=mesh, model_axis="model", data_axes=dp)
