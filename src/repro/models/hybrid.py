"""Jamba-style hybrid: Mamba + attention 1:7 interleave, MoE every 2nd layer.

Layer i is attention iff ``i % attn_period == attn_period//2`` (one per
period), else Mamba; the MLP is MoE on odd layers, dense on even. To keep
scan-over-layers, the stack is organized as ``n_layers/attn_period``
*super-blocks*, each containing (period-1) Mamba sub-layers and 1 attention
sub-layer with their MLPs — one ``lax.scan`` over super-blocks, Python loop
over the period inside (HLO size ∝ period, not depth).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.layers import (
    _dense,
    dtype_of,
    init_attn,
    init_mlp,
    next_token_loss,
    rmsnorm,
    rope,
)
from repro.models.transformer import _head_shard, _shard_residual


def _layout(cfg: ArchConfig):
    period = cfg.attn_period
    blocks = cfg.n_layers // period
    return period, blocks


def init_params(cfg: ArchConfig, rng: jax.Array) -> Dict:
    period, blocks = _layout(cfg)
    D, V = cfg.d_model, cfg.vocab
    dt = dtype_of(cfg)
    ks = jax.random.split(rng, 8)
    # one attention sub-layer per super-block
    attn_p = init_attn(ks[0], cfg, blocks)
    # period-1 mamba sub-layers per super-block: leaves (blocks, period-1, ...)
    def per_slot(init_fn, rng, n_slots, count):
        outs = [init_fn(jax.random.fold_in(rng, i), cfg, count) for i in range(n_slots)]
        return jax.tree.map(lambda *xs: jnp.stack(xs, axis=1), *outs)

    mamba_p = per_slot(ssm.init_mamba, ks[1], period - 1, blocks)
    # MLPs: within a period, slots alternate dense/MoE per cfg.moe_every
    n_moe = sum(1 for i in range(period) if cfg.is_moe_layer(i))
    n_dense = period - n_moe
    dense_p = per_slot(init_mlp, ks[2], n_dense, blocks)
    moe_p = per_slot(moe_mod.init_moe, ks[3], n_moe, blocks)
    norms = {
        "attn_norm": jnp.ones((blocks, period, D), dt),
        "mlp_norm": jnp.ones((blocks, period, D), dt),
    }
    return {
        "embed": _dense(ks[4], (V, D), D, dt),
        "blocks": {"attn": attn_p, "mamba": mamba_p, "dense": dense_p, "moe": moe_p, **norms},
        "final_norm": jnp.ones((D,), dt),
        "lm_head": _dense(ks[5], (D, V), D, dt),
    }


def _super_block(cfg, mesh_info, x, bp, positions, states=None, pos=None):
    """One super-block (period sub-layers). states: per-sub-layer decode state."""
    period, _ = _layout(cfg)
    attn_slot = period // 2
    i_mamba = i_dense = i_moe = 0
    new_states = {"mamba": [], "k": None, "v": None}
    aux_acc = None
    b = x.shape[0]
    for i in range(period):
        x = _shard_residual(x, cfg, mesh_info, seq_shard=(x.shape[1] > 1))
        h = rmsnorm(x, bp["attn_norm"][i], cfg.norm_eps)
        if i == attn_slot:
            if states is None:  # train/prefill
                H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
                s = x.shape[1]
                q = jnp.einsum("bsd,de->bse", h, bp["attn"]["wq"]).reshape(b, s, H, hd)
                k = jnp.einsum("bsd,de->bse", h, bp["attn"]["wk"]).reshape(b, s, KV, hd)
                v = jnp.einsum("bsd,de->bse", h, bp["attn"]["wv"]).reshape(b, s, KV, hd)
                q, k, v = _head_shard(cfg, mesh_info, q, k, v)  # reshard once/layer
                q, k = rope(q, positions, cfg.rope_theta), rope(k, positions, cfg.rope_theta)
                o = attn.flash_attention(q, k, v, causal=True)
                new_states["k"], new_states["v"] = k, v
            else:  # decode
                H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
                q = jnp.einsum("bsd,de->bse", h, bp["attn"]["wq"]).reshape(b, 1, H, hd)
                k = jnp.einsum("bsd,de->bse", h, bp["attn"]["wk"]).reshape(b, 1, KV, hd)
                v = jnp.einsum("bsd,de->bse", h, bp["attn"]["wv"]).reshape(b, 1, KV, hd)
                q, k = rope(q, positions, cfg.rope_theta), rope(k, positions, cfg.rope_theta)
                kc, vc = attn.cache_update(states["k"], states["v"], k, v, pos)
                o = attn.decode_attention(q, kc, vc, pos)
                new_states["k"], new_states["v"] = kc, vc
            o = jnp.einsum(
                "bse,ed->bsd", o.reshape(b, o.shape[1], cfg.n_heads * cfg.hd), bp["attn"]["wo"]
            )
        else:
            mp = jax.tree.map(lambda a: a[i_mamba], bp["mamba"])
            st = None if states is None else states["mamba"][i_mamba]
            o, new_st = ssm.mamba_block(mp, h, cfg, st)
            new_states["mamba"].append(new_st)
            i_mamba += 1
        x = x + o
        h2 = rmsnorm(x, bp["mlp_norm"][i], cfg.norm_eps)
        if cfg.is_moe_layer(i):
            lp = jax.tree.map(lambda a: a[i_moe], bp["moe"])
            mi = mesh_info if mesh_info is not None else moe_mod.MoEMeshInfo()
            if mi.mesh is not None and cfg.moe_experts >= mi.model_size and x.shape[1] > 1:
                y, aux = moe_mod.moe_ep(lp, h2, cfg, mi)
            elif mi.mesh is not None and cfg.moe_experts >= mi.model_size:
                y, aux = moe_mod.moe_ep_decode(lp, h2, cfg, mi)
            else:
                y, aux = moe_mod.moe_tp(lp, h2, cfg)
            aux_acc = (
                aux
                if aux_acc is None
                else jax.tree.map(
                    lambda a, bb: (a | bb) if a.dtype == bool else a + bb, aux_acc, aux
                )
            )
            i_moe += 1
        else:
            dp_ = jax.tree.map(lambda a: a[i_dense], bp["dense"])
            g = jnp.einsum("bsd,df->bsf", h2, dp_["w_gate"])
            u = jnp.einsum("bsd,df->bsf", h2, dp_["w_up"])
            y = jnp.einsum(
                "bsf,fd->bsd", jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u, dp_["w_down"]
            )
            i_dense += 1
        x = x + y
    if aux_acc is None:
        aux_acc = {
            "lb_loss": jnp.zeros(()),
            "z_loss": jnp.zeros(()),
            "overflow": jnp.zeros((), bool),
        }
    return x, new_states, aux_acc


def forward_train(cfg, params, tokens, labels, mesh_info=None, extras=None):
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    block = functools.partial(_super_block, cfg, mesh_info)
    if cfg.remat:
        block = jax.checkpoint(block)

    def scan_body(x, bp):
        x, _, aux = block(x, bp, positions)
        return x, aux

    x, auxs = lax.scan(scan_body, x, params["blocks"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    loss = next_token_loss(logits[:, :-1], labels[:, 1:])
    aux = {k: (v.sum() if v.dtype != bool else v.any()) for k, v in auxs.items()}
    loss = loss + 0.01 * aux["lb_loss"] + 1e-3 * aux["z_loss"]
    return loss, aux


def prefill(cfg, params, tokens, mesh_info=None, extras=None, cache_len=None):
    b, s = tokens.shape
    cache_len = cache_len or s
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def scan_body(x, bp):
        x, st, _ = _super_block(cfg, mesh_info, x, bp, positions)
        pad = cache_len - s
        kc = jnp.pad(st["k"], ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(st["v"], ((0, 0), (0, pad), (0, 0), (0, 0)))
        mamba_st = jax.tree.map(lambda *xs: jnp.stack(xs), *st["mamba"])
        return x, (kc, vc, mamba_st)

    x, (kc, vc, mamba_st) = lax.scan(scan_body, x, params["blocks"])
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
    cache = {
        "k": kc,
        "v": vc,
        "mamba": mamba_st,
        "pos": jnp.full((), s - 1, jnp.int32),
    }
    return cache, logits


def decode_step(cfg, params, cache, token, mesh_info=None):
    b = token.shape[0]
    pos = cache["pos"] + 1
    x = params["embed"][token][:, None, :]
    positions = jnp.broadcast_to(pos[None], (b, 1))
    period, _ = _layout(cfg)

    def scan_body(x, inputs):
        bp, kc, vc, mamba_st = inputs
        states = {
            "k": kc,
            "v": vc,
            "mamba": [jax.tree.map(lambda a: a[i], mamba_st) for i in range(period - 1)],
        }
        x, st, _ = _super_block(cfg, mesh_info, x, bp, positions, states=states, pos=pos)
        new_mamba = jax.tree.map(lambda *xs: jnp.stack(xs), *st["mamba"])
        return x, (st["k"], st["v"], new_mamba)

    x, (kc, vc, mamba_st) = lax.scan(
        scan_body, x, (params["blocks"], cache["k"], cache["v"], cache["mamba"])
    )
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
    return logits, {"k": kc, "v": vc, "mamba": mamba_st, "pos": pos}


def cache_shapes(cfg: ArchConfig, batch: int, cache_len: int):
    period, blocks = _layout(cfg)
    KV, hd = cfg.n_kv_heads, cfg.hd
    dt = dtype_of(cfg)
    (hsh, csh) = ssm.mamba_state_shape(cfg, batch)
    return {
        "k": jax.ShapeDtypeStruct((blocks, batch, cache_len, KV, hd), dt),
        "v": jax.ShapeDtypeStruct((blocks, batch, cache_len, KV, hd), dt),
        "mamba": (
            jax.ShapeDtypeStruct((blocks, period - 1) + hsh, jnp.float32),
            jax.ShapeDtypeStruct((blocks, period - 1) + csh, dt),
        ),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
