from repro.data.pipeline import batches_for_run, length_bucketed_order, synthetic_batch  # noqa: F401
