"""Data pipeline: stateless-seeded synthetic LM batches + BSP-sort bucketing.

* ``synthetic_batch(cfg, shape, step)`` — deterministic (step → batch), so a
  restart from checkpoint replays the exact stream (fault-tolerance
  contract with train/checkpoint.py).
* ``length_bucketed_order`` — global length-bucketing of a corpus of
  variable-length documents via the paper's distributed sort: keys =
  document lengths, payload = doc ids (SORT_IRAN_BSP, key-value form). This
  is the paper's technique as the data-layer feature: one balanced
  communication round replaces a gather-sort-scatter shuffle.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import TierStats, bsp_sort_safe
from repro.models.layers import dtype_of


def synthetic_batch(
    cfg: ArchConfig, shape: ShapeConfig, step: int, *, batch_override: Optional[int] = None
) -> Dict[str, jnp.ndarray]:
    b = batch_override or shape.global_batch
    s = shape.seq_len
    rng = jax.random.key(step)
    tokens = jax.random.randint(rng, (b, s), 0, cfg.vocab, dtype=jnp.int32)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.zeros(
            (b, cfg.vision_tokens, cfg.d_model), dtype_of(cfg)
        )
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(rng, 1), (b, cfg.enc_positions, cfg.d_model)
        ).astype(dtype_of(cfg))
    return batch


def length_bucketed_order(
    doc_lengths: np.ndarray,
    p: int,
    *,
    algorithm: str = "iran",
    seed: int = 0,
    stats: Optional[TierStats] = None,
) -> np.ndarray:
    """Return doc ids in globally length-sorted order using the BSP sort.

    ``doc_lengths``: (n,) int32. The corpus is dealt to ``p`` simulated
    processors, sorted by (length) with doc-id payload, and the
    concatenated valid prefixes give the bucketing order — equal lengths
    keep corpus order (stability = deterministic batch composition).

    Runs through the overflow-safe driver: a skewed corpus (e.g. every doc
    the same length) escalates the capacity tier instead of silently
    dropping ids. Pass a ``TierStats`` to accumulate retry counters.
    """
    n = doc_lengths.shape[0]
    # round the per-proc run up to a power of two: queue length varies every
    # serving step, and each distinct n_p is a distinct jit/XLA compile of
    # the whole tier ladder — bucketing bounds that to O(log n) programs.
    n_p = max(8, 1 << max(0, -(-n // p) - 1).bit_length())
    pad = p * n_p - n
    lengths = np.concatenate([doc_lengths, np.full(pad, np.iinfo(np.int32).max)])
    ids = np.concatenate([np.arange(n, dtype=np.int32), np.full(pad, -1, np.int32)])
    res, vals, _ = bsp_sort_safe(
        jnp.asarray(lengths.reshape(p, n_p)),
        algorithm=algorithm,
        pair_capacity="whp",  # cheap production tier; ladder handles skew
        values=(jnp.asarray(ids.reshape(p, n_p)),),
        seed=seed,
        stats=stats,
    )
    buf = np.asarray(vals[0])
    cnt = np.asarray(res.count)
    order = np.concatenate([buf[k, : cnt[k]] for k in range(p)])
    return order[order >= 0]


def batches_for_run(cfg: ArchConfig, shape: ShapeConfig, start_step: int, n_steps: int):
    for step in range(start_step, start_step + n_steps):
        yield step, synthetic_batch(cfg, shape, step)
