"""Data pipeline: stateless-seeded synthetic LM batches + BSP-sort bucketing.

* ``synthetic_batch(cfg, shape, step)`` — deterministic (step → batch), so a
  restart from checkpoint replays the exact stream (fault-tolerance
  contract with train/checkpoint.py).
* ``length_bucketed_order`` — global length-bucketing of a corpus of
  variable-length documents via the paper's distributed sort: keys =
  document lengths, payload = doc ids (SORT_IRAN_BSP, key-value form). This
  is the paper's technique as the data-layer feature: one balanced
  communication round replaces a gather-sort-scatter shuffle. Routed
  through the sort service (``repro.service``): the corpus is one segment
  of a fused segmented sort, so a data-pipeline shuffle can share a batch
  (and a compiled program bucket) with concurrent serving-side requests.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import TierStats
from repro.models.layers import dtype_of
from repro.planner import CapacityPlanner
from repro.service import ServiceConfig, SortService

#: shared across the per-call throwaway services below — compiled programs
#: already pool in the default executor; pooling the planner the same way
#: lets its per-bucket tier learning accumulate across calls instead of
#: being discarded with each one-shot service.
_DEFAULT_PLANNER = CapacityPlanner()


def synthetic_batch(
    cfg: ArchConfig, shape: ShapeConfig, step: int, *, batch_override: Optional[int] = None
) -> Dict[str, jnp.ndarray]:
    b = batch_override or shape.global_batch
    s = shape.seq_len
    rng = jax.random.key(step)
    tokens = jax.random.randint(rng, (b, s), 0, cfg.vocab, dtype=jnp.int32)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.zeros(
            (b, cfg.vision_tokens, cfg.d_model), dtype_of(cfg)
        )
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(rng, 1), (b, cfg.enc_positions, cfg.d_model)
        ).astype(dtype_of(cfg))
    return batch


def length_bucketed_order(
    doc_lengths: np.ndarray,
    p: int,
    *,
    algorithm: str = "iran",
    seed: int = 0,
    stats: Optional[TierStats] = None,
    service: Optional[SortService] = None,
) -> np.ndarray:
    """Return doc ids in globally length-sorted order using the BSP sort.

    ``doc_lengths``: (n,) int32. The corpus goes through the sort service
    as one segment of a fused segmented sort: dealt to ``p`` simulated
    processors, sorted by (length) with the within-corpus index riding as
    payload, the result's stable argsort IS the bucketing order — equal
    lengths keep corpus order (stability = deterministic batch
    composition). The service's pow2 batch former bounds the distinct
    compiled programs to O(log n) across varying queue lengths, and its
    overflow-safe per-batch escalation means a skewed corpus (e.g. every
    doc the same length) climbs the capacity ladder instead of silently
    dropping ids. Pass a ``TierStats`` to accumulate retry counters, or a
    ``SortService`` to fuse with its queued requests — in which case the
    service's own config governs algorithm/seed and its stats accumulate
    the retries (``p`` must agree with the service's).
    """
    if service is None:
        service = SortService(
            ServiceConfig(p=p, algorithm=algorithm, seed=seed),
            stats=stats,
            planner=_DEFAULT_PLANNER,
        )
    elif service.cfg.p != p:
        raise ValueError(
            f"service sorts with p={service.cfg.p}, caller asked for p={p}"
        )
    return service.sort_one(np.asarray(doc_lengths, np.int32)).order


def batches_for_run(cfg: ArchConfig, shape: ShapeConfig, start_step: int, n_steps: int):
    for step in range(start_step, start_step + n_steps):
        yield step, synthetic_batch(cfg, shape, step)
