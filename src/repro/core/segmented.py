"""Segmented BSP sort — many independent sorts fused into ONE tagged sort.

The paper's transparent duplicate handling (§5.1.1) works by *tagging*:
sample/splitter records carry explicit ``(processor, index)`` tags so the
comparator is a total order even when every key is equal, and splitter
selection stays balanced without doubling communication. The same mechanism
generalizes to *segment* tags. A batch of R independent sort requests
("segments") is fused into one BSP sort by lifting every key to the
composite

    comp = segment_id * 2^32 + (key + 2^31)        (int64, order-preserving)

i.e. the pair ``(segment_id, key)`` compared lexicographically. One balanced
sort of the composites returns every segment contiguous *and* sorted — the
segment tag rides in the key's high bits exactly like the §5.1.1 duplicate
tag rides in the comparator, and splitters drawn from the shared oversample
of the composites automatically land inside each segment in proportion to
its size, so a batch of many small/skewed requests is load-balanced as one
n-key sort instead of R degenerate p-lane sorts (the regime where naive
per-request sample sort collapses — Axtmann & Sanders 2016).

Everything rides the existing machinery unchanged: the composite sort goes
through :func:`repro.core.api.bsp_sort_safe`, so it inherits the resumable
prepare/route phase pipeline, the capacity-tier escalation ladder
(whp → whp×2 → exact → allgather) and the :class:`SortExecutor` compile
cache — one compiled program per ``(p, n_per_proc)`` shape serves every
batch that packs to that shape. That includes the fused single-collective
exchange and, via ``merge="tree"``, the payload-generic rank-merge tail:
the int64 composites and their ``pos`` payload ride the lg p rank merges
instead of a full re-sort (``ServiceConfig.merge`` exposes the knob one
level up).

Layout: ``pack_segments`` supports two lane layouts.

* ``contiguous`` (the PR 3 default) concatenates the ragged requests in
  submit order, pads the tail up to ``p * n_per_proc`` with composites of
  the past-the-last segment id (they sort after every real key), and deals
  the result row-major onto the ``(p, n_per_proc)`` global layout. Simple,
  but every lane's run is *value-clustered* (it spans only a couple of
  segments and routes almost whole to the destination covering its own
  global position range), which structurally violates any sub-exact
  per-pair routing capacity.
* ``striped`` splits EVERY segment into ``p`` consecutive chunks, chunk k
  appended to lane k (remainder +1s rotated across lanes so lane totals
  differ by at most one). Each lane then holds ~1/p of every segment — a
  value-representative sample of the whole batch — so per-(src,dst)
  routing loads concentrate near ``n/p²`` again and the planner's
  segment-aware w.h.p. pair capacity (``repro.planner.capacity``) applies.
  Stability is preserved: within a segment, chunk k's submit positions all
  precede chunk k+1's, so the pipeline's (source proc, local index) order
  for equal composites is still ascending submit order. Pads get *distinct*
  composites ``(R << 32) | (j·p + k)`` (lane k's j-th pad) interleaving the
  lanes in sorted order, so the pad tail routes evenly instead of aiming
  each lane's constant pad run at one bucket.

A per-key ``pos`` payload (the key's index *within its segment*) rides
along, so the unpacked result carries each segment's stable argsort for
free — both layouts keep equal keys in original within-segment order.

Keys are int32 (the library's key dtype throughout datagen/benchmarks);
segment count is bounded by 2^31 so the composite stays inside int64.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from .api import (
    InFlightSort,
    SortExecutor,
    TierStats,
    bsp_sort_safe_launch,
    gathered_output,
)
from .types import SortConfig

#: bits of the composite holding the (biased) key; segment id sits above.
SEG_SHIFT = 32
_KEY_BIAS = np.int64(1) << 31  # maps int32 -> [0, 2^32): order-preserving
_KEY_MASK = (np.int64(1) << SEG_SHIFT) - 1


def pack_keys(seg_ids: np.ndarray, keys: np.ndarray) -> np.ndarray:
    """Lift (segment_id, int32 key) pairs to order-preserving int64 composites."""
    seg = np.asarray(seg_ids, np.int64)
    k = np.asarray(keys, np.int64)
    return (seg << SEG_SHIFT) | (k + _KEY_BIAS)


def unpack_keys(comp: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Invert :func:`pack_keys`: composites -> (segment ids, int32 keys)."""
    comp = np.asarray(comp, np.int64)
    seg = (comp >> SEG_SHIFT).astype(np.int32)
    keys = ((comp & _KEY_MASK) - _KEY_BIAS).astype(np.int32)
    return seg, keys


def _pow2_n_per_proc(total: int, p: int, min_n_per_proc: int) -> int:
    """Power-of-two per-proc run length covering ``total`` packed keys.

    Each distinct n_per_proc is a distinct XLA compile of the whole tier
    ladder; rounding to the next power of two bounds the compiled-program
    count to O(log n) across arbitrary traffic (same rationale as the
    serve-side cache_len bucketing).
    """
    per = max(1, -(-total // p))
    return max(min_n_per_proc, 1 << (per - 1).bit_length())


@dataclasses.dataclass
class PackedSegments:
    """A batch of ragged requests packed onto the (p, n_per_proc) layout.

    Arrays stay host-side (numpy): device transfer happens inside the
    sort's ``enable_x64`` scope — an eager ``jnp.asarray`` under the repo's
    default 32-bit mode would truncate the int64 composites.

    Single-segment batches (the serve-admission / data-bucketing hot path)
    skip the composite lift entirely: a segment tag carries zero
    information for R = 1, so ``comp`` holds the raw int32 keys (pads =
    int32 max, which may collide with real keys — the unpack therefore
    filters by the pos payload, not by value) and the sort runs in the
    repo's native 32-bit mode at half the key bytes.
    """

    comp: np.ndarray  # (p, n_p) keys: int64 composites (R>1) / int32 (R=1)
    pos: np.ndarray  # (p, n_p) int32 within-segment index (pads: -1)
    sizes: Tuple[int, ...]  # true per-segment lengths, submit order
    p: int
    n_per_proc: int
    layout: str = "contiguous"  # lane layout this batch was packed with

    @property
    def n_keys(self) -> int:
        return int(sum(self.sizes))


def contiguous_lane_sizes(total: int, p: int) -> np.ndarray:
    """(p,) real-key counts of the contiguous even-share lane deal.

    The single source of truth for the contiguous packing geometry — used
    by :func:`pack_segments` to fill lanes and by the planner's
    fingerprint (``repro.planner.fingerprint.lane_spread``) to reason
    about which segments each lane would span.
    """
    q, rem = divmod(int(total), p)
    out = np.full(p, q, np.int64)
    out[:rem] += 1
    return out


def striped_chunk_sizes(sizes: Sequence[int], p: int) -> np.ndarray:
    """(R, p) per-lane chunk lengths for the striped layout.

    Segment s contributes ``floor(m_s/p)`` keys to every lane plus a +1 to
    ``m_s mod p`` lanes; the +1 windows are rotated (laid head-to-tail
    around the lane circle) so final lane totals differ by at most one —
    which is what keeps the packed batch inside ``n_p = ceil(total/p)``.
    Deterministic, so the capacity planner can bound per-lane loads from
    the sizes alone.
    """
    out = np.zeros((len(sizes), p), np.int64)
    start = 0
    for i, m in enumerate(sizes):
        q, r = divmod(int(m), p)
        out[i, :] = q
        if r:
            out[i, (start + np.arange(r)) % p] += 1
            start += r
    return out


def pack_segments(
    arrays: Sequence[np.ndarray],
    p: int,
    *,
    n_per_proc: Optional[int] = None,
    min_n_per_proc: int = 8,
    layout: str = "contiguous",
) -> PackedSegments:
    """Pack ragged int32 request arrays into one tagged (p, n_p) sort input.

    ``n_per_proc`` defaults to the power-of-two bucket covering the batch
    (see :func:`_pow2_n_per_proc`); passing it explicitly lets a batch
    former pin the bucket. Pads carry segment id ``len(arrays)`` — strictly
    above every real composite — so they sort to the global tail and the
    valid prefix decodes exactly.

    ``layout="contiguous"`` deals the submit-order concatenation row-major:
    each lane gets an *even share* of the real keys (submit-contiguous, so
    stability still reads in submit order) with its own tail pads, rather
    than all pads piling onto the last lanes: an all-pad lane is a constant
    run aimed at one routing bucket, which would structurally fault the whp
    pair capacity even for a single benign segment.

    ``layout="striped"`` splits every segment into ``p`` consecutive chunks
    (chunk k → lane k, remainders rotated; :func:`striped_chunk_sizes`), so
    each lane holds a value-representative ~1/p of every segment and the
    planner's segment-aware sub-exact pair capacity applies. Single-segment
    batches ignore the distinction: the contiguous even-share deal IS the
    one-segment stripe, and they keep the raw-int32 fast path.
    """
    if layout not in ("contiguous", "striped"):
        raise ValueError(f"unknown layout {layout!r}")
    arrays = [np.asarray(a, np.int32).reshape(-1) for a in arrays]
    sizes = tuple(int(a.shape[0]) for a in arrays)
    total = sum(sizes)
    n_p = n_per_proc or _pow2_n_per_proc(total, p, min_n_per_proc)
    if p * n_p < total:
        raise ValueError(f"batch of {total} keys exceeds p*n_per_proc={p * n_p}")
    keys = (
        np.concatenate(arrays) if arrays else np.zeros((0,), np.int32)
    )
    pos = np.concatenate(
        [np.arange(s, dtype=np.int32) for s in sizes]
        or [np.zeros((0,), np.int32)]
    )
    if len(arrays) <= 1:  # hot path: no tag needed, sort raw int32 keys
        layout = "contiguous"
        comp = keys
        pad_comp = np.iinfo(np.int32).max
        comp_rows = np.full((p, n_p), pad_comp, np.int32)
    else:
        seg = np.repeat(np.arange(len(arrays), dtype=np.int64), sizes)
        comp = pack_keys(seg, keys)
        pad_comp = np.int64(len(arrays)) << SEG_SHIFT
        comp_rows = np.full((p, n_p), pad_comp, np.int64)
    pos_rows = np.full((p, n_p), -1, np.int32)

    if layout == "striped":
        chunks = striped_chunk_sizes(sizes, p)
        seg_starts = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)
        # chunk offsets within each segment: lane k's slice of segment s is
        # [offs[s, k], offs[s, k + 1]) of that segment's submit order
        offs = np.concatenate(
            [np.zeros((len(sizes), 1), np.int64), np.cumsum(chunks, axis=1)],
            axis=1,
        )
        for k in range(p):
            sel = np.concatenate(
                [
                    np.arange(seg_starts[s] + offs[s, k], seg_starts[s] + offs[s, k + 1])
                    for s in range(len(sizes))
                ]
                or [np.zeros((0,), np.int64)]
            )
            c = sel.shape[0]
            comp_rows[k, :c] = comp[sel]
            pos_rows[k, :c] = pos[sel]
            # distinct interleaved pad composites: lane k's j-th pad sorts
            # between other lanes' pads (value j·p + k), so the pad tail
            # routes evenly instead of one constant per-lane run
            comp_rows[k, c:] = pad_comp | (
                np.arange(n_p - c, dtype=np.int64) * p + k
            )
    else:
        off = 0
        for k, c in enumerate(contiguous_lane_sizes(total, p)):
            comp_rows[k, :c] = comp[off : off + c]
            pos_rows[k, :c] = pos[off : off + c]
            off += c
    return PackedSegments(
        comp=comp_rows,
        pos=pos_rows,
        sizes=sizes,
        p=p,
        n_per_proc=n_p,
        layout=layout,
    )


@dataclasses.dataclass
class SegmentedResult:
    """Per-segment outputs of one fused sort, in submit order."""

    keys: List[np.ndarray]  # segment r's keys, sorted ascending
    order: List[np.ndarray]  # stable argsort: keys[r] == input_r[order[r]]
    stats: TierStats  # escalation counters of the fused sort
    tier: Optional[str]  # capacity tier that served the batch
    n_per_proc: int  # the pow2 bucket this batch compiled under


@dataclasses.dataclass
class InFlightSegmentedSort:
    """A dispatched fused batch awaiting completion.

    Host-side packing is done and the sort's first ladder rung is in the
    device queue (:class:`repro.core.api.InFlightSort`); :meth:`wait` is the
    only sync point — it escalates through the remaining capacity rungs if
    the launched rung faulted, then unpacks per segment. The async service
    dispatcher launches batch k+1's packing/planning while batch k sits
    here.
    """

    packed: PackedSegments
    flight: InFlightSort

    def done(self) -> bool:
        return self.flight.done()

    def wait(self) -> SegmentedResult:
        res, vbufs, stats = self.flight.wait()
        return _unpack_result(self.packed, res, vbufs, stats)


def segmented_sort_launch(
    packed: PackedSegments,
    cfg: Optional[SortConfig] = None,
    *,
    rng: Optional[jax.Array] = None,
    stats: Optional[TierStats] = None,
    executor: Optional[SortExecutor] = None,
    **overrides,
) -> InFlightSegmentedSort:
    """Launch one fused overflow-safe sort without awaiting it.

    The composite keys run through :func:`bsp_sort_safe_launch` (prepare
    once, re-enter route per capacity-ladder rung), with the within-segment
    index as payload. Default config: randomized oversampling starting at
    the *exact* pair capacity — the safe choice for the default *contiguous*
    packing, whose value-clustered lanes structurally violate the whp
    per-pair bound. Batches packed with ``layout="striped"`` can instead
    pass ``pair_capacity="planned"`` with the capacity planner's
    segment-aware bound (``repro.planner``) and start sub-exact. The
    receive side is still the Claim 5.1 bound; a batch that overflows it
    (however skewed) escalates to the allgather terminal tier instead of
    dropping keys.

    Int-key fused batches can pass ``route="radix"`` instead (the planner
    does, for balanced key ranges): the segment-tag composite is itself a
    dense-int prefix, so the count-then-distribute route buckets the batch
    by segment runs, sizes its ONE rung from the exact counted totals, and
    never retries — no oversampling parameter, no splitter superstep.
    """
    if cfg is None:
        cfg = SortConfig(
            p=packed.p,
            n_per_proc=packed.n_per_proc,
            **{"algorithm": "iran", "pair_capacity": "exact", **overrides},
        )
    assert (cfg.p, cfg.n_per_proc) == (packed.p, packed.n_per_proc)
    stats = stats if stats is not None else TierStats()
    # Multi-segment composites need all 64 bits; the repo otherwise runs
    # with JAX's default 32-bit mode, so x64 is enabled only around the
    # sort's device entries. Every launch (not just the first trace) must
    # sit inside the scope — input canonicalization is per-call, and a
    # 32-bit call would truncate the segment tags and retrace the
    # executor's cached callables — so the scope *factory* travels with the
    # in-flight sort and is re-entered when ``wait`` escalates. Single-
    # segment batches carry raw int32 keys and stay in native 32-bit mode.
    scope = (
        enable_x64
        if packed.comp.dtype == np.int64
        else contextlib.nullcontext
    )
    with scope():
        x = jnp.asarray(packed.comp)
        pos = jnp.asarray(packed.pos)
    flight = bsp_sort_safe_launch(
        x,
        cfg,
        values=(pos,),
        rng=rng,
        stats=stats,
        executor=executor,
        scope=scope,
    )
    if flight.trace_tid is not None:
        # attach the batch's segment shape to the sort's timeline lane
        from ..obs import resolve_tracer

        tracer = resolve_tracer(cfg.obs)
        sizes = packed.sizes
        tracer.point(
            "segments",
            tid=flight.trace_tid,
            n_segments=len(sizes),
            n_keys=packed.n_keys,
            layout=packed.layout,
            sizes=list(sizes) if len(sizes) <= 256 else None,
            size_max=max(sizes) if sizes else 0,
        )
    return InFlightSegmentedSort(packed=packed, flight=flight)


def segmented_sort_safe(
    packed: PackedSegments,
    cfg: Optional[SortConfig] = None,
    *,
    rng: Optional[jax.Array] = None,
    stats: Optional[TierStats] = None,
    executor: Optional[SortExecutor] = None,
    **overrides,
) -> SegmentedResult:
    """Sort every packed segment in one overflow-safe BSP sort (blocking).

    The launch-then-wait form of :func:`segmented_sort_launch` —
    byte-identical output; see there for capacity semantics.
    """
    return segmented_sort_launch(
        packed, cfg, rng=rng, stats=stats, executor=executor, **overrides
    ).wait()


def _unpack_result(packed: PackedSegments, res, vbufs, stats) -> SegmentedResult:
    """Host-side: slice the fused sorted sequence back into segments."""
    n = packed.n_keys
    cnt = np.asarray(res.count)
    pbuf = np.asarray(vbufs[0])
    pos = np.concatenate([pbuf[k, : cnt[k]] for k in range(packed.p)])
    flat = gathered_output(res)
    if len(packed.sizes) == 1:
        # int32 fast path: pads (= int32 max) may equal real keys and
        # interleave with them among the global maxima, so filter by the
        # pos payload instead of slicing a prefix. Dropping elements from
        # a sorted sequence keeps it sorted, and real equal keys keep
        # their (proc, idx) = submit order.
        mask = pos >= 0
        return SegmentedResult(
            keys=[flat[mask]],
            order=[pos[mask]],
            stats=stats,
            tier=stats.last_tier,
            n_per_proc=packed.n_per_proc,
        )
    flat, pos = flat[:n], pos[:n]  # pad composites (seg = R) hold the tail
    _, keys = unpack_keys(flat)
    bounds = np.concatenate([[0], np.cumsum(packed.sizes)])
    return SegmentedResult(
        keys=[keys[bounds[r] : bounds[r + 1]] for r in range(len(packed.sizes))],
        order=[pos[bounds[r] : bounds[r + 1]] for r in range(len(packed.sizes))],
        stats=stats,
        tier=stats.last_tier,
        n_per_proc=packed.n_per_proc,
    )


def sort_segments(
    arrays: Sequence[np.ndarray],
    p: int = 8,
    *,
    n_per_proc: Optional[int] = None,
    min_n_per_proc: int = 8,
    layout: str = "contiguous",
    stats: Optional[TierStats] = None,
    executor: Optional[SortExecutor] = None,
    rng: Optional[jax.Array] = None,
    **overrides,
) -> SegmentedResult:
    """Convenience: pack + fused-sort + unpack a batch of ragged requests."""
    packed = pack_segments(
        arrays, p, n_per_proc=n_per_proc, min_n_per_proc=min_n_per_proc,
        layout=layout,
    )
    return segmented_sort_safe(
        packed, rng=rng, stats=stats, executor=executor, **overrides
    )
