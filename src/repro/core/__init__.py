"""BSP sorting — the paper's primary contribution, as a composable JAX module.

Public API:
    SortConfig, SortResult        — configuration / result types
    PreparedSort                  — tier-invariant prepared state (Ph2 + det Ph3)
    bsp_sort                      — simulated-processor runner (vmap)
    bsp_sort_sharded              — real-device runner (shard_map, cached)
    bsp_sort_safe / _sharded_safe — overflow-safe drivers: prepare once, then
                                    re-enter only the route stage per rung of
                                    the capacity ladder; no key ever dropped
    bsp_sort_safe_launch,
    InFlightSort                  — the drivers' launch/wait split: dispatch
                                    rung 0 and return (JAX async dispatch);
                                    wait() walks the remaining rungs — the
                                    service's in-flight batch pipelining
    SortExecutor                  — compiled-callable registry (both runners)
    TierStats                     — per-tier retry counters for the drivers
    phase_fns                     — per-phase callables (paper Tables 4-7)
    predict, BSPMachine, CRAY_T3D — BSP (p, L, g) cost model (§1.1, Props 5.1/5.3)
    datagen                       — §6.3 benchmark input distributions (+ zipf)
    pack_segments, sort_segments,
    segmented_sort_safe,
    segmented_sort_launch         — segmented sort: many requests fused into
                                    one (segment_id, key)-tagged BSP sort
                                    (the repro.service layer's engine);
                                    _launch is its non-blocking form
"""
from .api import (
    InFlightSort,
    SortExecutor,
    TierStats,
    bsp_sort,
    bsp_sort_safe,
    bsp_sort_safe_launch,
    bsp_sort_sharded,
    bsp_sort_sharded_safe,
    default_executor,
    gathered_output,
    phase_fns,
    spmd_prepare_fn,
    spmd_route_fn,
    spmd_sort_fn,
)
from .bsp import BSPMachine, CRAY_T3D, Prediction, predict, theoretical_max_imbalance
from .segmented import (
    InFlightSegmentedSort,
    PackedSegments,
    SegmentedResult,
    pack_segments,
    segmented_sort_launch,
    segmented_sort_safe,
    sort_segments,
)
from .types import AXIS, PreparedSort, SortConfig, SortResult, sentinel_for

from . import datagen  # noqa: F401

__all__ = [
    "AXIS",
    "BSPMachine",
    "CRAY_T3D",
    "InFlightSegmentedSort",
    "InFlightSort",
    "PackedSegments",
    "Prediction",
    "PreparedSort",
    "SegmentedResult",
    "SortConfig",
    "SortExecutor",
    "SortResult",
    "TierStats",
    "bsp_sort",
    "bsp_sort_safe",
    "bsp_sort_safe_launch",
    "bsp_sort_sharded",
    "bsp_sort_sharded_safe",
    "datagen",
    "default_executor",
    "gathered_output",
    "pack_segments",
    "phase_fns",
    "predict",
    "segmented_sort_launch",
    "segmented_sort_safe",
    "sentinel_for",
    "sort_segments",
    "spmd_prepare_fn",
    "spmd_route_fn",
    "spmd_sort_fn",
    "theoretical_max_imbalance",
]
