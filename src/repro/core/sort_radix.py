"""Radix h-relation — count-then-distribute routing for integer keys.

For integer keys, sampling is pure overhead (*A study of integer sorting on
multicores*, Gerbessiotis): exact bucket boundaries are computable in ONE
counting pass over the locally sorted run, so the splitter superstep (Ph3)
disappears, there is no oversampling parameter, and — decisively for the
capacity ladder — the per-destination counts are known *before any data
moves*. The (p,)-word count superstep of the fused h-relation (routing.py)
already communicates them; the launch driver additionally host-reads the
prepared boundaries and sizes the single rung to the true maxima, so a
``route="radix"`` sort retries zero times by construction.

Destination function
--------------------
Keys are mapped through :func:`radix._to_unsigned_order_preserving` (the
sign-bit bias that makes unsigned compare agree with signed order — the same
map every LSD pass of ``radix_argsort`` uses), then bucketed over the
*observed global key range*::

    lo, hi = pmin(u_local_min), pmax(u_local_max)   # two scalar collectives
    width  = (hi - lo) // p + 1
    dest   = (u - lo) // width                      # in [0, p-1]

Range-normalising instead of taking raw top bits is what makes the flagship
workloads work: small dense domains (expert ids, segment-tag composites)
share all their high bits, and a static MSB split would aim every key at one
processor. ``dest`` is monotone in key order, so bucket i's keys are all ≤
bucket i+1's (the concatenated output is globally sorted) and equal keys
share a destination (stability is preserved through the source-ordered
exchange). The boundaries of the sorted run are then a vectorised
``searchsorted`` — exactly the Ph4 shape the shared Ph5/Ph6 tail
(:func:`routing.route_and_merge`) consumes, so the radix route rides the
same fused byte-packed ``a2a_dense`` exchange and merge tail as the sample
route. Radix buckets arrive *disjoint* in key range, so the merge tail only
ever interleaves equal-bucket runs — per-bucket local passes, never a
global fix-up.

Both collectives live in ``prepare``: they are tier-invariant, deterministic
(no rng), and their result is carried host-readably in
``PreparedSort.splits`` for the exact-capacity launch path.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import routing
from .local_sort import local_sort
from .radix import _to_unsigned_order_preserving
from .types import PreparedSort, SortConfig


def radix_boundaries(
    xs: jnp.ndarray, p: int, axis: str
) -> jnp.ndarray:
    """Counted (p+1,) bucket boundaries of the locally sorted run ``xs``.

    b[0] = 0, b[p] = n_p; destination i receives ``xs[b[i]:b[i+1]]``. Costs
    two scalar collectives (global min/max of the bias-mapped keys) plus one
    vectorised binary search — no sample, no splitter sort.
    """
    u = _to_unsigned_order_preserving(xs)
    lo = lax.pmin(u[0], axis)  # xs is sorted: u[0]/u[-1] are local extremes
    hi = lax.pmax(u[-1], axis)
    width = (hi - lo) // u.dtype.type(p) + u.dtype.type(1)
    dest = ((u - lo) // width).astype(jnp.int32)  # monotone, in [0, p-1]
    return jnp.searchsorted(
        dest, jnp.arange(p + 1, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)


def host_send_counts(bounds) -> np.ndarray:
    """(p, p) per-(src, dst) send counts from the counted boundaries.

    Host-side companion of :func:`radix_boundaries`: ``bounds`` is the
    prepared ``splits[0]`` — (p, p+1) under the global layout, one row per
    source — and differencing each row yields the exact h-relation count
    matrix. Shared by the launch driver's single-rung capacity sizing and
    the tracer's per-(src, dst) byte-volume record; reading it is the radix
    launch path's only host sync.
    """
    return np.diff(np.asarray(bounds), axis=1)


def prepare_radix_spmd(
    x: jnp.ndarray,
    cfg: SortConfig,
    axis: str,
    values: Sequence[jnp.ndarray] = (),
    rng: jax.Array | None = None,  # unused: the radix route draws no sample
) -> PreparedSort:
    """Tier-invariant stage: Ph2 stable local sort + the counting pass.

    Unlike the sample route, the boundary computation is tier-invariant too
    (capacity never enters it), so it belongs here — and carrying it in
    ``splits`` lets the launch driver host-read the exact counts and size
    the single capacity rung before dispatching the route stage.
    """
    del rng
    xs, vals = local_sort(x, cfg.local_sort, values)  # Ph2
    bounds = radix_boundaries(xs, cfg.p, axis)
    return PreparedSort(xs=xs, vals=tuple(vals), splits=(bounds,))


def route_radix_spmd(
    prep: PreparedSort,
    cfg: SortConfig,
    axis: str,
    rng: jax.Array | None = None,  # unused: nothing random to redraw
) -> Tuple[jnp.ndarray, List[jnp.ndarray], jnp.ndarray, jnp.ndarray]:
    """Tier-dependent stages: Ph5 fused h-relation + Ph6 merge tail.

    Ph3/Ph4 are already done — the counted boundaries ride in from
    ``prep.splits``. The shared tail keeps its overflow detection, but with
    a host-counted capacity rung the flag is statically false.
    """
    del rng
    return routing.route_and_merge(
        prep.xs, prep.splits[0], cfg, axis, list(prep.vals)
    )


def sort_radix_spmd(
    x: jnp.ndarray,
    cfg: SortConfig,
    axis: str,
    values: Sequence[jnp.ndarray] = (),
    rng: jax.Array | None = None,
) -> Tuple[jnp.ndarray, List[jnp.ndarray], jnp.ndarray, jnp.ndarray]:
    return route_radix_spmd(prepare_radix_spmd(x, cfg, axis, values), cfg, axis, rng)
