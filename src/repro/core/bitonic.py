"""[BSI] — Batcher bitonic sort across processors (paper §6.2 item 3).

Classic hypercube compare-split: after a local sort, lg p · (lg p + 1)/2
supersteps; in each, partners (k, k XOR 2^j) exchange their n/p-key runs, one
keeps the lower half of the merge, the other the upper half. Perfectly
balanced (always exactly n/p keys per proc — no capacity machinery needed)
but Θ(lg² p) routing rounds of g·(n/p) each, versus the sample-sort
algorithms' single round: this is precisely the communication gap the paper's
Table comparisons exhibit, and why [BSI] is used only for sample sorting.
"""
from __future__ import annotations

import math
from typing import List, Sequence, Tuple

import jax.numpy as jnp
from jax import lax

from . import primitives as prim
from .local_sort import local_sort
from .types import SortConfig


def _compare_split(xs: jnp.ndarray, other: jnp.ndarray, keep_low) -> jnp.ndarray:
    n_p = xs.shape[0]
    merged = jnp.sort(jnp.concatenate([xs, other]))
    return jnp.where(keep_low, merged[:n_p], merged[n_p:])


def sort_bitonic_spmd(
    x: jnp.ndarray,
    cfg: SortConfig,
    axis: str,
    values: Sequence[jnp.ndarray] = (),
    rng=None,
) -> Tuple[jnp.ndarray, List[jnp.ndarray], jnp.ndarray, jnp.ndarray]:
    del rng
    if values:
        raise NotImplementedError("[BSI] baseline is key-only")
    p = cfg.p
    lgp = int(math.log2(p))
    me = prim.proc_id(axis)
    xs, _ = local_sort(x, cfg.local_sort)
    for i in range(lgp):
        for j in range(i, -1, -1):
            other = prim.exchange_with(xs, 1 << j, axis, p=p)
            up = ((me >> (i + 1)) & 1) == 0
            lower_half = ((me >> j) & 1) == 0
            keep_low = jnp.equal(up, lower_half)
            xs = _compare_split(xs, other, keep_low)
    n_p = jnp.asarray(x.shape[0], jnp.int32)
    return xs, [], n_p, jnp.zeros((), bool)
