"""Public entry points for the BSP sorting library.

Two runners share one SPMD implementation (verified equivalent in tests):

* :func:`bsp_sort` — *simulated processors*: the global (p, n_per_proc)
  layout is vmapped with an ``axis_name``, so JAX's collective batching rules
  execute the exact same collective pattern on one device. This is how the
  paper's Cray T3D experiments (p = 8..128) are reproduced on CPU.
* :func:`bsp_sort_sharded` — *real devices*: the same SPMD function under
  ``jax.shard_map`` over a mesh axis; used by the multi-pod dry-run, the MoE
  dispatch layer, and the distributed tests.

Phase-decomposed callables for the paper's Table 4-7 timing methodology are
exposed via :func:`phase_fns`.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from . import merge as merge_mod
from . import routing, splitters
from .bitonic import sort_bitonic_spmd
from .local_sort import local_sort
from .sort_det import sort_det_spmd
from .sort_iran import sort_iran_spmd
from .sort_ran import sort_ran_spmd
from .types import AXIS, SortConfig, SortResult

_ALGOS = {
    "det": sort_det_spmd,
    "iran": sort_iran_spmd,
    "ran": sort_ran_spmd,
    "bitonic": sort_bitonic_spmd,
}


def spmd_sort_fn(cfg: SortConfig) -> Callable:
    """The per-processor SPMD sort body for ``cfg.algorithm``."""
    cfg.validate()
    return functools.partial(_ALGOS[cfg.algorithm], cfg=cfg)


# ------------------------------------------------------------------ runners
def bsp_sort(
    x: jnp.ndarray,
    cfg: Optional[SortConfig] = None,
    *,
    values: Sequence[jnp.ndarray] = (),
    rng: Optional[jax.Array] = None,
    **overrides,
) -> SortResult:
    """Sort a (p, n_per_proc) global array with simulated processors."""
    p, n_p = x.shape
    if cfg is None:
        cfg = SortConfig(p=p, n_per_proc=n_p, **overrides)
    assert (cfg.p, cfg.n_per_proc) == (p, n_p), "config/layout mismatch"
    if rng is None:
        rng = jax.random.key(cfg.seed)
    fn = spmd_sort_fn(cfg)

    def body(xk, vk):
        buf, vbufs, count, overflow = fn(xk, axis=AXIS, values=vk, rng=rng)
        return buf, vbufs, count, overflow

    buf, vbufs, count, overflow = jax.vmap(body, axis_name=AXIS)(x, list(values))
    return SortResult(buf=buf, count=count, overflow=overflow.any()), vbufs


def bsp_sort_sharded(
    x: jnp.ndarray,
    mesh,
    mesh_axis: str,
    cfg: Optional[SortConfig] = None,
    *,
    values: Sequence[jnp.ndarray] = (),
    rng: Optional[jax.Array] = None,
    **overrides,
) -> SortResult:
    """Sort a (p, n_per_proc) array sharded over ``mesh_axis`` of ``mesh``."""
    p, n_p = x.shape
    if cfg is None:
        cfg = SortConfig(p=p, n_per_proc=n_p, **overrides)
    if rng is None:
        rng = jax.random.key(cfg.seed)
    fn = spmd_sort_fn(cfg)

    def body(xk, *vk):
        buf, vbufs, count, overflow = fn(
            xk[0], axis=mesh_axis, values=[v[0] for v in vk], rng=rng
        )
        return (
            buf[None],
            tuple(v[None] for v in vbufs),
            count[None],
            overflow[None],
        )

    nv = len(values)
    shmapped = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(mesh_axis),) * (1 + nv),
        out_specs=(P(mesh_axis), (P(mesh_axis),) * nv, P(mesh_axis), P(mesh_axis)),
        check_vma=False,
    )
    buf, vbufs, count, overflow = shmapped(x, *values)
    return SortResult(buf=buf, count=count, overflow=overflow.any()), list(vbufs)


def gathered_output(result: SortResult) -> np.ndarray:
    """Host-side: concatenate valid prefixes into the full sorted sequence."""
    buf = np.asarray(result.buf)
    count = np.asarray(result.count)
    return np.concatenate([buf[k, : count[k]] for k in range(buf.shape[0])])


# ------------------------------------------------- phase-decomposed (bench)
def phase_fns(cfg: SortConfig, rng: Optional[jax.Array] = None) -> Dict[str, Callable]:
    """Separately-jittable phase functions over the global (p, n_p) layout.

    Mirrors the paper's Ph2..Ph6 instrumentation (Tables 4-7). Each callable
    consumes the previous phase's output so a benchmark can block between
    phases. Only det/iran decompose; ran/bitonic are single calls.
    """
    cfg.validate()
    if rng is None:
        rng = jax.random.key(cfg.seed)

    def vm(f):
        return jax.jit(jax.vmap(f, axis_name=AXIS))

    def ph2(x):
        return local_sort(x, cfg.local_sort)[0]

    def ph3(xs):
        if cfg.algorithm == "det":
            sample = splitters.regular_sample(xs, cfg, AXIS)
        else:
            sample = splitters.random_sample(xs, cfg, AXIS, rng)
        return splitters.splitters_from_sorted_sample(cfg, sample, AXIS)

    def ph4(xs, splits):
        return splitters.searchsorted_tagged(xs, splits, AXIS)

    def ph5(xs, bounds):
        buf, _, count, overflow = routing.route(xs, bounds, cfg, AXIS)
        return buf, count, overflow

    def ph6(buf):
        return merge_mod.merge_by_sort(buf)[0]

    return {
        "SeqSort": vm(ph2),
        "Sampling": vm(ph3),
        "Prefix": vm(ph4),
        "Routing": vm(ph5),
        "Merging": vm(ph6),
    }
