"""Public entry points for the BSP sorting library.

Two runners share one SPMD implementation (verified equivalent in tests):

* :func:`bsp_sort` — *simulated processors*: the global (p, n_per_proc)
  layout is vmapped with an ``axis_name``, so JAX's collective batching rules
  execute the exact same collective pattern on one device. This is how the
  paper's Cray T3D experiments (p = 8..128) are reproduced on CPU.
* :func:`bsp_sort_sharded` — *real devices*: the same SPMD function under
  ``jax.shard_map`` over a mesh axis; used by the multi-pod dry-run, the MoE
  dispatch layer, and the distributed tests.

Execution model — the resumable phase pipeline
-----------------------------------------------
Every algorithm body is an explicit two-stage pipeline:

* ``prepare(x) -> PreparedSort`` — Ph2 local sort plus whatever sampling
  state is *capacity-tier-invariant* (for ``det``, the full Ph3
  sample/splitter computation; for ``iran``/``ran`` nothing random — a retry
  must redraw its sample);
* ``route(prepared, tier_cfg, rng) -> (buf, vals, count, overflow)`` —
  Ph3b/Ph4/Ph5/Ph6, the only stages that depend on the capacity tier.

Because a sort may never drop keys, production callers use the *overflow-safe
drivers* :func:`bsp_sort_safe` / :func:`bsp_sort_sharded_safe`: a host-side
escalation loop that runs ``prepare`` **once**, then re-enters only ``route``
at each rung of the config's capacity-tier ladder (``SortConfig.tier_ladder``:
whp → whp×2 → exact → allgather/full) until the ``overflow`` fault flag is
clean. The rng is folded per tier so a randomized retry is an independent
splitter trial. Re-using the tier-invariant work cuts the retry cost by the
Ph2 share of a tier attempt — ~2× end-to-end for the radix local-sort
variants, measured (not asserted) by the ``capacity`` benchmark table's
``retry_cost`` column. Per-tier attempt counters (:class:`TierStats`) feed
the serving engine and the benchmark tables.

The route stage's Ph5 exchange is *fused* by default
(``SortConfig.exchange="fused"``): key + payload rows are byte-packed into
one send buffer so each data superstep issues exactly ONE collective
regardless of payload count, and the Ph6 ``merge="tree"`` tail is
payload-generic — rank positions are computed once on the keys and every
payload rides the same gather, so key-value callers (MoE dispatch, the
segmented service composites) take the lg p rank-merge tail instead of a
full re-sort (see ``core/routing.py`` and the ``hotpath`` benchmark table).

Compiled callables for *both* runners live in a :class:`SortExecutor`
registry keyed by ``(stage, runner, cfg, n_values[, mesh])`` — prepare
callables additionally key on ``SortConfig.prepare_key()`` so every rung of
a ladder shares one compiled prepare, and repeated sharded calls with the
same mesh/config stop rebuilding ``shard_map`` (the registry counts traces,
so tests can assert compile reuse).

Phase-decomposed callables for the paper's Table 4-7 timing methodology are
exposed via :func:`phase_fns`; they are a thin view over the same pipeline
stage functions (``local_sort`` / ``splitters.splitter_stage`` /
``searchsorted_tagged`` / ``routing.route`` / ``merge``), not a parallel
reimplementation.

The service layer — many concurrent sorts as one
------------------------------------------------
Above these drivers sits the *sort service* (``repro.service``), the layer
consumers use when traffic is many small/ragged requests rather than one
big array:

* **segment tagging** (``core/segmented.py``) — a batch of R requests is
  fused into ONE sort by lifting each key to the int64 composite
  ``(segment_id << 32) | biased(key)``: the paper's §5.1.1 duplicate tag
  generalized to a segment tag. One balanced sort returns every segment
  contiguous and sorted, with splitters drawn from the shared oversample
  landing inside each segment in proportion to its size;
* **batch former** (``service/batch.py``) — ragged requests are packed
  greedily (FIFO) into batches quantized to power-of-two
  ``n_per_proc`` buckets, so arbitrary traffic shares O(log n) compiled
  programs through this module's :class:`SortExecutor` registry;
* **escalation per batch** (``service/service.py``) — each fused batch
  runs through :func:`bsp_sort_safe`'s capacity ladder independently, so
  an adversarial request escalates only its own batch, and per-request
  latency plus :class:`TierStats` counters surface as service telemetry;
* **capacity planning** (``repro.planner``) — the batch's starting tier
  and oversampling ratio come from a workload fingerprint + the
  segment-aware whp bound (``pair_capacity="planned"`` over the striped
  packing layout), adapted per fingerprint bucket by observed fault
  rates. The same planner object optionally drives :func:`bsp_sort_safe`
  and ``moe_ep_safe`` ladder starts (``planner=``).

Serve admission ordering (``serve/engine.py``) and data-pipeline length
bucketing (``data/pipeline.py``) are service consumers.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from . import merge as merge_mod
from . import primitives as prim
from . import routing, splitters
from .bitonic import sort_bitonic_spmd
from .local_sort import local_sort
from .sort_det import prepare_det_spmd, route_det_spmd, sort_det_spmd
from .sort_iran import prepare_iran_spmd, route_iran_spmd, sort_iran_spmd
from .sort_radix import (
    host_send_counts,
    prepare_radix_spmd,
    route_radix_spmd,
    sort_radix_spmd,
)
from .sort_ran import prepare_ran_spmd, route_ran_spmd, sort_ran_spmd
from .types import AXIS, PreparedSort, SortConfig, SortResult
from ..chaos import resolve_chaos
from ..obs import REGISTRY as _OBS
from ..obs import resolve_tracer

_ALGOS = {
    "det": sort_det_spmd,
    "iran": sort_iran_spmd,
    "ran": sort_ran_spmd,
    "bitonic": sort_bitonic_spmd,
}


def _prepare_bitonic_spmd(x, cfg, axis, values=(), rng=None):
    """[BSI] is perfectly balanced (single-rung ladder): nothing to carry."""
    del rng
    return PreparedSort(xs=x, vals=tuple(values), splits=None)


def _route_bitonic_spmd(prep, cfg, axis, rng=None):
    return sort_bitonic_spmd(prep.xs, cfg, axis, values=list(prep.vals), rng=rng)


#: algorithm -> (prepare, route); sort body == route(prepare(x)).
_PIPELINES = {
    "det": (prepare_det_spmd, route_det_spmd),
    "iran": (prepare_iran_spmd, route_iran_spmd),
    "ran": (prepare_ran_spmd, route_ran_spmd),
    "bitonic": (_prepare_bitonic_spmd, _route_bitonic_spmd),
}


def spmd_sort_fn(cfg: SortConfig) -> Callable:
    """The per-processor SPMD sort body for ``cfg``.

    ``route="radix"`` selects the count-then-distribute pipeline
    (``sort_radix.py``) regardless of ``algorithm`` — the distribution
    route replaces Ph3..Ph4, not the Ph2 local method.
    """
    cfg.validate()
    if cfg.route == "radix":
        return functools.partial(sort_radix_spmd, cfg=cfg)
    return functools.partial(_ALGOS[cfg.algorithm], cfg=cfg)


def spmd_prepare_fn(cfg: SortConfig) -> Callable:
    """The tier-invariant prepare stage for ``cfg``."""
    cfg.validate()
    if cfg.route == "radix":
        return functools.partial(prepare_radix_spmd, cfg=cfg)
    return functools.partial(_PIPELINES[cfg.algorithm][0], cfg=cfg)


def spmd_route_fn(cfg: SortConfig) -> Callable:
    """The tier-dependent route stage for ``cfg``."""
    cfg.validate()
    if cfg.route == "radix":
        return functools.partial(route_radix_spmd, cfg=cfg)
    return functools.partial(_PIPELINES[cfg.algorithm][1], cfg=cfg)


# ------------------------------------------------------------------ runners
def bsp_sort(
    x: jnp.ndarray,
    cfg: Optional[SortConfig] = None,
    *,
    values: Sequence[jnp.ndarray] = (),
    rng: Optional[jax.Array] = None,
    **overrides,
) -> SortResult:
    """Sort a (p, n_per_proc) global array with simulated processors."""
    p, n_p = x.shape
    if cfg is None:
        cfg = SortConfig(p=p, n_per_proc=n_p, **overrides)
    assert (cfg.p, cfg.n_per_proc) == (p, n_p), "config/layout mismatch"
    if rng is None:
        rng = jax.random.key(cfg.seed)
    fn = spmd_sort_fn(cfg)

    def body(xk, vk):
        buf, vbufs, count, overflow = fn(xk, axis=AXIS, values=vk, rng=rng)
        return buf, vbufs, count, overflow

    buf, vbufs, count, overflow = jax.vmap(body, axis_name=AXIS)(x, list(values))
    return SortResult(buf=buf, count=count, overflow=overflow.any()), vbufs


def bsp_sort_sharded(
    x: jnp.ndarray,
    mesh,
    mesh_axis: str,
    cfg: Optional[SortConfig] = None,
    *,
    values: Sequence[jnp.ndarray] = (),
    rng: Optional[jax.Array] = None,
    executor: Optional["SortExecutor"] = None,
    **overrides,
) -> SortResult:
    """Sort a (p, n_per_proc) array sharded over ``mesh_axis`` of ``mesh``.

    The shard-mapped callable comes from the executor registry, so repeated
    calls with the same (mesh, cfg, n_values) reuse one compiled program.
    """
    p, n_p = x.shape
    if cfg is None:
        cfg = SortConfig(p=p, n_per_proc=n_p, **overrides)
    if cfg.obs is not None or cfg.chaos is not None:
        # obs/chaos are hash-excluded, but strip them so executor keys
        # never pin a Tracer or FaultPlan
        cfg = dataclasses.replace(cfg, obs=None, chaos=None)
    if rng is None:
        rng = jax.random.key(cfg.seed)
    ex = executor if executor is not None else _EXECUTOR
    fn = ex.sort_sharded(cfg, mesh, mesh_axis, len(values))
    buf, vbufs, count, overflow = fn(jax.random.key_data(rng), x, *values)
    return SortResult(buf=buf, count=count, overflow=overflow.any()), list(vbufs)


# ------------------------------------------------- overflow-safe drivers
@dataclasses.dataclass
class TierStats:
    """Per-tier attempt counters for the capacity-escalation driver.

    ``attempts[tier]`` counts runs started at that tier, ``successes[tier]``
    the runs whose overflow flag was clean. Accumulates across calls when the
    same instance is passed back in, so a serving engine or benchmark loop
    gets "how often did w.h.p. capacity actually suffice" for free.
    """

    attempts: Dict[str, int] = dataclasses.field(default_factory=dict)
    successes: Dict[str, int] = dataclasses.field(default_factory=dict)
    last_tier: Optional[str] = None
    retries: int = 0  # total re-runs forced by overflow faults

    def record(self, tier: str, ok: bool) -> None:
        # Mirror every attempt into the process-wide metrics registry;
        # merge_from deliberately does NOT re-mirror (the per-batch record
        # already counted each attempt once).
        self.attempts[tier] = self.attempts.get(tier, 0) + 1
        _OBS.counter("sort.tier_attempts", tier=tier).inc()
        if ok:
            self.successes[tier] = self.successes.get(tier, 0) + 1
            self.last_tier = tier
            _OBS.counter("sort.tier_ok", tier=tier).inc()
        else:
            self.retries += 1
            _OBS.counter("sort.retries").inc()

    def merge_from(self, other: "TierStats") -> None:
        """Fold another instance's counters in (per-batch → accumulator).

        Lets a caller observe one dispatch in isolation (e.g. the capacity
        planner's fault feedback) while still accumulating service-wide
        telemetry in a shared instance.
        """
        for t, n in other.attempts.items():
            self.attempts[t] = self.attempts.get(t, 0) + n
        for t, n in other.successes.items():
            self.successes[t] = self.successes.get(t, 0) + n
        self.retries += other.retries
        if other.last_tier is not None:
            self.last_tier = other.last_tier

    def as_row(self) -> Dict[str, int]:
        """Flat counter row: attempts, clean-run counts, total retries.

        Successes are kept per tier (not just ``last_tier``) because one
        accumulating instance spans many calls — ``ok_whp/tier_whp`` is the
        long-run "how often did w.h.p. capacity suffice" rate.
        """
        row = {f"tier_{t}": n for t, n in self.attempts.items()}
        row |= {f"ok_{t}": n for t, n in self.successes.items()}
        row["retries"] = self.retries
        return row


class SortExecutor:
    """Registry of compiled sort callables for both runners.

    One instance (the module-level default) serves the whole process; tests
    may pass a fresh instance to the drivers for isolation. Callables are
    keyed by ``(stage, runner, cfg, n_values[, mesh, mesh_axis])`` where

    * ``prepare`` entries key on ``cfg.prepare_key()`` — every rung of a
      capacity ladder shares one compiled prepare callable and hence one
      :class:`PreparedSort`;
    * ``route``/``sort`` entries key on the full tier config (frozen
      dataclass, hashable — each rung compiles exactly once per process);
    * sharded entries additionally key on ``(mesh, mesh_axis)``, which is
      what stops ``bsp_sort_sharded_safe`` from rebuilding ``shard_map``
      per call (``jax.sharding.Mesh`` hashes by devices + axis names).

    ``trace_counts[key]`` increments every time JAX actually (re)traces the
    callable, so regression tests can assert compile reuse directly.

    All callables take the rng as raw ``jax.random.key_data`` (a (2,) uint32
    array) rather than a typed key: key data passes uniformly through jit
    *and* ``shard_map`` in/out specs on the pinned jax 0.4.37.
    """

    def __init__(self) -> None:
        self._fns: Dict[tuple, Callable] = {}
        self.trace_counts: Dict[tuple, int] = {}

    def _get(self, key: tuple, build: Callable[[], Callable]) -> Callable:
        fn = self._fns.get(key)
        if fn is None:
            fn = self._fns[key] = build()
        return fn

    def _count_trace(self, key: tuple) -> None:
        # Runs at trace time only (it is Python, not jaxpr), so the count is
        # exactly the number of (re)compilations of this callable.
        self.trace_counts[key] = self.trace_counts.get(key, 0) + 1

    # ------------------------------------------------------- vmap runner
    def prepare_vmap(self, cfg: SortConfig, n_values: int) -> Callable:
        pcfg = cfg.prepare_key()
        key = ("prepare", "vmap", pcfg, n_values)

        def build():
            prepare = spmd_prepare_fn(pcfg)

            def run(x, *vals):
                self._count_trace(key)

                def body(xk, vk):
                    return prepare(xk, axis=AXIS, values=vk)

                return jax.vmap(body, axis_name=AXIS)(x, list(vals))

            return jax.jit(run)

        return self._get(key, build)

    def route_vmap(self, tier_cfg: SortConfig, n_values: int) -> Callable:
        key = ("route", "vmap", tier_cfg, n_values)

        def build():
            route = spmd_route_fn(tier_cfg)

            def run(prep, rng_data):
                self._count_trace(key)
                rng = jax.random.wrap_key_data(rng_data)

                def body(prep_k):
                    return route(prep_k, axis=AXIS, rng=rng)

                return jax.vmap(body, axis_name=AXIS)(prep)

            return jax.jit(run)

        return self._get(key, build)

    def sort_vmap(self, cfg: SortConfig, n_values: int) -> Callable:
        """Monolithic prepare∘route in one program (fresh runs, benchmarks)."""
        key = ("sort", "vmap", cfg, n_values)

        def build():
            fn = spmd_sort_fn(cfg)

            def run(x, rng_data, *vals):
                self._count_trace(key)
                rng = jax.random.wrap_key_data(rng_data)

                def body(xk, vk):
                    return fn(xk, axis=AXIS, values=vk, rng=rng)

                return jax.vmap(body, axis_name=AXIS)(x, list(vals))

            return jax.jit(run)

        return self._get(key, build)

    # ---------------------------------------------------- sharded runner
    def _prep_specs(self, cfg: SortConfig, mesh_axis: str, n_values: int):
        if cfg.route == "radix":
            splits_spec = (P(mesh_axis),)  # counted (p+1,) boundaries
        elif cfg.algorithm == "det":
            splits_spec = (P(mesh_axis),) * 3
        else:
            splits_spec = None
        return PreparedSort(
            xs=P(mesh_axis), vals=(P(mesh_axis),) * n_values, splits=splits_spec
        )

    def prepare_sharded(
        self, cfg: SortConfig, mesh, mesh_axis: str, n_values: int
    ) -> Callable:
        pcfg = cfg.prepare_key()
        key = ("prepare", "sharded", pcfg, n_values, mesh, mesh_axis)

        def build():
            prepare = spmd_prepare_fn(pcfg)

            def body(xk, *vk):
                prep = prepare(xk[0], axis=mesh_axis, values=[v[0] for v in vk])
                return jax.tree.map(lambda a: a[None], prep)

            shmapped = prim.shard_map(
                body,
                mesh=mesh,
                in_specs=(P(mesh_axis),) * (1 + n_values),
                out_specs=self._prep_specs(pcfg, mesh_axis, n_values),
            )

            def run(x, *vals):
                self._count_trace(key)
                return shmapped(x, *vals)

            return jax.jit(run)

        return self._get(key, build)

    def route_sharded(
        self, tier_cfg: SortConfig, mesh, mesh_axis: str, n_values: int
    ) -> Callable:
        key = ("route", "sharded", tier_cfg, n_values, mesh, mesh_axis)

        def build():
            route = spmd_route_fn(tier_cfg)

            def body(prep, rng_data):
                prep_k = jax.tree.map(lambda a: a[0], prep)
                rng = jax.random.wrap_key_data(rng_data)
                buf, vbufs, count, overflow = route(prep_k, axis=mesh_axis, rng=rng)
                return (
                    buf[None],
                    tuple(v[None] for v in vbufs),
                    count[None],
                    overflow[None],
                )

            shmapped = prim.shard_map(
                body,
                mesh=mesh,
                in_specs=(
                    self._prep_specs(tier_cfg, mesh_axis, n_values),
                    P(),
                ),
                out_specs=(
                    P(mesh_axis),
                    (P(mesh_axis),) * n_values,
                    P(mesh_axis),
                    P(mesh_axis),
                ),
            )

            def run(prep, rng_data):
                self._count_trace(key)
                return shmapped(prep, rng_data)

            return jax.jit(run)

        return self._get(key, build)

    def sort_sharded(
        self, cfg: SortConfig, mesh, mesh_axis: str, n_values: int
    ) -> Callable:
        key = ("sort", "sharded", cfg, n_values, mesh, mesh_axis)

        def build():
            fn = spmd_sort_fn(cfg)

            def body(rng_data, xk, *vk):
                rng = jax.random.wrap_key_data(rng_data)
                buf, vbufs, count, overflow = fn(
                    xk[0], axis=mesh_axis, values=[v[0] for v in vk], rng=rng
                )
                return (
                    buf[None],
                    tuple(v[None] for v in vbufs),
                    count[None],
                    overflow[None],
                )

            shmapped = prim.shard_map(
                body,
                mesh=mesh,
                in_specs=(P(),) + (P(mesh_axis),) * (1 + n_values),
                out_specs=(
                    P(mesh_axis),
                    (P(mesh_axis),) * n_values,
                    P(mesh_axis),
                    P(mesh_axis),
                ),
            )

            def run(rng_data, x, *vals):
                self._count_trace(key)
                return shmapped(rng_data, x, *vals)

            return jax.jit(run)

        return self._get(key, build)


#: process-wide default registry; drivers accept ``executor=`` for isolation.
_EXECUTOR = SortExecutor()


def default_executor() -> SortExecutor:
    return _EXECUTOR


class InFlightSort:
    """A *launched* overflow-safe sort whose completion has not been awaited.

    Construction dispatches the first ladder rung's route stage to the
    device queue and returns immediately — JAX's async dispatch means the
    host is free while the device executes, so a caller can plan/pack/launch
    the *next* batch before blocking here. :meth:`wait` is the only sync
    point: it reads the rung's overflow flag (the escalation decision) and,
    on a fault, launches the next rung — the same escalation loop
    ``bsp_sort_safe`` always ran, split at the host-sync boundary.

    The rng is folded per tier so a randomized retry is an independent trial
    (re-drawing the failed splitter sample would correlate failures).
    ``run_tier(tier_cfg, tier_rng) -> (SortResult, value_bufs)``. ``ladder``
    is (a suffix of) ``SortConfig.tier_ladder()`` — a planner policy may
    have sliced the doomed cheap rungs off the front. ``scope`` is a context
    factory entered around every device launch (the segmented service needs
    ``enable_x64`` re-entered when escalation re-launches from ``wait``);
    ``on_complete(stats)`` fires once, after the winning rung — completion-
    callback hooks (planner feedback) ride it instead of blocking the
    launcher. ``wait`` is idempotent: the result is cached.

    ``tracer``/``trace_meta`` (``repro.obs``) record one "route" span per
    rung — opened at the device launch here or in :meth:`wait`'s escalation,
    closed at the overflow host-sync — carrying the rung's traced h-relation
    size, superstep count, and received-key balance. Both default to off and
    only ever touch host-side bookkeeping around the jitted calls.
    """

    def __init__(
        self,
        ladder: tuple,
        rng: jax.Array,
        stats: Optional[TierStats],
        run_tier: Callable,
        *,
        scope: Optional[Callable] = None,
        on_complete: Optional[Callable] = None,
        tracer=None,
        trace_meta: Optional[Dict] = None,
        chaos=None,
    ) -> None:
        self.stats = stats if stats is not None else TierStats()
        self._ladder = ladder
        self._rng = rng
        self._run_tier = run_tier
        self._scope = scope if scope is not None else contextlib.nullcontext
        self._on_complete = on_complete
        self._tracer = tracer
        # chaos capacity-fault injection: a host-side flip of the overflow
        # decision for non-terminal rungs only (repro.chaos.FaultPlan) —
        # the escalation it forces is the real recovery path, and the next
        # rung's result is byte-identical to an unfaulted run's
        self._chaos = chaos
        self._chaos_key = chaos.next_sort() if chaos is not None else 0
        self._meta = trace_meta if trace_meta is not None else {}
        #: timeline lane of this sort's spans (None when untraced) — the
        #: segmented service uses it to attach its own points to the lane.
        self.trace_tid = self._meta.get("tid") if tracer is not None else None
        self._out: Optional[Tuple[SortResult, List[jnp.ndarray], TierStats]] = None
        self._i = 0
        self._t_launch = tracer.now() if tracer is not None else 0.0
        with self._scope():
            self._pending = run_tier(ladder[0][1], jax.random.fold_in(rng, 0))

    def done(self) -> bool:
        """Whether :meth:`wait` has already resolved (never blocks)."""
        return self._out is not None

    def _record_route(self, res: SortResult, tier: str, tier_cfg, ok, t_sync):
        """Close the launch-opened route span at the overflow host-sync."""
        tr = self._tracer
        t_end = tr.now()
        cat = self._meta.get("cat", "sort")
        tid = self.trace_tid or "main"
        counts = np.asarray(res.count)
        recv_max = int(counts.max())
        recv_mean = float(counts.mean())
        row_bytes = int(self._meta.get("row_bytes", 4))
        # h of the route stage in 32-bit words: the larger of what any proc
        # sent (its n_per_proc rows) and what any proc received, times the
        # packed row width of the fused exchange.
        h_words = (max(recv_max, tier_cfg.n_per_proc) * row_bytes) // 4
        args = dict(
            tier=tier,
            rung=self._i,
            ok=ok,
            sync_s=round(t_end - t_sync, 6),
            h_words=h_words,
            supersteps=routing.route_supersteps(tier_cfg.routing, tier_cfg.p),
            recv_max=recv_max,
            recv_mean=recv_mean,
            imbalance=(recv_max / recv_mean) if recv_mean > 0 else 1.0,
        )
        if tier_cfg.p <= 64:
            args["recv"] = counts.tolist()  # per-proc key counts
        tr.add_span("route", self._t_launch, t_end=t_end, cat=cat, tid=tid, **args)
        tr.point("host_sync", cat=cat, tid=tid, what="overflow", rung=self._i, ok=ok)

    def wait(self) -> Tuple[SortResult, List[jnp.ndarray], TierStats]:
        """Block until a rung's overflow flag is clean; escalate on faults."""
        if self._out is not None:
            return self._out
        while True:
            res, vbufs = self._pending
            tier, tier_cfg = self._ladder[self._i]
            t_sync = self._tracer.now() if self._tracer is not None else 0.0
            ok = not bool(res.overflow)  # host sync: the retry decision point
            if (
                ok
                and self._chaos is not None
                and self._i + 1 < len(self._ladder)  # never fault terminal
                and self._chaos.fault_capacity(self._chaos_key, self._i)
            ):
                ok = False  # injected capacity fault: walk the next rung
                if self._tracer is not None:
                    self._tracer.point(
                        "chaos_capacity_fault",
                        cat="chaos",
                        tid=self.trace_tid or "main",
                        rung=self._i,
                        tier=tier,
                    )
            if self._tracer is not None:
                self._record_route(res, tier, tier_cfg, ok, t_sync)
            self.stats.record(tier, ok)
            if ok:
                self._out = (res, vbufs, self.stats)
                if self._on_complete is not None:
                    self._on_complete(self.stats)
                return self._out
            self._i += 1
            if self._i >= len(self._ladder):
                raise RuntimeError(
                    "capacity escalation exhausted — unreachable: the "
                    "allgather/full tier cannot overflow (ladder: "
                    f"{[t for t, _ in self._ladder]})"
                )
            if self._tracer is not None:
                self._t_launch = self._tracer.now()
            with self._scope():
                self._pending = self._run_tier(
                    self._ladder[self._i][1],
                    jax.random.fold_in(self._rng, self._i),
                )


def _escalate(
    ladder: tuple,
    rng: jax.Array,
    stats: Optional[TierStats],
    run_tier: Callable,
    *,
    tracer=None,
    trace_meta: Optional[Dict] = None,
) -> Tuple[SortResult, List[jnp.ndarray], TierStats]:
    """Blocking escalation: launch rung 0 and wait through the ladder."""
    return InFlightSort(
        ladder, rng, stats, run_tier, tracer=tracer, trace_meta=trace_meta
    ).wait()


def _trace_meta_for(tracer, x, values, cat: str = "sort") -> Optional[Dict]:
    """Per-launch trace metadata: a fresh timeline lane + packed row width."""
    if tracer is None:
        return None
    return {
        "tid": tracer.next_tid("sort"),
        "cat": cat,
        "row_bytes": routing.packed_row_bytes(x.dtype, [v.dtype for v in values]),
    }


def _trace_prepared(tracer, meta: Dict, cfg: SortConfig, prep: PreparedSort) -> None:
    """Record the prepared distribution snapshot (host-side, traced runs only).

    * ``route="radix"`` — the counted boundaries are exact: per-(src, dst)
      send counts and byte volumes of the upcoming h-relation, before any
      data moves.
    * ``det`` — the tier-invariant splitters are in hand: searchsorting each
      locally sorted run against them gives the splitter-implied boundary
      *estimate* (tag-blind, so off by at most the duplicate runs) and hence
      the oversampling skew the Lemma 5.1 bound is guarding against.
    * ``iran``/``ran`` draw their sample inside the route stage (a retry
      must be an independent trial), so there is nothing prepared to read.
    """
    tid, cat = meta["tid"], meta.get("cat", "sort")
    row_bytes = int(meta.get("row_bytes", 4))
    if cfg.route == "radix" and prep.splits is not None:
        sendc = host_send_counts(prep.splits[0])  # (p, p) exact counts
        recv = sendc.sum(axis=0)
        args = dict(
            kind="radix_counts",
            pair_max=int(sendc.max()),
            recv_max=int(recv.max()),
            imbalance=float(recv.max() / recv.mean()) if recv.mean() > 0 else 1.0,
            row_bytes=row_bytes,
        )
        if cfg.p <= 64:
            args["send_bytes"] = (sendc * row_bytes).tolist()  # per (src, dst)
        tracer.point("distribution", cat=cat, tid=tid, **args)
    elif cfg.algorithm == "det" and cfg.route == "sample" and prep.splits:
        keys = np.asarray(prep.splits[0])[0]  # replicated (p-1,) splitter keys
        xs = np.asarray(prep.xs)  # (p, n_per_proc), locally sorted
        bounds = np.stack([np.searchsorted(row, keys) for row in xs])
        sendc = np.diff(
            np.concatenate(
                [
                    np.zeros((cfg.p, 1), np.int64),
                    bounds,
                    np.full((cfg.p, 1), xs.shape[1], np.int64),
                ],
                axis=1,
            ),
            axis=1,
        )
        recv = sendc.sum(axis=0)
        args = dict(
            kind="splitter_estimate",
            pair_max=int(sendc.max()),
            recv_max=int(recv.max()),
            skew=float(recv.max() / recv.mean()) if recv.mean() > 0 else 1.0,
            omega=cfg.omega_eff,
            sample_size=cfg.s,
            row_bytes=row_bytes,
        )
        if cfg.p <= 64:
            args["send_bytes"] = (sendc * row_bytes).tolist()  # per (src, dst)
        tracer.point("distribution", cat=cat, tid=tid, **args)


def _radix_exact_ladder(cfg: SortConfig, prep: PreparedSort) -> tuple:
    """The radix route's whole ladder: ONE rung at the host-counted capacity.

    ``prep.splits[0]`` carries the counted (p, p+1) bucket boundaries, so
    the true per-(src,dst) maximum and the true receive total are known
    *before any data moves* — a (p², ) int32 host read (the launch path's
    only extra sync; the boundaries were computed by prepare anyway). Both
    bounds are quantized up to ~16 octave steps (a relative 1/16 grid, so
    a balanced batch's pair capacity stays within ~6% of the true n_p/p
    count instead of rounding to a coarse absolute step) — nearby batches
    share compiled route programs while distinct capacities stay
    logarithmic in n_p. Then clamped to the exact-tier sizes — the rung
    can never exceed what ``pair_capacity="exact"`` + ``n_max_mode="full"``
    would have allocated, and since cap ≥ true count on every pair,
    overflow (and hence any retry) is impossible.
    """
    sendc = host_send_counts(prep.splits[0])  # counts[src, dst]
    pair_true = int(sendc.max())
    recv_true = int(sendc.sum(axis=0).max())

    def _quant(true, hi):
        step = max(cfg.pad_align, 1 << max(0, true.bit_length() - 4))
        return min(hi, -(-max(true, 1) // step) * step)

    qpair = _quant(pair_true, cfg.n_per_proc)
    qrecv = _quant(recv_true, cfg.n)
    tier = dataclasses.replace(
        cfg,
        pair_capacity="planned",
        pair_cap_override=qpair,
        capacity_factor=1.0,
        n_max_mode="bound",
        n_max_override=qrecv,
    )
    return (("radix", tier),)


def bsp_sort_safe_launch(
    x: jnp.ndarray,
    cfg: Optional[SortConfig] = None,
    *,
    values: Sequence[jnp.ndarray] = (),
    rng: Optional[jax.Array] = None,
    stats: Optional[TierStats] = None,
    executor: Optional[SortExecutor] = None,
    resume: bool = True,
    planner=None,
    scope: Optional[Callable] = None,
    **overrides,
) -> InFlightSort:
    """Launch an overflow-safe sort without awaiting it.

    ``prepare`` plus the first ladder rung's ``route`` are dispatched to the
    device queue and an :class:`InFlightSort` is returned immediately —
    the caller overlaps host work (planning the next batch) with the device
    execution and blocks only at :meth:`InFlightSort.wait`. The async
    service dispatcher (``repro.service.dispatch``) is the primary consumer.

    ``planner`` (a :class:`repro.planner.CapacityPlanner`) is an optional
    traffic-learned policy: repeated sorts of the same shape/config that
    keep faulting their cheap rung start one rung up next time (and probe
    back down after a clean streak) — the ladder above the learned start is
    unchanged, so safety is untouched. Its outcome feedback runs as a
    completion callback on ``wait``. ``scope`` is a context factory entered
    around every device launch (``enable_x64`` for int64 composites).
    """
    p, n_p = x.shape
    if cfg is None:
        cfg = SortConfig(p=p, n_per_proc=n_p, **overrides)
    tracer = resolve_tracer(cfg.obs)
    chaos = resolve_chaos(cfg.chaos)
    if cfg.obs is not None or cfg.chaos is not None:
        # Hold the tracer/chaos plan as locals only: the cfg the ladder/
        # executor see carries obs=None/chaos=None, so registry keys never
        # pin a Tracer or FaultPlan. (Both are hash/compare-excluded —
        # this changes no cache key.)
        cfg = dataclasses.replace(cfg, obs=None, chaos=None)
    meta = _trace_meta_for(tracer, x, values)
    if rng is None:
        rng = jax.random.key(cfg.seed)
    ex = executor if executor is not None else _EXECUTOR
    nv = len(values)

    ladder = cfg.tier_ladder()
    bucket = None
    if planner is not None and len(ladder) > 1:
        bucket = (
            f"sort/{cfg.algorithm}/p{p}/npp{n_p}/{cfg.pair_capacity}"
        )
        ladder = ladder[planner.rung_for(bucket, len(ladder)) :]
    stats = stats if stats is not None else TierStats()
    retries_before = stats.retries

    on_complete = None
    if bucket is not None:
        n_rungs = len(cfg.tier_ladder())

        def on_complete(st: TierStats, _bucket=bucket) -> None:
            planner.observe(_bucket, st.retries > retries_before, n_rungs)

    if not resume:

        def run_tier(tier_cfg, tier_rng):
            fn = ex.sort_vmap(tier_cfg, nv)
            buf, vbufs, count, overflow = fn(
                x, jax.random.key_data(tier_rng), *values
            )
            return SortResult(buf=buf, count=count, overflow=overflow.any()), list(
                vbufs
            )

    else:
        # Ph2 (+ det Ph3, or the radix counting pass), exactly once — inside
        # the scope: the prepare stage consumes the (possibly int64) input
        # directly
        def _prepare():
            if scope is not None:
                with scope():
                    return ex.prepare_vmap(cfg, nv)(x, *values)
            return ex.prepare_vmap(cfg, nv)(x, *values)

        if tracer is not None:
            # Traced runs block at the stage boundary so the prepare span is
            # device-inclusive and the route spans start clean. Untraced runs
            # keep full async dispatch.
            with tracer.span(
                "prepare",
                tid=meta["tid"],
                algorithm=cfg.algorithm,
                route=cfg.route,
                p=p,
                n_per_proc=n_p,
            ):
                prep = jax.block_until_ready(_prepare())
            _trace_prepared(tracer, meta, cfg, prep)
        else:
            prep = _prepare()
        if cfg.route == "radix":
            # counts are in hand: collapse the ladder to one rung sized to
            # the true maxima — zero retries by construction
            if tracer is not None:
                tracer.point(
                    "host_sync", tid=meta["tid"], what="radix_counts"
                )
            ladder = _radix_exact_ladder(cfg, prep)

        def run_tier(tier_cfg, tier_rng):
            fn = ex.route_vmap(tier_cfg, nv)
            buf, vbufs, count, overflow = fn(prep, jax.random.key_data(tier_rng))
            return SortResult(buf=buf, count=count, overflow=overflow.any()), list(
                vbufs
            )

    return InFlightSort(
        ladder,
        rng,
        stats,
        run_tier,
        scope=scope,
        on_complete=on_complete,
        tracer=tracer,
        trace_meta=meta,
        chaos=chaos,
    )


def bsp_sort_safe(
    x: jnp.ndarray,
    cfg: Optional[SortConfig] = None,
    *,
    values: Sequence[jnp.ndarray] = (),
    rng: Optional[jax.Array] = None,
    stats: Optional[TierStats] = None,
    executor: Optional[SortExecutor] = None,
    resume: bool = True,
    planner=None,
    **overrides,
) -> Tuple[SortResult, List[jnp.ndarray], TierStats]:
    """Overflow-safe :func:`bsp_sort`: escalate through the capacity ladder.

    Runs ``prepare`` once, then the jitted ``route`` stage at each tier of
    ``cfg.tier_ladder()``; the first tier whose ``overflow`` flag is clean
    wins. The terminal tier holds the whole input, so no key is ever dropped
    regardless of skew or adversarial placement. ``resume=False`` falls back
    to re-running the whole sort per rung (the pre-pipeline behaviour, kept
    for the ``retry_cost`` benchmark comparison). Returns
    ``(result, value_bufs, stats)``. The blocking form of
    :func:`bsp_sort_safe_launch` — launch + immediate wait, byte-identical.
    """
    return bsp_sort_safe_launch(
        x,
        cfg,
        values=values,
        rng=rng,
        stats=stats,
        executor=executor,
        resume=resume,
        planner=planner,
        **overrides,
    ).wait()


def bsp_sort_sharded_safe(
    x: jnp.ndarray,
    mesh,
    mesh_axis: str,
    cfg: Optional[SortConfig] = None,
    *,
    values: Sequence[jnp.ndarray] = (),
    rng: Optional[jax.Array] = None,
    stats: Optional[TierStats] = None,
    executor: Optional[SortExecutor] = None,
    resume: bool = True,
    **overrides,
) -> Tuple[SortResult, List[jnp.ndarray], TierStats]:
    """Overflow-safe :func:`bsp_sort_sharded` — same resumable escalation on
    real devices. Shard-mapped prepare/route callables come from the executor
    registry, so repeated calls with the same mesh/cfg reuse one compiled
    program per stage instead of rebuilding ``shard_map`` per call."""
    p, n_p = x.shape
    if cfg is None:
        cfg = SortConfig(p=p, n_per_proc=n_p, **overrides)
    tracer = resolve_tracer(cfg.obs)
    if cfg.obs is not None or cfg.chaos is not None:
        # chaos injection targets the vmapped service path; the sharded
        # driver only strips the handle so executor keys stay clean
        cfg = dataclasses.replace(cfg, obs=None, chaos=None)
    meta = _trace_meta_for(tracer, x, values)
    if rng is None:
        rng = jax.random.key(cfg.seed)
    ex = executor if executor is not None else _EXECUTOR
    nv = len(values)

    if not resume:

        def run_tier(tier_cfg, tier_rng):
            fn = ex.sort_sharded(tier_cfg, mesh, mesh_axis, nv)
            buf, vbufs, count, overflow = fn(
                jax.random.key_data(tier_rng), x, *values
            )
            return SortResult(buf=buf, count=count, overflow=overflow.any()), list(
                vbufs
            )

        return _escalate(
            cfg.tier_ladder(), rng, stats, run_tier, tracer=tracer, trace_meta=meta
        )

    if tracer is not None:
        with tracer.span(
            "prepare",
            tid=meta["tid"],
            algorithm=cfg.algorithm,
            route=cfg.route,
            p=p,
            n_per_proc=n_p,
        ):
            prep = jax.block_until_ready(
                ex.prepare_sharded(cfg, mesh, mesh_axis, nv)(x, *values)
            )
        _trace_prepared(tracer, meta, cfg, prep)
    else:
        prep = ex.prepare_sharded(cfg, mesh, mesh_axis, nv)(x, *values)
    ladder = cfg.tier_ladder()
    if cfg.route == "radix":
        if tracer is not None:
            tracer.point("host_sync", tid=meta["tid"], what="radix_counts")
        ladder = _radix_exact_ladder(cfg, prep)

    def run_tier(tier_cfg, tier_rng):
        fn = ex.route_sharded(tier_cfg, mesh, mesh_axis, nv)
        buf, vbufs, count, overflow = fn(prep, jax.random.key_data(tier_rng))
        return SortResult(buf=buf, count=count, overflow=overflow.any()), list(vbufs)

    return _escalate(ladder, rng, stats, run_tier, tracer=tracer, trace_meta=meta)


def gathered_output(result: SortResult) -> np.ndarray:
    """Host-side: concatenate valid prefixes into the full sorted sequence."""
    buf = np.asarray(result.buf)
    count = np.asarray(result.count)
    return np.concatenate([buf[k, : count[k]] for k in range(buf.shape[0])])


# ------------------------------------------------- phase-decomposed (bench)
def phase_fns(cfg: SortConfig, rng: Optional[jax.Array] = None) -> Dict[str, Callable]:
    """Separately-jittable phase functions over the global (p, n_p) layout.

    Mirrors the paper's Ph2..Ph6 instrumentation (Tables 4-7). Each callable
    consumes the previous phase's output so a benchmark can block between
    phases. Only det/iran decompose; ran/bitonic are single calls.

    This is a thin view over the pipeline: SeqSort (+ Sampling for ``det``)
    is exactly the prepare stage's work, Prefix/Routing/Merging the route
    stage's — each phase calls the same stage function the sort bodies use.
    """
    cfg.validate()
    if rng is None:
        rng = jax.random.key(cfg.seed)

    def vm(f):
        return jax.jit(jax.vmap(f, axis_name=AXIS))

    def ph2(x):
        return local_sort(x, cfg.local_sort)[0]

    def ph3(xs):
        return splitters.splitter_stage(xs, cfg, AXIS, rng)

    def ph4(xs, splits):
        return splitters.searchsorted_tagged(xs, splits, AXIS)

    def ph5(xs, bounds):
        buf, _, count, overflow = routing.route(xs, bounds, cfg, AXIS)
        return buf, count, overflow

    def ph6(buf):
        return merge_mod.merge_by_sort(buf)[0]

    return {
        "SeqSort": vm(ph2),
        "Sampling": vm(ph3),
        "Prefix": vm(ph4),
        "Routing": vm(ph5),
        "Merging": vm(ph6),
    }
