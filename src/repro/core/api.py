"""Public entry points for the BSP sorting library.

Two runners share one SPMD implementation (verified equivalent in tests):

* :func:`bsp_sort` — *simulated processors*: the global (p, n_per_proc)
  layout is vmapped with an ``axis_name``, so JAX's collective batching rules
  execute the exact same collective pattern on one device. This is how the
  paper's Cray T3D experiments (p = 8..128) are reproduced on CPU.
* :func:`bsp_sort_sharded` — *real devices*: the same SPMD function under
  ``jax.shard_map`` over a mesh axis; used by the multi-pod dry-run, the MoE
  dispatch layer, and the distributed tests.

Because a sort may never drop keys, production callers use the *overflow-safe
drivers* :func:`bsp_sort_safe` / :func:`bsp_sort_sharded_safe`: a host-side
escalation loop that runs the jitted sort at each rung of the config's
capacity-tier ladder (``SortConfig.tier_ladder``: whp → whp×2 → exact →
allgather/full), inspects the ``overflow`` fault flag, and re-runs at the
next tier until the output is complete. Per-tier attempt counters
(:class:`TierStats`) feed the serving engine and the benchmark tables.

Phase-decomposed callables for the paper's Table 4-7 timing methodology are
exposed via :func:`phase_fns`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from . import merge as merge_mod
from . import primitives as prim
from . import routing, splitters
from .bitonic import sort_bitonic_spmd
from .local_sort import local_sort
from .sort_det import sort_det_spmd
from .sort_iran import sort_iran_spmd
from .sort_ran import sort_ran_spmd
from .types import AXIS, SortConfig, SortResult

_ALGOS = {
    "det": sort_det_spmd,
    "iran": sort_iran_spmd,
    "ran": sort_ran_spmd,
    "bitonic": sort_bitonic_spmd,
}


def spmd_sort_fn(cfg: SortConfig) -> Callable:
    """The per-processor SPMD sort body for ``cfg.algorithm``."""
    cfg.validate()
    return functools.partial(_ALGOS[cfg.algorithm], cfg=cfg)


# ------------------------------------------------------------------ runners
def bsp_sort(
    x: jnp.ndarray,
    cfg: Optional[SortConfig] = None,
    *,
    values: Sequence[jnp.ndarray] = (),
    rng: Optional[jax.Array] = None,
    **overrides,
) -> SortResult:
    """Sort a (p, n_per_proc) global array with simulated processors."""
    p, n_p = x.shape
    if cfg is None:
        cfg = SortConfig(p=p, n_per_proc=n_p, **overrides)
    assert (cfg.p, cfg.n_per_proc) == (p, n_p), "config/layout mismatch"
    if rng is None:
        rng = jax.random.key(cfg.seed)
    fn = spmd_sort_fn(cfg)

    def body(xk, vk):
        buf, vbufs, count, overflow = fn(xk, axis=AXIS, values=vk, rng=rng)
        return buf, vbufs, count, overflow

    buf, vbufs, count, overflow = jax.vmap(body, axis_name=AXIS)(x, list(values))
    return SortResult(buf=buf, count=count, overflow=overflow.any()), vbufs


def bsp_sort_sharded(
    x: jnp.ndarray,
    mesh,
    mesh_axis: str,
    cfg: Optional[SortConfig] = None,
    *,
    values: Sequence[jnp.ndarray] = (),
    rng: Optional[jax.Array] = None,
    **overrides,
) -> SortResult:
    """Sort a (p, n_per_proc) array sharded over ``mesh_axis`` of ``mesh``."""
    p, n_p = x.shape
    if cfg is None:
        cfg = SortConfig(p=p, n_per_proc=n_p, **overrides)
    if rng is None:
        rng = jax.random.key(cfg.seed)
    fn = spmd_sort_fn(cfg)

    def body(xk, *vk):
        buf, vbufs, count, overflow = fn(
            xk[0], axis=mesh_axis, values=[v[0] for v in vk], rng=rng
        )
        return (
            buf[None],
            tuple(v[None] for v in vbufs),
            count[None],
            overflow[None],
        )

    nv = len(values)
    shmapped = prim.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(mesh_axis),) * (1 + nv),
        out_specs=(P(mesh_axis), (P(mesh_axis),) * nv, P(mesh_axis), P(mesh_axis)),
    )
    buf, vbufs, count, overflow = shmapped(x, *values)
    return SortResult(buf=buf, count=count, overflow=overflow.any()), list(vbufs)


# ------------------------------------------------- overflow-safe drivers
@dataclasses.dataclass
class TierStats:
    """Per-tier attempt counters for the capacity-escalation driver.

    ``attempts[tier]`` counts runs started at that tier, ``successes[tier]``
    the runs whose overflow flag was clean. Accumulates across calls when the
    same instance is passed back in, so a serving engine or benchmark loop
    gets "how often did w.h.p. capacity actually suffice" for free.
    """

    attempts: Dict[str, int] = dataclasses.field(default_factory=dict)
    successes: Dict[str, int] = dataclasses.field(default_factory=dict)
    last_tier: Optional[str] = None
    retries: int = 0  # total re-runs forced by overflow faults

    def record(self, tier: str, ok: bool) -> None:
        self.attempts[tier] = self.attempts.get(tier, 0) + 1
        if ok:
            self.successes[tier] = self.successes.get(tier, 0) + 1
            self.last_tier = tier
        else:
            self.retries += 1

    def as_row(self) -> Dict[str, int]:
        """Flat counter row: attempts, clean-run counts, total retries.

        Successes are kept per tier (not just ``last_tier``) because one
        accumulating instance spans many calls — ``ok_whp/tier_whp`` is the
        long-run "how often did w.h.p. capacity suffice" rate.
        """
        row = {f"tier_{t}": n for t, n in self.attempts.items()}
        row |= {f"ok_{t}": n for t, n in self.successes.items()}
        row["retries"] = self.retries
        return row


#: jitted per-tier callables, keyed by (cfg, n_values) — tier configs are
#: frozen dataclasses, so each rung compiles exactly once per process.
_TIER_JIT_CACHE: Dict[Tuple[SortConfig, int], Callable] = {}


def _tier_callable(cfg: SortConfig, n_values: int) -> Callable:
    key = (cfg, n_values)
    fn = _TIER_JIT_CACHE.get(key)
    if fn is None:

        def run(x, rng, *vals):
            res, vbufs = bsp_sort(x, cfg, values=vals, rng=rng)
            return res.buf, vbufs, res.count, res.overflow

        fn = _TIER_JIT_CACHE[key] = jax.jit(run)
    return fn


def _escalate(
    cfg: SortConfig, rng: jax.Array, stats: Optional[TierStats], run_tier: Callable
) -> Tuple[SortResult, List[jnp.ndarray], TierStats]:
    """Shared escalation loop: run each ladder rung until the overflow flag
    is clean. The rng is folded per tier so a randomized retry is an
    independent trial (re-drawing the failed splitter sample would correlate
    failures). ``run_tier(tier_cfg, tier_rng) -> (SortResult, value_bufs)``."""
    stats = stats if stats is not None else TierStats()
    ladder = cfg.tier_ladder()
    for i, (tier, tier_cfg) in enumerate(ladder):
        res, vbufs = run_tier(tier_cfg, jax.random.fold_in(rng, i))
        ok = not bool(res.overflow)  # host sync: the retry decision point
        stats.record(tier, ok)
        if ok:
            return res, vbufs, stats
    raise RuntimeError(
        "capacity escalation exhausted — unreachable: the allgather/full "
        f"tier cannot overflow (ladder: {[t for t, _ in ladder]})"
    )


def bsp_sort_safe(
    x: jnp.ndarray,
    cfg: Optional[SortConfig] = None,
    *,
    values: Sequence[jnp.ndarray] = (),
    rng: Optional[jax.Array] = None,
    stats: Optional[TierStats] = None,
    **overrides,
) -> Tuple[SortResult, List[jnp.ndarray], TierStats]:
    """Overflow-safe :func:`bsp_sort`: escalate through the capacity ladder.

    Runs the jitted sort at each tier of ``cfg.tier_ladder()``; the first
    tier whose ``overflow`` flag is clean wins. The terminal tier holds the
    whole input, so no key is ever dropped regardless of skew or adversarial
    placement. Returns ``(result, value_bufs, stats)``.
    """
    p, n_p = x.shape
    if cfg is None:
        cfg = SortConfig(p=p, n_per_proc=n_p, **overrides)
    if rng is None:
        rng = jax.random.key(cfg.seed)

    def run_tier(tier_cfg, tier_rng):
        fn = _tier_callable(tier_cfg, len(values))
        buf, vbufs, count, overflow = fn(x, tier_rng, *values)
        return SortResult(buf=buf, count=count, overflow=overflow), list(vbufs)

    return _escalate(cfg, rng, stats, run_tier)


def bsp_sort_sharded_safe(
    x: jnp.ndarray,
    mesh,
    mesh_axis: str,
    cfg: Optional[SortConfig] = None,
    *,
    values: Sequence[jnp.ndarray] = (),
    rng: Optional[jax.Array] = None,
    stats: Optional[TierStats] = None,
    **overrides,
) -> Tuple[SortResult, List[jnp.ndarray], TierStats]:
    """Overflow-safe :func:`bsp_sort_sharded` — same escalation loop on real
    devices. The per-tier callables are rebuilt per call (shard_map closes
    over the mesh); XLA's compile cache dedupes the repeats."""
    p, n_p = x.shape
    if cfg is None:
        cfg = SortConfig(p=p, n_per_proc=n_p, **overrides)
    if rng is None:
        rng = jax.random.key(cfg.seed)

    def run_tier(tier_cfg, tier_rng):
        return bsp_sort_sharded(
            x, mesh, mesh_axis, tier_cfg, values=values, rng=tier_rng
        )

    return _escalate(cfg, rng, stats, run_tier)


def gathered_output(result: SortResult) -> np.ndarray:
    """Host-side: concatenate valid prefixes into the full sorted sequence."""
    buf = np.asarray(result.buf)
    count = np.asarray(result.count)
    return np.concatenate([buf[k, : count[k]] for k in range(buf.shape[0])])


# ------------------------------------------------- phase-decomposed (bench)
def phase_fns(cfg: SortConfig, rng: Optional[jax.Array] = None) -> Dict[str, Callable]:
    """Separately-jittable phase functions over the global (p, n_p) layout.

    Mirrors the paper's Ph2..Ph6 instrumentation (Tables 4-7). Each callable
    consumes the previous phase's output so a benchmark can block between
    phases. Only det/iran decompose; ran/bitonic are single calls.
    """
    cfg.validate()
    if rng is None:
        rng = jax.random.key(cfg.seed)

    def vm(f):
        return jax.jit(jax.vmap(f, axis_name=AXIS))

    def ph2(x):
        return local_sort(x, cfg.local_sort)[0]

    def ph3(xs):
        if cfg.algorithm == "det":
            sample = splitters.regular_sample(xs, cfg, AXIS)
        else:
            sample = splitters.random_sample(xs, cfg, AXIS, rng)
        return splitters.splitters_from_sorted_sample(cfg, sample, AXIS)

    def ph4(xs, splits):
        return splitters.searchsorted_tagged(xs, splits, AXIS)

    def ph5(xs, bounds):
        buf, _, count, overflow = routing.route(xs, bounds, cfg, AXIS)
        return buf, count, overflow

    def ph6(buf):
        return merge_mod.merge_by_sort(buf)[0]

    return {
        "SeqSort": vm(ph2),
        "Sampling": vm(ph3),
        "Prefix": vm(ph4),
        "Routing": vm(ph5),
        "Merging": vm(ph6),
    }
