"""Linear-work integer sort — the paper's radixsort ([DSR]/[RSR] variants).

The T3D implementation is a scalar LSD radix sort. The TPU-native analogue of
a counting sort pass is a *one-hot cumulative-sum rank computation*: for each
digit value d, rank(i) = (# earlier keys with digit d) + (# keys with digit
< d) — both are cumsums of the (n, 2^bits) one-hot matrix, which lower to
full-width vector ops (and on MXU-bearing hardware the one-hot reduction is a
matmul). Work is O(n · 2^bits / bits) per word — linear, like the paper's.

Stable per pass ⇒ stable overall, so it composes with §5.1.1 duplicate
handling exactly like the comparison sorts.
"""
from __future__ import annotations

import jax.numpy as jnp


def _to_unsigned_order_preserving(keys: jnp.ndarray) -> jnp.ndarray:
    """Map keys to a same-width unsigned dtype preserving order (bias the
    sign bit for signed ints). Width-generic: 64-bit keys — e.g. the
    segmented sort's (segment, key) composites — keep all their bits."""
    nbits = jnp.dtype(keys.dtype).itemsize * 8
    udtype = jnp.dtype(f"uint{nbits}")
    if jnp.issubdtype(keys.dtype, jnp.signedinteger):
        return keys.astype(udtype) ^ udtype.type(1 << (nbits - 1))
    return keys.astype(udtype)


def radix_argsort(keys: jnp.ndarray, bits: int = 4) -> jnp.ndarray:
    """Stable argsort of integer keys via LSD counting passes.

    Each pass computes ranks with one-hot cumsums (stable), giving linear
    total work ``O(n · w/bits · 2^bits)`` vector ops for w-bit keys.
    """
    assert jnp.issubdtype(keys.dtype, jnp.integer)
    u = _to_unsigned_order_preserving(keys)
    nbits = jnp.dtype(u.dtype).itemsize * 8
    n = keys.shape[0]
    order = jnp.arange(n, dtype=jnp.int32)
    for shift in range(0, nbits, bits):
        digits = ((u[order] >> u.dtype.type(shift)) & u.dtype.type((1 << bits) - 1)).astype(
            jnp.int32
        )
        onehot = (
            digits[:, None] == jnp.arange(1 << bits, dtype=jnp.int32)[None, :]
        ).astype(jnp.int32)
        within = jnp.cumsum(onehot, axis=0) - 1  # occurrence index per digit
        totals = onehot.sum(0)
        base = jnp.cumsum(totals) - totals  # exclusive prefix over digit bins
        pos = base[digits] + jnp.take_along_axis(within, digits[:, None], 1)[:, 0]
        order = jnp.zeros_like(order).at[pos].set(order)
    return order


def radix_sort(keys: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    """Stable LSD radix sort of integer keys (paper's radixsort)."""
    return keys[radix_argsort(keys, bits=bits)]
