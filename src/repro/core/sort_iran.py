"""SORT_IRAN_BSP (Fig. 3) — the paper's randomized algorithm.

Inverts classic sample-sort order: *local sort first*, then randomized
oversampling (s = 2ω²·lg n per proc), parallel sample sort, one balanced
routing round, and a final stable multi-way *merge* (not sort). Random
oversampling admits a wider ω range than the deterministic variant, giving
tighter key balance for the same sample size (paper §6.4: observed imbalance
<15% vs the ~20% theoretical bound 1/√(lg n)).

Shares Ph4-Ph6 with SORT_DET_BSP including §5.1.1 duplicate handling.

Pipeline split: only Ph2 (the local sort) is tier-invariant here — the Ph3
sample is drawn from the rng, and the overflow-safe driver folds the rng per
capacity tier so every retry is an *independent* splitter trial (re-routing
with the splitters that just overflowed would fail deterministically on
skewed inputs). Hence :func:`prepare_iran_spmd` carries only the sorted run
and :func:`route_iran_spmd` re-runs Ph3..Ph6 per rung.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import routing, splitters
from .local_sort import local_sort
from .types import PreparedSort, SortConfig


def prepare_iran_spmd(
    x: jnp.ndarray,
    cfg: SortConfig,
    axis: str,
    values: Sequence[jnp.ndarray] = (),
    rng: jax.Array | None = None,  # unused: Ph3 randomness lives in route
) -> PreparedSort:
    """Tier-invariant stage: Ph2 stable local sort (keys + payload)."""
    del rng
    xs, vals = local_sort(x, cfg.local_sort, values)  # Ph2
    return PreparedSort(xs=xs, vals=tuple(vals), splits=None)


def route_iran_spmd(
    prep: PreparedSort,
    cfg: SortConfig,
    axis: str,
    rng: jax.Array | None = None,
) -> Tuple[jnp.ndarray, List[jnp.ndarray], jnp.ndarray, jnp.ndarray]:
    """Tier-dependent stages: Ph3 random splitters, Ph4..Ph6."""
    if rng is None:
        rng = jax.random.key(cfg.seed)
    splits = splitters.splitter_stage(prep.xs, cfg, axis, rng)  # Ph3
    bounds = splitters.searchsorted_tagged(prep.xs, splits, axis)  # Ph4
    return routing.route_and_merge(prep.xs, bounds, cfg, axis, list(prep.vals))


def sort_iran_spmd(
    x: jnp.ndarray,
    cfg: SortConfig,
    axis: str,
    values: Sequence[jnp.ndarray] = (),
    rng: jax.Array | None = None,
) -> Tuple[jnp.ndarray, List[jnp.ndarray], jnp.ndarray, jnp.ndarray]:
    return route_iran_spmd(prepare_iran_spmd(x, cfg, axis, values), cfg, axis, rng)
