"""SORT_IRAN_BSP (Fig. 3) — the paper's randomized algorithm.

Inverts classic sample-sort order: *local sort first*, then randomized
oversampling (s = 2ω²·lg n per proc), parallel sample sort, one balanced
routing round, and a final stable multi-way *merge* (not sort). Random
oversampling admits a wider ω range than the deterministic variant, giving
tighter key balance for the same sample size (paper §6.4: observed imbalance
<15% vs the ~20% theoretical bound 1/√(lg n)).

Shares Ph4-Ph6 with SORT_DET_BSP including §5.1.1 duplicate handling.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import merge as merge_mod
from . import routing, splitters
from .local_sort import local_sort
from .types import SortConfig


def sort_iran_spmd(
    x: jnp.ndarray,
    cfg: SortConfig,
    axis: str,
    values: Sequence[jnp.ndarray] = (),
    rng: jax.Array | None = None,
) -> Tuple[jnp.ndarray, List[jnp.ndarray], jnp.ndarray, jnp.ndarray]:
    if rng is None:
        rng = jax.random.key(cfg.seed)
    xs, vals = local_sort(x, cfg.local_sort, values)  # Ph2
    sample = splitters.random_sample(xs, cfg, axis, rng)  # Ph3
    splits = splitters.splitters_from_sorted_sample(cfg, sample, axis)
    bounds = splitters.searchsorted_tagged(xs, splits, axis)  # Ph4

    if cfg.merge == "tree" and not vals and cfg.routing != "ring":
        rows, rcounts, overflow = routing.recv_rows(xs, bounds, cfg, axis, vals)
        merged, count = merge_mod.merge_tree(rows[0], rcounts)
        merged = merged[: cfg.n_max]
        return merged, [], jnp.minimum(count, cfg.n_max), overflow

    buf, vbufs, count, overflow = routing.route(xs, bounds, cfg, axis, vals)  # Ph5
    merged, mvals = merge_mod.merge_by_sort(buf, vbufs)  # Ph6
    return merged, mvals, count, overflow
