"""SPMD primitive operations (paper §4) mapped onto JAX collectives.

The paper's Lemmas 4.1/4.2 build pipelined t-ary broadcast / parallel-prefix
trees because on a torus a naive broadcast costs g·n·lg p. XLA's collectives
already lower to bandwidth-optimal ICI ring/tree algorithms, so the BSP
*primitives* map to single calls here; their BSP *cost accounting* lives in
``core/bsp.py`` so the model-validation benchmarks can still price them.

All functions run inside an ``axis_name`` region — under ``jax.vmap``
(simulated processors) or ``jax.shard_map`` (real devices) interchangeably.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax


def proc_id(axis: str) -> jnp.ndarray:
    return lax.axis_index(axis)


def nprocs(axis: str) -> int:
    """Static size of the named processor axis.

    ``lax.axis_size`` only exists on newer JAX; on 0.4.x the portable idiom
    is ``psum`` of a unit constant, which both vmap and shard_map constant-
    fold to a Python int at trace time. Collectives that build permutation
    tables prefer an explicitly threaded static ``p`` (see ``ppermute_shift``
    / ``exchange_with``) so they never depend on this trace-time folding.
    """
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis)
    return lax.psum(1, axis)


def shard_map(f, *, mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` (replication checks off).

    ``jax.shard_map(..., check_vma=...)`` on newer JAX; the pinned 0.4.37
    only has ``jax.experimental.shard_map.shard_map(..., check_rep=...)``.
    Every real-device entry point (core/api.py, models/moe.py) goes through
    this wrapper so the collective layer has exactly one version seam.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


def broadcast_from(x: jnp.ndarray, src: int, axis: str) -> jnp.ndarray:
    """Lemma 4.1 analogue: one-superstep broadcast of ``x`` from proc ``src``."""
    contrib = jnp.where(proc_id(axis) == src, x, jnp.zeros_like(x))
    return lax.psum(contrib, axis)


def exclusive_cumsum(x: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    c = jnp.cumsum(x, axis=axis)
    return c - x


def prefix_counts(local_counts: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Lemma 4.2 analogue: p independent parallel prefixes over the proc axis.

    ``local_counts``: (m,) per proc. Returns (m,) exclusive prefix over
    processors (sum of counts on lower-ranked procs), via a masked psum —
    one superstep, h = m words.
    """
    me = proc_id(axis)
    gathered = lax.all_gather(local_counts, axis)  # (p, m)
    p = gathered.shape[0]
    mask = (jnp.arange(p) < me)[:, None]
    return jnp.sum(jnp.where(mask, gathered, 0), axis=0)


def ppermute_shift(x, axis: str, shift: int = 1, *, p: int | None = None):
    """Rotate values around the ring by ``shift`` (one superstep).

    ``p`` is the static axis size; callers thread it from their SortConfig
    (the permutation table must be built at trace time).
    """
    p = nprocs(axis) if p is None else p
    perm = [(i, (i + shift) % p) for i in range(p)]
    if isinstance(x, (tuple, list)):
        return type(x)(lax.ppermute(v, axis, perm) for v in x)
    return lax.ppermute(x, axis, perm)


def exchange_with(x, partner_xor: int, axis: str, *, p: int | None = None):
    """Pairwise exchange with the XOR partner (bitonic compare-split step)."""
    p = nprocs(axis) if p is None else p
    perm = [(i, i ^ partner_xor) for i in range(p)]
    if isinstance(x, (tuple, list)):
        return type(x)(lax.ppermute(v, axis, perm) for v in x)
    return lax.ppermute(x, axis, perm)


def lex_sort(operands: Sequence[jnp.ndarray], num_keys: int) -> tuple:
    """Stable lexicographic sort on multiple operands (§5.1.1 tagged compare)."""
    return lax.sort(tuple(operands), num_keys=num_keys, is_stable=True)


def lex_less(ka, pa, ia, kb, pb, ib):
    """(key, proc, idx) lexicographic strict less-than — §5.1.1's comparator."""
    return (ka < kb) | ((ka == kb) & ((pa < pb) | ((pa == pb) & (ia < ib))))
