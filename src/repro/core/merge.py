"""Ph6 — stable multi-way merging of the routed buckets (Fig. 1 step 12).

Knuth's heap-based p-way merge (the paper's n_max·lg p charge) is scalar and
branchy; the TPU-native counterparts are:

* ``sort``  — one stable re-sort of the capacity buffer. The routed buffer is
  already ordered by (source proc, local idx), so a *stable* key sort yields
  exactly the paper's stable merge semantics; under XLA this is one fused
  O(n_max lg² n_max) sorting network, usually fastest in practice. 1-D
  payloads ride the same network as extra ``lax.sort`` operands (one fused
  multi-operand sort); only multi-dim payloads pay the argsort+gather
  permutation path.
* ``tree``  — lg p rounds of pairwise *rank merges*: each element's output
  position is ``own_idx + rank_in_other`` (searchsorted), stability by taking
  left-run elements first on ties. Work O(n_max·lg n_max·?) per round but
  each round is a fully vectorized gather/scatter — this honours the paper's
  merge-not-sort structure (Robust/Practical Massively Parallel Sorting:
  *merge* the received sorted runs, don't re-sort them). Rank positions are
  computed ONCE on the keys and the scatter applied to every payload array,
  so the tree tail is payload-generic: key-value callers (MoE dispatch,
  segmented SortService composites) skip the compact+re-sort path entirely.

``merge_backend="pallas"`` routes the tree tail through the Pallas kernel
packages (interpret mode on CPU CI, real kernels on TPU): rank computation
through ``kernels/searchsorted`` (masked-count ranks) and key-only pairwise
merges through ``kernels/merge_path`` (merge-path partitioned network merge).
Both are value-identical to the XLA path.

Both tails keep pads (key == sentinel) at the tail by construction.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .types import sentinel_for


def merge_by_sort(
    buf: jnp.ndarray, values: Sequence[jnp.ndarray] = ()
) -> Tuple[jnp.ndarray, List[jnp.ndarray]]:
    """Stable re-sort of the (cap,) buffer (+ payload), pads stay at tail."""
    if not values:
        out = lax.sort((buf,), num_keys=1, is_stable=True)
        return out[0], []
    if all(v.ndim == 1 for v in values):
        # equal-shape 1-D payloads ride the one fused sorting network
        out = lax.sort((buf, *values), num_keys=1, is_stable=True)
        return out[0], list(out[1:])
    # lax.sort wants equal-shape operands along the sort dim; multi-dim
    # payloads are sorted via gathered permutation instead.
    perm = jnp.argsort(buf, stable=True)
    return buf[perm], [v[perm] for v in values]


def _rank(data: jnp.ndarray, queries: jnp.ndarray, side: str, backend: str):
    """searchsorted ranks of ``queries`` in the sorted ``data`` run."""
    if backend == "pallas":
        from repro.kernels.searchsorted import ops as ss_ops  # lazy: optional layer

        return ss_ops.rank_in(data, queries, side=side)
    return jnp.searchsorted(data, queries, side=side)


def _rank_merge_two(
    ka: jnp.ndarray,
    ca: jnp.ndarray,
    kb: jnp.ndarray,
    cb: jnp.ndarray,
    sent: jnp.ndarray,
    va: Sequence[jnp.ndarray] = (),
    vb: Sequence[jnp.ndarray] = (),
    backend: str = "xla",
    w_out: int | None = None,
) -> Tuple[jnp.ndarray, List[jnp.ndarray], jnp.ndarray]:
    """Stable merge of two sorted padded runs -> ((w_out,) run, payloads, count).

    pos_a(i) = i + #{j < cb : b_j < a_i}   (left run first on ties); pos_a
    is strictly increasing over the valid prefix, so the *inverse*
    permutation is itself a binary search: output slot o holds a-element
    ``A(o)-1`` if ``pos_a[A(o)-1] == o`` (where ``A(o) = #{pos_a <= o}``)
    and b-element ``o - A(o)`` otherwise. Everything is ranks + gathers —
    no scatter (whose vmapped lowering is the slow path on every backend we
    measured) and only ONE rank computation per pair. The ``take``
    permutation is computed once on the keys; every payload array rides the
    same gather, which is what makes the tree tail payload-generic.

    ``w_out`` (default 2w) truncates the output run: a caller that knows a
    global bound on the VALID total (the routing receive bound ``n_max``)
    caps every round's width at it, so only pad slots are dropped and the
    per-round work tracks the valid volume, not the padded capacity.
    """
    wa, wb = ka.shape[0], kb.shape[0]
    w2 = wa + wb
    w_out = w2 if w_out is None else min(w_out, w2)
    if wa == 0 or wb == 0:
        # degenerate span (Δ=0 folds, one-run-empty merge-tree lanes): the
        # general path would gather from a width-0 ``pos_a``, which XLA
        # rejects — pass the populated run through, re-masking pads so a
        # truncated w_out still leaves only valid keys followed by sentinel
        ks, cs, vs = (ka, ca, va) if wb == 0 else (kb, cb, vb)
        o = jnp.arange(w_out)
        valid = o < cs
        out = jnp.where(valid, ks[:w_out], sent)
        vout = []
        for v in vs:
            m = valid.reshape((w_out,) + (1,) * (v.ndim - 1))
            vout.append(jnp.where(m, v[:w_out], jnp.zeros((), v.dtype)))
        return out, vout, jnp.minimum(cs, w_out)
    ra = jnp.minimum(_rank(kb, ka, "left", backend), cb)
    ia = jnp.arange(wa)
    # invalid (padded) a-entries park past every output slot, keeping pos_a
    # strictly increasing so the inverse search below stays well-defined
    pos_a = jnp.where(ia < ca, ia + ra, w2 + ia)
    o = jnp.arange(w_out)
    A = _rank(pos_a, o, "right", backend)  # a-elements at output slots <= o
    from_a = jnp.where(A > 0, pos_a[jnp.maximum(A - 1, 0)] == o, False)
    take = jnp.where(
        from_a, jnp.maximum(A - 1, 0), jnp.minimum(wa + o - A, w2 - 1)
    )
    valid = o < ca + cb
    out = jnp.where(valid, jnp.concatenate([ka, kb])[take], sent)
    vout = []
    for a_v, b_v in zip(va, vb):
        m = valid.reshape((w_out,) + (1,) * (a_v.ndim - 1))
        cat = jnp.concatenate([a_v, b_v])
        vout.append(jnp.where(m, cat[take], jnp.zeros((), a_v.dtype)))
    return out, vout, jnp.minimum(ca + cb, w_out)


def merge_tree(
    runs: jnp.ndarray,
    counts: jnp.ndarray,
    values: Sequence[jnp.ndarray] = (),
    backend: str = "xla",
    cap: int | None = None,
) -> Tuple[jnp.ndarray, List[jnp.ndarray], jnp.ndarray]:
    """Merge (m, w) sorted padded runs (m a power of two) into one run.

    lg m rounds of vmapped pairwise rank merges; payload arrays (m, w, ...)
    follow the key positions through every round. Returns
    ``((min(m·w, cap),) run, [payloads], count)``. ``cap`` is the caller's
    bound on the total VALID element count (the routing receive bound
    ``n_max``): every round's output width is clipped to it, so the padded
    capacity of oversized tiers (``exact``'s p·n/p send layout) never
    inflates the merge work — only pad slots are ever dropped.
    ``backend="pallas"`` takes the kernel substrate: key-only pairs go
    through the merge-path partitioned network merge, key-value pairs
    through the masked-count rank kernel.
    """
    sent = sentinel_for(runs.dtype)
    m = runs.shape[0]
    assert m & (m - 1) == 0, "run count must be a power of two"
    vals = list(values)
    while m > 1:
        a, b = runs[0::2], runs[1::2]
        ca, cb = counts[0::2], counts[1::2]
        if backend == "pallas" and not vals:
            from repro.kernels.merge_path import ops as mp_ops  # lazy

            merged = mp_ops.merge_partitioned(a, b)
            if cap is not None and merged.shape[1] > cap:
                merged = merged[:, :cap]
            runs, counts = merged, jnp.minimum(ca + cb, merged.shape[1])
        else:
            w_out = None if cap is None else min(cap, 2 * runs.shape[1])
            va = tuple(v[0::2] for v in vals)
            vb = tuple(v[1::2] for v in vals)
            runs, vals, counts = jax.vmap(
                lambda ka, ca_, kb, cb_, va_, vb_: _rank_merge_two(
                    ka, ca_, kb, cb_, sent, va_, vb_, backend=backend,
                    w_out=w_out,
                )
            )(a, ca, b, cb, va, vb)
        m //= 2
    return runs[0], [v[0] for v in vals], counts[0]
