"""Ph6 — stable multi-way merging of the routed buckets (Fig. 1 step 12).

Knuth's heap-based p-way merge (the paper's n_max·lg p charge) is scalar and
branchy; the TPU-native counterparts are:

* ``sort``  — one stable re-sort of the capacity buffer. The routed buffer is
  already ordered by (source proc, local idx), so a *stable* key sort yields
  exactly the paper's stable merge semantics; under XLA this is one fused
  O(n_max lg² n_max) sorting network, usually fastest in practice.
* ``tree``  — lg p rounds of pairwise *rank merges*: each element's output
  position is ``own_idx + rank_in_other`` (searchsorted), stability by taking
  left-run elements first on ties. Work O(n_max·lg n_max·?) per round but
  each round is a fully vectorized gather/scatter — this honours the paper's
  merge-not-sort structure and is exposed for §Perf comparison.

Both keep pads (key == sentinel) at the tail by construction.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .types import sentinel_for


def merge_by_sort(
    buf: jnp.ndarray, values: Sequence[jnp.ndarray] = ()
) -> Tuple[jnp.ndarray, List[jnp.ndarray]]:
    """Stable re-sort of the (cap,) buffer (+ payload), pads stay at tail."""
    if not values:
        out = lax.sort((buf,), num_keys=1, is_stable=True)
        return out[0], []
    flat_vals = []
    shapes = []
    for v in values:
        shapes.append(v.shape)
        flat_vals.append(v.reshape(v.shape[0], -1) if v.ndim > 1 else v)
    # lax.sort wants equal-shape operands along the sort dim; multi-dim
    # payloads are sorted via gathered permutation instead.
    perm = jnp.argsort(buf, stable=True)
    out_vals = [v[perm].reshape(s) for v, s in zip(values, shapes)]
    return buf[perm], out_vals


def _rank_merge_two(
    ka: jnp.ndarray,
    ca: jnp.ndarray,
    kb: jnp.ndarray,
    cb: jnp.ndarray,
    sent: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stable merge of two sorted padded runs -> (2w,) run + count.

    pos_a(i) = i + #{j < cb : b_j <  a_i}   (left run first on ties)
    pos_b(j) = j + #{i < ca : a_i <= b_j}
    Invalid (padded) entries are routed to unique tail slots.
    """
    wa, wb = ka.shape[0], kb.shape[0]
    ra = jnp.minimum(jnp.searchsorted(kb, ka, side="left"), cb)
    rb = jnp.minimum(jnp.searchsorted(ka, kb, side="right"), ca)
    ia, ib = jnp.arange(wa), jnp.arange(wb)
    pos_a = jnp.where(ia < ca, ia + ra, ca + cb + ia)
    pos_b = jnp.where(ib < cb, ib + rb, ca + cb + wa + ib)
    out = jnp.full((wa + wb,), sent, ka.dtype)
    out = out.at[jnp.clip(pos_a, 0, wa + wb - 1)].set(
        jnp.where(ia < ca, ka, sent), mode="drop"
    )
    out = out.at[jnp.clip(pos_b, 0, wa + wb - 1)].set(
        jnp.where(ib < cb, kb, sent), mode="drop"
    )
    return out, ca + cb


def merge_tree(
    runs: jnp.ndarray, counts: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Merge (m, w) sorted padded runs (m a power of two) into one run.

    lg m rounds of vmapped pairwise rank merges; returns ((m·w,), count).
    """
    sent = sentinel_for(runs.dtype)
    m = runs.shape[0]
    assert m & (m - 1) == 0, "run count must be a power of two"
    while m > 1:
        a, b = runs[0::2], runs[1::2]
        ca, cb = counts[0::2], counts[1::2]
        runs, counts = jax.vmap(
            lambda ka, ca, kb, cb: _rank_merge_two(ka, ca, kb, cb, sent)
        )(a, ca, b, cb)
        m //= 2
    return runs[0], counts[0]
