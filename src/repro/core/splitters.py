"""Sampling, parallel sample-sort and splitter machinery (Fig. 1 steps 4-9).

Implements:

* deterministic *regular oversampling* — rp-1 evenly spaced keys + local max
  (paper Fig. 1 step 4, Lemma 5.1 padding analysis);
* randomized oversampling — s uniform positions per proc (Fig. 3 step 4);
* transparent duplicate tagging (§5.1.1): ONLY sample/splitter records carry
  explicit ``(processor, index)`` tags; local keys use their implicit
  position, so memory/comm overhead is o(n);
* parallel sample sort: ``gather`` (all_gather + fused stable lexicographic
  sort — optimal when p·s fits one core) or ``bitonic`` (distributed Batcher
  compare-split over the proc axis — the paper's scheme);
* ``searchsorted_tagged`` — vectorized binary search of tagged splitters into
  the local sorted run under the (key, proc, idx) order; monotone because the
  local run is sorted and local indices ascend.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import primitives as prim
from .types import SortConfig


Tagged = Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]  # (keys, proc, idx)


def regular_sample(x_sorted: jnp.ndarray, cfg: SortConfig, axis: str) -> Tagged:
    """Deterministic regular oversampling: s evenly spaced keys (+ local max).

    Pads the local run to ``s·x`` with the max key (Lemma 5.1 proof) and takes
    segment right-boundaries; the tag index of a padded slot saturates at
    ``n_p - 1`` which reproduces "append the maximum" transparently.
    """
    n_p = x_sorted.shape[0]
    s, x = cfg.s, cfg.segment_len
    pos = (jnp.arange(1, s + 1) * x) - 1  # right boundary of each segment
    idx = jnp.minimum(pos, n_p - 1).astype(jnp.int32)
    keys = x_sorted[idx]
    me = prim.proc_id(axis).astype(jnp.int32)
    return keys, jnp.full((s,), me, jnp.int32), idx


def random_sample(
    x_sorted: jnp.ndarray, cfg: SortConfig, axis: str, rng: jax.Array
) -> Tagged:
    """Randomized oversampling: s uniform local positions, tagged, locally
    sorted (the run is sorted, so sorting the positions sorts the sample)."""
    n_p = x_sorted.shape[0]
    me = prim.proc_id(axis)
    k = jax.random.fold_in(rng, me)
    idx = jnp.sort(jax.random.randint(k, (cfg.s,), 0, n_p)).astype(jnp.int32)
    keys = x_sorted[idx]
    return keys, jnp.full((cfg.s,), me, jnp.int32), idx


# --------------------------------------------------------------- sample sort
def _merge_split_tagged(a: Tagged, b: Tagged, keep_low: jnp.ndarray) -> Tagged:
    """Bitonic compare-split: merge two sorted tagged runs, keep one half."""
    m = a[0].shape[0]
    cat = tuple(jnp.concatenate([ai, bi]) for ai, bi in zip(a, b))
    sk, sp, si = prim.lex_sort(cat, num_keys=3)
    low = (sk[:m], sp[:m], si[:m])
    high = (sk[m:], sp[m:], si[m:])
    return tuple(jnp.where(keep_low, lo, hi) for lo, hi in zip(low, high))


def sample_sort_bitonic(sample: Tagged, p: int, axis: str) -> Tagged:
    """Distributed Batcher bitonic sort of the tagged sample over the proc
    axis (Fig. 1 step 5 / [BSI]); local runs must already be sorted.

    lg p · (lg p + 1)/2 compare-split supersteps; each is one ppermute of the
    s-word sample plus an s·lg s local merge — matching the paper's
    2s(lg²p+lg p)/2 computation and (lg²p+lg p)(L+gs)/2 communication charge.
    """
    lgp = int(math.log2(p))
    me = prim.proc_id(axis)
    cur = sample
    for i in range(lgp):
        for j in range(i, -1, -1):
            partner = 1 << j
            other = prim.exchange_with(cur, partner, axis, p=p)
            up = ((me >> (i + 1)) & 1) == 0
            lower_half = ((me >> j) & 1) == 0
            keep_low = jnp.equal(up, lower_half)
            cur = _merge_split_tagged(cur, other, keep_low)
    return cur


def sample_sort_gather(sample: Tagged, axis: str) -> Tagged:
    """All-gather the o(n) sample and sort it with one fused stable
    lexicographic sort — the sequential-sample-sort choice the paper blesses
    for architectures where p·s fits one node (§5, final remark)."""
    gathered = tuple(lax.all_gather(a, axis).reshape(-1) for a in sample)
    return prim.lex_sort(gathered, num_keys=3)


def select_splitters(cfg: SortConfig, sample: Tagged, axis: str, mode: str) -> Tagged:
    """Fig. 1 step 6: p-1 evenly spaced splitters from the sorted sample.

    ``gather`` mode: the sorted sample is replicated; take positions i·s-1.
    ``bitonic`` mode: splitter i is the *last* sample key held by proc i-1;
    one all_gather of a single record per proc broadcasts all splitters
    (Fig. 1 step 7's broadcast, one superstep of h = O(p)).
    """
    p, s = cfg.p, cfg.s
    if mode == "gather":
        pos = jnp.arange(1, p) * s - 1
        return tuple(a[pos] for a in sample)
    # bitonic mode: local run of s sorted records per proc.
    last = tuple(a[-1] for a in sample)
    allp = tuple(lax.all_gather(a, axis) for a in last)  # (p,) each
    return tuple(a[:-1] for a in allp)


# ---------------------------------------------------- tagged binary search
def searchsorted_tagged(
    x_sorted: jnp.ndarray,
    splitters: Tagged,
    axis: str,
) -> jnp.ndarray:
    """Partition boundaries of the local run induced by tagged splitters.

    Returns ``b`` of shape (p+1,) with b[0]=0, b[p]=n_p; bucket i is
    x[b[i]:b[i+1]]. Local element j on proc ``me`` belongs left of splitter
    (ks, ps, is) iff (x[j], me, j) < (ks, ps, is) lexicographically — the
    §5.1.1 comparator. Count via vectorized binary search (monotone predicate
    since the run is sorted and j ascends), ⌈lg(n_p+1)⌉ steps.
    """
    n_p = x_sorted.shape[0]
    sk, sp, si = splitters
    me = prim.proc_id(axis).astype(jnp.int32)
    nq = sk.shape[0]
    lo = jnp.zeros((nq,), jnp.int32)
    hi = jnp.full((nq,), n_p, jnp.int32)
    steps = max(1, math.ceil(math.log2(n_p + 1)))

    def body(_, lohi):
        lo, hi = lohi
        active = lo < hi  # converged lanes must not move (mid==hi is OOB)
        mid = (lo + hi) // 2
        xm = x_sorted[jnp.clip(mid, 0, n_p - 1)]
        less = prim.lex_less(xm, me, mid, sk, sp, si)
        lo = jnp.where(active & less, mid + 1, lo)
        hi = jnp.where(active & ~less, mid, hi)
        return lo, hi

    lo, hi = lax.fori_loop(0, steps, body, (lo, hi))
    b = jnp.concatenate([jnp.zeros((1,), jnp.int32), lo, jnp.full((1,), n_p, jnp.int32)])
    return b


def splitters_from_sorted_sample(
    cfg: SortConfig, sample: Tagged, axis: str
) -> Tagged:
    """Convenience: run the configured sample sort + splitter selection."""
    if cfg.sample_sort == "gather":
        sorted_sample = sample_sort_gather(sample, axis)
        return select_splitters(cfg, sorted_sample, axis, "gather")
    sorted_sample = sample_sort_bitonic(sample, cfg.p, axis)
    return select_splitters(cfg, sorted_sample, axis, "bitonic")


def splitter_stage(
    x_sorted: jnp.ndarray, cfg: SortConfig, axis: str, rng: jax.Array | None = None
) -> Tagged:
    """Full Ph3 for ``cfg.algorithm``: sampling + sample sort + selection.

    The single splitter pipeline shared by the sort bodies, the resumable
    route stage and the phase-decomposed benchmark callables. ``det`` is
    deterministic (and hence capacity-tier-invariant — it runs in the
    prepare stage); ``iran`` draws its sample from ``rng``, so the route
    stage re-enters here with a per-tier folded key.
    """
    if cfg.algorithm == "det":
        sample = regular_sample(x_sorted, cfg, axis)
    else:
        sample = random_sample(x_sorted, cfg, axis, rng)
    return splitters_from_sorted_sample(cfg, sample, axis)
