"""SORT_RAN_BSP (Fig. 2) — classic one-round randomized sample sort.

The traditional pattern the paper *departs from*: sample & splitter-select
first, route, then local sort. Kept as the comparison baseline (the paper
implements IRAN instead, §5.2: step-9 set formation costs D·n/p with a large
constant, and sample sorting is sequential on processor 0).

Step 9's "integer sort by destination" is realized as a stable argsort of the
destination ids — exactly the set-formation operation the paper prices at
D·n/p.

Pipeline split: *nothing* here is tier-invariant — the sample is drawn from
the raw run with the per-tier rng, and the full local sort happens after
routing (step 12). :func:`prepare_ran_spmd` therefore just wraps the input;
escalation still profits from the shared executor (compiled-callable reuse)
and from the uniform prepare/route execution model.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import merge as merge_mod
from . import routing
from .types import PreparedSort, SortConfig


def prepare_ran_spmd(
    x: jnp.ndarray,
    cfg: SortConfig,
    axis: str,
    values: Sequence[jnp.ndarray] = (),
    rng: jax.Array | None = None,
) -> PreparedSort:
    """No tier-invariant work: classic sample sort local-sorts *last*."""
    del rng
    return PreparedSort(xs=x, vals=tuple(values), splits=None)


def route_ran_spmd(
    prep: PreparedSort,
    cfg: SortConfig,
    axis: str,
    rng: jax.Array | None = None,
) -> Tuple[jnp.ndarray, List[jnp.ndarray], jnp.ndarray, jnp.ndarray]:
    if rng is None:
        rng = jax.random.key(cfg.seed)
    x, values = prep.xs, list(prep.vals)
    n_p = x.shape[0]
    p = cfg.p
    me = lax.axis_index(axis)

    # Fig. 2 steps 2-5: random sample, gathered and sorted "at processor 0"
    # (deterministically replicated here — same result, one superstep).
    k = jax.random.fold_in(rng, me)
    pos = jax.random.randint(k, (cfg.s,), 0, n_p)
    local_sample = x[pos]
    gathered = lax.all_gather(local_sample, axis).reshape(-1)
    ybar = jnp.sort(gathered)
    # Step 6: p-1 evenly spaced splitters.
    splits = ybar[jnp.arange(1, p) * cfg.s - 1]

    # Step 9: destination of every (unsorted) key + set formation (stable
    # integer sort by destination — the D·n/p operation).
    dest = jnp.searchsorted(splits, x, side="right").astype(jnp.int32)
    order = jnp.argsort(dest, stable=True)
    xg = x[order]
    vals = [v[order] for v in values]
    bounds = jnp.searchsorted(
        dest[order], jnp.arange(p + 1, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)

    # Steps 10-11: routing; Step 12: full local sort (not a merge).
    buf, vbufs, count, overflow = routing.route(xg, bounds, cfg, axis, vals)
    merged, mvals = merge_mod.merge_by_sort(buf, vbufs)
    return merged, mvals, count, overflow


def sort_ran_spmd(
    x: jnp.ndarray,
    cfg: SortConfig,
    axis: str,
    values: Sequence[jnp.ndarray] = (),
    rng: jax.Array | None = None,
) -> Tuple[jnp.ndarray, List[jnp.ndarray], jnp.ndarray, jnp.ndarray]:
    return route_ran_spmd(prepare_ran_spmd(x, cfg, axis, values), cfg, axis, rng)
