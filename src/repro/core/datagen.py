"""Sorting benchmark input distributions (paper §6.3, after [39,40,41]).

Seven generators producing the (p, n_per_proc) int32 global layout. The
paper's [Z]/[RD] sets are omitted by the paper's own choice (§6.3: results
match [DD]/[WR] and are never worse than [U]).

Service-workload additions beyond the paper's sets (the sort-service
benchmark sorts *many small requests*, a regime §6.3 never exercises):

* ``zipf`` / :func:`zipf_keys` — duplicate-heavy Zipf-distributed keys
  (heavy head: a handful of values covers most of the mass — the §5.1.1
  duplicate-tagging stress in its naturally occurring form);
* :func:`zipf_sizes` — skewed *request-size* mix for a batch of concurrent
  sort requests (sizes ∝ rank^-alpha: a few big requests, a long tail of
  tiny ones — the fusion win case);
* ``dense_int`` / :func:`dense_int` — small-domain integer keys
  (expert-id-like), the count-then-distribute ``route="radix"`` flagship.

INT_MAX = 2^31 (values in [0, 2^31 - 1], 32-bit signed — paper's setting).
"""
from __future__ import annotations

import numpy as np

INT_MAX = 2**31


def _rngs(p: int, seed: int):
    # paper: processor i's seed is 21 + 1001*i
    return [np.random.default_rng(seed + 21 + 1001 * i) for i in range(p)]


def uniform(p: int, n_p: int, seed: int = 0) -> np.ndarray:
    """[U] — uniform in [0, INT_MAX)."""
    return np.stack([r.integers(0, INT_MAX, n_p, dtype=np.int64) for r in _rngs(p, seed)]).astype(np.int32)


def gaussian(p: int, n_p: int, seed: int = 0) -> np.ndarray:
    """[G] — mean of four uniform draws."""
    out = []
    for r in _rngs(p, seed):
        out.append(sum(r.integers(0, INT_MAX, n_p, dtype=np.int64) for _ in range(4)) // 4)
    return np.stack(out).astype(np.int32)


def bucket_sorted(p: int, n_p: int, seed: int = 0) -> np.ndarray:
    """[B] — per proc, p equal buckets; bucket i uniform in its 1/p range."""
    w = INT_MAX // p
    out = []
    for r in _rngs(p, seed):
        per = n_p // p
        parts = [
            r.integers(i * w, (i + 1) * w, per, dtype=np.int64) for i in range(p)
        ]
        rest = n_p - per * p
        if rest:
            parts.append(r.integers(0, INT_MAX, rest, dtype=np.int64))
        out.append(np.concatenate(parts))
    return np.stack(out).astype(np.int32)


def g_group(p: int, n_p: int, seed: int = 0, g: int = 2) -> np.ndarray:
    """[g-G] — procs in groups of g; bucket ranges rotated by jg + p/2 + i."""
    w = INT_MAX // p
    out = []
    rngs = _rngs(p, seed)
    for k in range(p):
        j = k // g
        per = n_p // g
        parts = []
        for i in range(g):
            lo = ((j * g + p // 2 + i) % p) * w
            parts.append(rngs[k].integers(lo, lo + w, per, dtype=np.int64))
        rest = n_p - per * g
        if rest:
            parts.append(rngs[k].integers(0, INT_MAX, rest, dtype=np.int64))
        out.append(np.concatenate(parts))
    return np.stack(out).astype(np.int32)


def staggered(p: int, n_p: int, seed: int = 0) -> np.ndarray:
    """[S] — proc i<p/2 in range (2i+1)/p; proc i>=p/2 in range (i-p/2)/p."""
    w = INT_MAX // p
    out = []
    rngs = _rngs(p, seed)
    for i in range(p):
        lo = ((2 * i + 1) * w) if i < p // 2 else ((i - p // 2) * w)
        out.append(rngs[i].integers(lo, lo + w, n_p, dtype=np.int64))
    return np.stack(out).astype(np.int32)


def deterministic_duplicates(p: int, n_p: int, seed: int = 0) -> np.ndarray:
    """[DD] — duplicates-heavy set after [39,40]: the first p/2 procs hold
    lg n everywhere, the next p/4 procs lg(n/2), …; the last proc's run is
    itself halved into runs of lg(n/p), lg(n/(2p)), …"""
    n = p * n_p
    lg = int(np.log2(max(n, 2)))
    x = np.zeros((p, n_p), np.int32)
    start, size, v = 0, max(p // 2, 1), lg
    while start < p - 1 and size >= 1:
        x[start : min(start + size, p - 1)] = v
        start += size
        size = max(size // 2, 1)
        v = max(v - 1, 0)
        if size == 1 and start >= p - 1:
            break
    # last processor: halving runs
    off, run, v = 0, max(n_p // 2, 1), int(np.log2(max(n // p, 2)))
    while off < n_p:
        x[p - 1, off : off + run] = v
        off += run
        run = max(run // 2, 1)
        v = max(v - 1, 0)
    return x


def worst_regular(p: int, n_p: int, seed: int = 0) -> np.ndarray:
    """[WR] — worst case for plain regular sampling [39]: the sorted sequence
    dealt cyclically, so every proc's evenly spaced sample is (nearly)
    identical and un-oversampled splitters maximally misbalance buckets."""
    n = p * n_p
    scale = max(INT_MAX // max(n, 1), 1)
    j = np.arange(n_p, dtype=np.int64)[None, :]
    i = np.arange(p, dtype=np.int64)[:, None]
    return ((j * p + i) * scale).astype(np.int32)


def zipf_keys(p: int, n_p: int, seed: int = 0, alpha: float = 1.5) -> np.ndarray:
    """[zipf] — duplicate-heavy keys, frequency of value v ∝ v^-alpha.

    The head values repeat across every processor (unlike [DD]'s per-proc
    blocks), so both the splitter tagging and the routing see naturally
    colliding duplicates.
    """
    return np.stack(
        [np.minimum(r.zipf(alpha, n_p), INT_MAX - 1) for r in _rngs(p, seed)]
    ).astype(np.int32)


def dense_int(p: int, n_p: int, seed: int = 0, domain: int = 64) -> np.ndarray:
    """[dense_int] — small-domain integer keys, uniform in [0, domain).

    The expert-id-like workload of MoE dispatch and segment tags: every key
    is drawn from a tiny dense domain, so *all* high bits agree and
    duplicates dominate (each value repeats ~n/domain times). Sampling-based
    splitter selection pays its full Ph3 cost to learn a range a single
    counting pass reads off directly — the flagship case for
    ``route="radix"``.
    """
    return np.stack(
        [r.integers(0, domain, n_p, dtype=np.int64) for r in _rngs(p, seed)]
    ).astype(np.int32)


NEAR_SORTED_PATTERNS = ("appended", "scattered", "rotated")


def near_sorted(
    n: int, delta_frac: float, pattern: str = "appended", seed: int = 0
) -> np.ndarray:
    """1-D near-sorted stream: sorted uniform base with Δ = ``delta_frac``·n
    keys out of place. The delta subsystem's workload generator (bench table
    ``delta`` + tests) — three disruption families:

    * ``appended`` — a sorted run of n−Δ keys with Δ fresh uniform draws
      appended unsorted (the arrival-stream / leaderboard-refill shape);
    * ``scattered`` — a fully sorted run with Δ positions overwritten by
      fresh uniform draws in place (the update-heavy shape — planted values
      may be arbitrarily far from their sorted position);
    * ``rotated`` — the leading Δ-block moved to the tail (a block rotation:
      locally sorted everywhere but globally displaced).

    ``delta_frac=0`` returns a fully sorted stream for every pattern.
    """
    n = int(n)
    d = min(n, int(round(n * float(delta_frac))))
    rng = np.random.default_rng(seed + 21)
    if pattern == "appended":
        base = np.sort(rng.integers(0, INT_MAX, n - d, dtype=np.int64))
        tail = rng.integers(0, INT_MAX, d, dtype=np.int64)
        out = np.concatenate([base, tail])
    elif pattern == "scattered":
        out = np.sort(rng.integers(0, INT_MAX, n, dtype=np.int64))
        if d:
            idx = rng.choice(n, size=d, replace=False)
            out[idx] = rng.integers(0, INT_MAX, d, dtype=np.int64)
    elif pattern == "rotated":
        base = np.sort(rng.integers(0, INT_MAX, n, dtype=np.int64))
        out = np.concatenate([base[d:], base[:d]])
    else:
        raise ValueError(
            f"unknown near-sorted pattern {pattern!r} "
            f"(use one of {NEAR_SORTED_PATTERNS})"
        )
    return out.astype(np.int32)


def zipf_sizes(
    n_requests: int, total: int, seed: int = 0, alpha: float = 1.2
) -> np.ndarray:
    """Skewed request-size mix: size of rank-r request ∝ r^-alpha, shuffled.

    Deterministic in ``seed``; sizes are ≥ 1 and sum exactly to ``total``
    (the residual lands on the largest request). Models the serving-side
    regime of a few big sorts amid a long tail of tiny ones.
    """
    assert total >= n_requests >= 1
    w = 1.0 / np.arange(1, n_requests + 1, dtype=np.float64) ** alpha
    sizes = np.maximum((w / w.sum() * total).astype(np.int64), 1)
    # clamping the tail to >= 1 can overshoot ``total`` (when total is close
    # to n_requests most floor-shares are 0): shave the excess off the
    # largest entries, never below 1 — total >= n_requests guarantees the
    # shave terminates. Any rounding shortfall lands on the largest request.
    excess = int(sizes.sum()) - total
    order = np.argsort(-sizes)
    i = 0
    while excess > 0:
        j = order[i % n_requests]
        take = min(excess, int(sizes[j]) - 1)
        sizes[j] -= take
        excess -= take
        i += 1
    if excess < 0:
        sizes[order[0]] -= excess
    assert sizes.min() >= 1 and sizes.sum() == total
    rng = np.random.default_rng(seed + 21)
    rng.shuffle(sizes)
    return sizes


DISTRIBUTIONS = {
    "U": uniform,
    "G": gaussian,
    "B": bucket_sorted,
    "2-G": g_group,
    "S": staggered,
    "DD": deterministic_duplicates,
    "WR": worst_regular,
    "zipf": zipf_keys,
    "dense_int": dense_int,
}


def generate(name: str, p: int, n_p: int, seed: int = 0) -> np.ndarray:
    return DISTRIBUTIONS[name](p, n_p, seed)
