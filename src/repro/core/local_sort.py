"""Ph2 — local sequential sort, dispatching on the configured method.

``lax``    — XLA's stable comparison sort (the [·SQ]/quicksort role).
``radix``  — linear-work counting-split (the [·SR]/radixsort role).
``bitonic``— Pallas in-VMEM sorting network (TPU hot path; interpret mode on
             CPU). Falls back to ``lax`` when the kernel does not support the
             shape/dtype.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax.numpy as jnp
from jax import lax

from .radix import radix_argsort


def local_sort(
    x: jnp.ndarray, method: str = "lax", values: Sequence[jnp.ndarray] = ()
) -> Tuple[jnp.ndarray, List[jnp.ndarray]]:
    """Stable local sort of (n_p,) keys, carrying optional payload arrays."""
    if method == "radix" and jnp.issubdtype(x.dtype, jnp.integer):
        order = radix_argsort(x)
        return x[order], [v[order] for v in values]
    if method == "bitonic":
        from repro.kernels.bitonic import ops as bitonic_ops  # lazy: optional layer

        if not values and bitonic_ops.supports(x):
            return bitonic_ops.sort(x), []
        # key-value / unsupported shapes: fall through to lax
    if not values:
        (out,) = lax.sort((x,), num_keys=1, is_stable=True)
        return out, []
    if all(v.ndim == 1 for v in values):
        # 1-D payloads ride the one fused sorting network (stable, so the
        # permutation is identical to the argsort+gather path)
        out = lax.sort((x, *values), num_keys=1, is_stable=True)
        return out[0], list(out[1:])
    perm = jnp.argsort(x, stable=True)
    return x[perm], [v[perm] for v in values]
