"""BSP cost model — the paper's analytical machinery (§1.1, Props 5.1/5.3).

A BSP machine is ``(p, L, g)``: p processors, L = synchronization latency in
basic-op units (or seconds here), g = per-word routing cost. A superstep with
local work x and h-relation h costs ``max(L, x + g·h)``.

The model below prices each phase of SORT_DET_BSP / SORT_IRAN_BSP exactly as
the paper's analysis does (charging n·lg n for sorting n keys, n·lg q for
q-way merging, ⌈lg n⌉ per binary search), and produces the paper's headline
quantities:

* ``pi``  (π)  = p·C_A / C_A*      — computational efficiency ratio,
* ``mu``  (μ)  = p·M_A / C_A*      — communication impact ratio,
* speedup = p/(π+μ), parallel efficiency = 1/(π+μ).

``predict_*`` return both op counts and seconds given a measured
time-per-comparison, enabling the paper's predicted-vs-observed methodology
(its §6 uses T3D constants; our benchmarks measure CPU constants).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from .types import SortConfig, log2


#: Cray T3D BSP parameters from the paper (§6): p -> (L seconds, g sec/word).
CRAY_T3D = {
    16: (130e-6, 0.21e-6),
    32: (175e-6, 0.26e-6),
    64: (364e-6, 0.28e-6),
    128: (762e-6, 0.34e-6),
}


@dataclasses.dataclass(frozen=True)
class BSPMachine:
    p: int
    L: float  # seconds per synchronization
    g: float  # seconds per 32-bit word of h-relation
    t_comp: float = 1.0 / 7e6  # seconds per comparison (paper: 7 cmp/us on T3D)

    def superstep(self, work_ops: float, h_words: float) -> float:
        return max(self.L, work_ops * self.t_comp + self.g * h_words)


@dataclasses.dataclass
class PhaseCost:
    comp_ops: float = 0.0  # comparisons / basic ops (max over procs)
    h_words: float = 0.0  # max words sent or received by any proc
    supersteps: int = 0

    def seconds(self, m: BSPMachine) -> float:
        base = self.comp_ops * m.t_comp + m.g * self.h_words
        return max(base, m.L * max(self.supersteps, 1)) if (
            self.h_words or self.supersteps
        ) else base


def _lg(x: float) -> float:
    return log2(x)


def phase_costs_det(cfg: SortConfig) -> Dict[str, PhaseCost]:
    """Per-phase BSP cost of SORT_DET_BSP (Prop. 5.1), phases Ph1-Ph7."""
    p, np_, s = cfg.p, cfg.n_per_proc, cfg.s
    n_max = cfg.n_max
    lgp = _lg(p)
    costs = {
        "Init": PhaseCost(comp_ops=p),
        # Ph2 — local sort of n/p keys: (n/p)·lg(n/p)
        "SeqSort": PhaseCost(comp_ops=np_ * _lg(np_)),
        # Ph3 — sample selection O(s) + parallel bitonic sample-sort:
        # 2s(lg^2 p + lg p)/2 comp, (lg^2 p + lg p)/2 supersteps of g·s each.
        "Sampling": PhaseCost(
            comp_ops=s + s * (lgp**2 + lgp),
            h_words=s * (lgp**2 + lgp) / 2.0,
            supersteps=int((lgp**2 + lgp) / 2) + 1,
        ),
        # Ph4 — splitter broadcast + partition (binary search of p-1 splitters
        # into the local run) + p parallel prefixes.
        "Prefix": PhaseCost(
            comp_ops=p * _lg(np_) + 2 * p * lgp,
            h_words=2.0 * p,
            supersteps=2 + int(lgp),
        ),
        # Ph5 — the single key-routing h-relation: h = n_max.
        "Routing": PhaseCost(comp_ops=0.0, h_words=float(n_max), supersteps=1),
        # Ph6 — p-way merge of n_max keys: n_max·lg p.
        "Merging": PhaseCost(comp_ops=n_max * lgp),
        "Termination": PhaseCost(comp_ops=1.0),
    }
    return costs


def phase_costs_iran(cfg: SortConfig) -> Dict[str, PhaseCost]:
    """Per-phase BSP cost of SORT_IRAN_BSP (Prop. 5.3)."""
    p, np_, s = cfg.p, cfg.n_per_proc, cfg.s
    n_max = cfg.n_max
    lgp = _lg(p)
    costs = phase_costs_det(cfg)
    # Randomized sampling: select s random keys O(s); parallel bitonic sort of
    # (p, s) sample: 2·s·lg n-ish terms per Prop 5.3: 2 ω² lg n lg² p comp.
    costs["Sampling"] = PhaseCost(
        comp_ops=s + s * (lgp**2 + lgp),
        h_words=s * (lgp**2 + lgp) / 2.0,
        supersteps=int((lgp**2 + lgp) / 2) + 1,
    )
    costs["Merging"] = PhaseCost(comp_ops=n_max * lgp)
    return costs


def phase_costs_ran(cfg: SortConfig) -> Dict[str, PhaseCost]:
    """Per-phase BSP cost of classic SORT_RAN_BSP (Prop. 5.2).

    Differences from IRAN: sample is shipped to processor 0 and sorted there
    (s·p·lg(s·p) on one proc), partition is a binary search of *keys into
    splitters* ((n/p)(lg p + 1)), and Ph6 is a full local sort (not merge).
    """
    p, np_, s = cfg.p, cfg.n_per_proc, cfg.s
    n_max = cfg.n_max
    costs = {
        "Init": PhaseCost(comp_ops=p),
        "SeqSort": PhaseCost(comp_ops=0.0),  # no up-front local sort
        "Sampling": PhaseCost(
            comp_ops=s * p * _lg(s * p) + p,
            h_words=float(s * p),
            supersteps=2,
        ),
        "Prefix": PhaseCost(comp_ops=np_ * (_lg(p) + 1), h_words=2.0 * p, supersteps=2),
        "Routing": PhaseCost(h_words=float(n_max), supersteps=1),
        "Merging": PhaseCost(comp_ops=n_max * _lg(max(n_max, 2))),  # local sort
        "Termination": PhaseCost(comp_ops=1.0),
    }
    return costs


_PHASES = {"det": phase_costs_det, "iran": phase_costs_iran, "ran": phase_costs_ran}


@dataclasses.dataclass
class Prediction:
    seconds_total: float
    seconds_comp: float
    seconds_comm: float
    pi: float
    mu: float
    efficiency: float
    speedup: float
    per_phase: Dict[str, float]


def predict(cfg: SortConfig, machine: BSPMachine) -> Prediction:
    """Price a sort under the BSP model; compare against sequential n·lg n."""
    costs = _PHASES[cfg.algorithm](cfg)
    per_phase = {k: c.seconds(machine) for k, c in costs.items()}
    comp = sum(c.comp_ops for c in costs.values()) * machine.t_comp
    comm = sum(
        max(machine.g * c.h_words, machine.L * c.supersteps)
        for c in costs.values()
        if c.h_words or c.supersteps
    )
    seq = cfg.n * _lg(cfg.n) * machine.t_comp  # best sequential comparison sort
    pi = cfg.p * comp / seq
    mu = cfg.p * comm / seq
    eff = 1.0 / (pi + mu)
    return Prediction(
        seconds_total=comp + comm,
        seconds_comp=comp,
        seconds_comm=comm,
        pi=pi,
        mu=mu,
        efficiency=eff,
        speedup=cfg.p * eff,
        per_phase=per_phase,
    )


def theoretical_max_imbalance(cfg: SortConfig) -> float:
    """Paper §6.4: det ≈ 1/⌈lg lg n⌉, ran ≈ 1/sqrt(lg n) (≈20% at n=2^23)."""
    if cfg.algorithm == "det":
        return 1.0 / max(1, math.ceil(log2(log2(cfg.n))))
    return 1.0 / math.sqrt(log2(cfg.n))
