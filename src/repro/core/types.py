"""Core datatypes and layout conventions for the BSP sorting library.

Layout conventions
------------------
A *distributed sequence* of ``n = p * n_per_proc`` keys is represented as:

* global layout: an array of shape ``(p, n_per_proc)`` (row k = processor k's
  local run, mirroring the paper's ``X^<k>`` notation);
* SPMD layout (inside an ``axis_name`` region): a local array ``(n_per_proc,)``.

Phase outputs that are variable-sized in the paper (the routed buckets, the
merged result) are *capacity-padded*: a pair ``(buf, count)`` where
``buf[:count]`` holds valid keys and ``buf[count:]`` holds the dtype sentinel.
The capacity is the paper's deterministic receive bound (Lemma 5.1) for the
deterministic algorithm and the Claim 5.1 w.h.p. bound for the randomized
algorithm — this static bound is exactly what makes the BSP h-relation
expressible as fixed-shape XLA collectives (see DESIGN.md §3).

Stability/padding invariant
---------------------------
Pads always occupy a suffix of every buffer, every sort is stable
(``lax.sort(..., is_stable=True)``), and routing/merging preserve
(source processor, local index) order for equal keys. Hence ``buf[:count]``
is exact even when real keys equal the sentinel value, and the paper's
transparent duplicate handling (§5.1.1) carries over with only the o(n)
sample/splitter tagging.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

#: Default collective axis name used by the simulated (vmap) runner.
AXIS = "bsp"


def sentinel_for(dtype) -> jnp.ndarray:
    """Largest representable value of ``dtype`` — used as tail padding."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def log2(x: float) -> float:
    return math.log2(max(x, 2.0))


@dataclasses.dataclass(frozen=True)
class SortConfig:
    """Static configuration of one BSP sort instance.

    Mirrors the tunables of the paper's implementations:

    * ``omega`` — the oversampling regulator ω_n. Paper defaults (§6.1):
      deterministic ω_n = lg lg n, randomized ω_n = sqrt(lg n).
    * ``local_sort`` — Ph2 sequential method: ``lax`` (stable comparison sort —
      the [·SQ]/quicksort variants), ``radix`` (counting-split — the [·SR]
      variants), or ``bitonic`` (Pallas in-VMEM sorting network).
    * ``merge`` — Ph6 method: ``sort`` (stable re-sort of the routed buffer)
      or ``tree`` (lg p rounds of stable pairwise rank-merges; payload
      arrays ride the same rank scatter, so key-value sorts take it too).
    * ``merge_backend`` — Ph6 ``tree`` substrate: ``xla`` (jnp.searchsorted
      ranks) or ``pallas`` (the ``kernels/searchsorted`` masked-count rank
      kernel, and the ``kernels/merge_path`` partitioned network merge for
      key-only pairs — interpret mode on CPU CI, real kernels on TPU).
    * ``routing`` — Ph5 schedule: ``a2a_dense`` (single all_to_all over a
      (p, pair_cap) buffer), ``allgather`` (reference; g·n volume), or
      ``ring`` (p-1 ppermute supersteps, n_per_proc-sized visitor buffer).
    * ``exchange`` — Ph5 payload packing: ``fused`` packs key + payload rows
      into ONE byte buffer so every data superstep issues exactly one
      collective regardless of payload count; ``per_array`` keeps the
      one-collective-per-array layout (comparison baseline).
    * ``sample_sort`` — Ph3 parallel sample sorting: ``gather`` (all_gather +
      fused local sort; optimal when p·s fits one core) or ``bitonic``
      (distributed Batcher compare-split, the paper's [BSI]-based scheme).
    """

    p: int
    n_per_proc: int
    algorithm: str = "det"  # det | iran | ran | bitonic
    # Distribution route: "sample" (Ph3 splitters from oversampling — the
    # paper's schemes) or "radix" (count-then-distribute: one counting pass
    # over the locally sorted run yields exact per-destination boundaries,
    # so Ph3 is skipped entirely, capacity is known before any data moves,
    # and the tier ladder collapses to a single rung with zero retries).
    route: str = "sample"
    omega: Optional[float] = None
    local_sort: str = "lax"
    merge: str = "sort"
    # Ph6 tree-tail substrate: "xla" | "pallas" (see class docstring).
    merge_backend: str = "xla"
    routing: str = "a2a_dense"
    # Ph5 exchange layout: "fused" (one collective per data superstep) |
    # "per_array" (one collective per array — comparison baseline).
    exchange: str = "fused"
    sample_sort: str = "gather"
    capacity_factor: float = 1.0
    pad_align: int = 8
    # pair capacity mode for a2a_dense: "exact" (= n_per_proc, distribution
    # independent), "whp" (Chernoff-scale n/p^2 bound; production setting,
    # overflow detected & surfaced as a retriable fault), or "planned" (a
    # host-computed bound carried in ``pair_cap_override`` — the capacity
    # planner's segment-aware w.h.p. bound for fused multi-segment batches,
    # see repro.planner.capacity).
    pair_capacity: str = "exact"
    # pair_capacity="planned": the per-(src,dst) capacity the planner solved
    # for (keys, pre-alignment). Tier-only — normalised away by
    # ``prepare_key`` like the other capacity fields.
    pair_cap_override: Optional[int] = None
    # receive-buffer sizing: "bound" (Lemma/Claim 5.1 × capacity_factor) or
    # "full" (= n — nothing can ever overflow; the ladder's terminal tier).
    n_max_mode: str = "bound"
    # route="radix": the exact receive bound the launch driver host-computed
    # from the counted per-destination totals (keys, pre-alignment).
    # Tier-only — normalised away by ``prepare_key`` like the capacity
    # fields. Overrides the Lemma/Claim 5.1 formula when set.
    n_max_override: Optional[int] = None
    seed: int = 0
    # Observability handle (repro.obs.Tracer or None). Host-side only: the
    # drivers read it at launch/wait boundaries, traced code never sees it.
    # compare=False keeps it out of the generated __eq__/__hash__, so a
    # traced and an untraced config are EQUAL — they share executor-registry
    # entries and compiled programs (the "obs must not change compiled
    # programs" invariant, asserted by tests/test_obs.py).
    obs: Optional[object] = dataclasses.field(
        default=None, compare=False, repr=False
    )
    # Chaos handle (repro.chaos.FaultPlan or None), hash/compare-excluded
    # for the same reason as ``obs``: a faulted and a clean config are
    # EQUAL and share compiled programs — every injection is a host-side
    # decision at a driver boundary, never a traced-code branch.
    chaos: Optional[object] = dataclasses.field(
        default=None, compare=False, repr=False
    )

    # ------------------------------------------------------------------ math
    @property
    def n(self) -> int:
        return self.p * self.n_per_proc

    @property
    def omega_eff(self) -> float:
        if self.omega is not None:
            return float(self.omega)
        if self.algorithm == "det":
            # paper §6.1: omega_n = lg lg n
            return max(1.0, math.ceil(log2(log2(self.n))))
        # randomized: omega_n^2 = lg n
        return max(1.0, math.sqrt(log2(self.n)))

    @property
    def r(self) -> int:
        """⌈ω_n⌉ — regular-oversampling ratio (deterministic algorithm)."""
        return max(1, math.ceil(self.omega_eff))

    @property
    def s(self) -> int:
        """Per-processor sample size.

        det: s = ⌈ω_n⌉·p (rp-1 evenly spaced keys + the local max, Fig. 1
        step 4). iran/ran: s = 2·ω_n²·lg n (Fig. 2/3 step 1).
        """
        if self.algorithm == "det":
            return self.r * self.p
        return max(2, int(2 * self.omega_eff**2 * log2(self.n)))

    @property
    def segment_len(self) -> int:
        """x = ⌈⌈n/p⌉ / s⌉ — regular sample segment length (Lemma 5.1 proof)."""
        return -(-self.n_per_proc // self.s)

    @property
    def n_max(self) -> int:
        """Receive-side bound per processor.

        det: exact bound from the Lemma 5.1 proof, b_{i+1}-b_i ≤ (s+p-1)·x
        (equivalently (1+1/⌈ω⌉)·n/p + ⌈ω⌉·p up to padding).
        iran/ran: Claim 5.1 w.h.p. bound (1+1/ω)·n/p, plus an ω·p slack term
        absorbing splitter granularity. ``n_max_mode="full"`` overrides both
        with n itself — an adversary cannot overflow a buffer that holds the
        whole input (the escalation ladder's terminal tier).
        """
        if self.n_max_mode == "full":
            return round_up(self.n, self.pad_align)
        if self.n_max_override is not None:
            # exact host-counted receive total (radix route) — no
            # capacity_factor: the count is a bound, not an estimate.
            return min(
                round_up(self.n_max_override, self.pad_align),
                max(self.n, self.pad_align),
            )
        if self.algorithm == "det":
            bound = (self.s + self.p - 1) * self.segment_len
        else:
            bound = int((1.0 + 1.0 / self.omega_eff) * self.n_per_proc) + int(
                self.omega_eff * self.p
            )
        bound = int(math.ceil(bound * self.capacity_factor))
        return min(round_up(bound, self.pad_align), max(self.n, self.pad_align))

    @property
    def pair_cap(self) -> int:
        """Per-(src,dst) capacity for the dense all_to_all schedule."""
        if self.pair_capacity == "exact":
            return round_up(self.n_per_proc, self.pad_align)
        if self.pair_capacity == "planned":
            # host-solved segment-aware bound (repro.planner.capacity);
            # capacity_factor carries the ladder's ×2 escalation.
            cap = int(math.ceil(self.pair_cap_override * self.capacity_factor))
        else:
            # w.h.p. bound: n/p^2 bucket share, (1+1/ω) expansion, +ω·p slack.
            cap = int(
                (1.0 + 1.0 / self.omega_eff) * (self.n_per_proc / self.p)
                + self.omega_eff * self.p
            )
            cap = int(math.ceil(cap * self.capacity_factor))
        return min(round_up(max(cap, self.pad_align), self.pad_align), round_up(self.n_per_proc, self.pad_align))

    # ------------------------------------------------------ capacity ladder
    def tier_ladder(self) -> tuple:
        """Capacity-escalation ladder for the overflow-safe driver.

        ``((name, SortConfig), ...)`` ordered cheapest-first:

        * ``whp``       — the configured w.h.p. pair capacity (Claim 5.1);
          or ``planned`` — the planner's segment-aware bound
          (``pair_cap_override``; repro.planner.capacity);
        * ``whp2``/``planned2`` — the same bound Chernoff-scaled ×2 (squares
          the already-polynomially-small failure probability);
        * ``exact``     — pair_cap = n/p, receive side at the Lemma 5.1 /
          Claim 5.1 bound — distribution independent for ``det``;
        * ``allgather`` — reference schedule with a full-size (n) receive
          buffer: no input, however adversarial, can overflow it.

        Tiers below the configured starting point are omitted, so a config
        that already starts exact gets the two-rung ladder exact→allgather.
        ``bitonic`` is always perfectly balanced (n/p keys per proc at every
        superstep) and needs no ladder at all.
        """
        if self.algorithm == "bitonic":
            return (("exact", self),)
        if self.route == "radix":
            # Count-then-distribute: capacity is KNOWN before sending, so the
            # ladder is one rung by construction. With a host-counted bound
            # (pair_cap_override + n_max_override, set by the launch driver
            # after reading the prepared boundaries) the rung runs at the
            # exact counted capacity; without one — direct calls that never
            # host-sync — it runs at pair_cap = n/p with a full receive
            # buffer, which no send pattern can overflow either way.
            if self.pair_capacity == "planned" and self.pair_cap_override:
                return (("radix", self),)
            return (
                (
                    "radix",
                    dataclasses.replace(
                        self,
                        pair_capacity="exact",
                        pair_cap_override=None,
                        n_max_mode="full",
                        n_max_override=None,
                    ),
                ),
            )
        tiers = []
        if (
            self.routing == "a2a_dense"
            and self.pair_capacity in ("whp", "planned")
            and self.n_max_mode == "bound"
        ):
            tiers.append((self.pair_capacity, self))
            tiers.append(
                (
                    self.pair_capacity + "2",
                    dataclasses.replace(self, capacity_factor=2.0 * self.capacity_factor),
                )
            )
        if not (self.routing == "allgather" and self.n_max_mode == "full"):
            # drop the override so two ladders that differ only in their
            # planned bound share ONE compiled exact/allgather rung
            tiers.append(
                (
                    "exact",
                    dataclasses.replace(
                        self, pair_capacity="exact", pair_cap_override=None
                    ),
                )
            )
        tiers.append(
            (
                "allgather",
                dataclasses.replace(
                    self,
                    routing="allgather",
                    pair_capacity="exact",
                    pair_cap_override=None,
                    n_max_mode="full",
                ),
            )
        )
        return tuple(tiers)

    def prepare_key(self) -> "SortConfig":
        """Config with the tier-varying fields normalised away.

        The capacity ladder (``tier_ladder``) only ever varies
        ``capacity_factor``, ``pair_capacity``, ``routing`` and
        ``n_max_mode`` — none of which enter the prepare stage (Ph2 local
        sort, and for ``det`` the Ph3 sample/splitters). Two configs with
        equal ``prepare_key()`` therefore share one compiled prepare
        callable and one :class:`PreparedSort`, which is what lets the
        escalation driver re-enter only the route stage per rung.
        ``merge``/``merge_backend`` (Ph6) and ``exchange`` (the Ph5 payload
        packing) are also normalised: they only affect the route stage
        but not the prepared state. ``omega`` is normalised for every
        algorithm except ``det`` (whose prepare includes the Ph3
        sample/splitter computation): iran/ran draw their sample inside the
        route stage and bitonic has no sample, so the prepare callable is
        omega-independent there — which lets the capacity planner tune the
        oversampling ratio per batch without retracing prepare.
        """
        return dataclasses.replace(
            self,
            capacity_factor=1.0,
            pair_capacity="exact",
            pair_cap_override=None,
            routing="a2a_dense",
            n_max_mode="bound",
            n_max_override=None,
            merge="sort",
            merge_backend="xla",
            exchange="fused",
            # radix prepare is a counting pass — no Ph3 sample, so it is
            # omega-independent even for det.
            omega=self.omega
            if (self.algorithm == "det" and self.route == "sample")
            else None,
            # hash-excluded anyway, but dropped so executor-registry keys
            # never pin a Tracer (and its span buffers) for process lifetime
            obs=None,
            chaos=None,
        )

    def validate(self) -> None:
        if self.p & (self.p - 1):
            raise ValueError(f"p must be a power of two for bitonic stages, got {self.p}")
        if self.algorithm not in ("det", "iran", "ran", "bitonic"):
            raise ValueError(f"unknown algorithm {self.algorithm!r}")
        if self.n_per_proc < 1:
            raise ValueError("n_per_proc must be >= 1")
        if self.n_max_mode not in ("bound", "full"):
            raise ValueError(f"unknown n_max_mode {self.n_max_mode!r}")
        if self.pair_capacity not in ("exact", "whp", "planned"):
            raise ValueError(f"unknown pair_capacity {self.pair_capacity!r}")
        if self.merge not in ("sort", "tree"):
            raise ValueError(f"unknown merge {self.merge!r}")
        if self.exchange not in ("fused", "per_array"):
            raise ValueError(f"unknown exchange {self.exchange!r}")
        if self.merge_backend not in ("xla", "pallas"):
            raise ValueError(f"unknown merge_backend {self.merge_backend!r}")
        if self.pair_capacity == "planned" and not self.pair_cap_override:
            raise ValueError("pair_capacity='planned' needs pair_cap_override")
        if self.route not in ("sample", "radix"):
            raise ValueError(f"unknown route {self.route!r}")
        if self.route == "radix":
            if self.algorithm == "bitonic":
                raise ValueError("route='radix' does not apply to bitonic")
            if self.routing != "a2a_dense":
                raise ValueError(
                    "route='radix' requires routing='a2a_dense' "
                    f"(got {self.routing!r})"
                )


@dataclasses.dataclass
class SortResult:
    """Per-processor capacity-padded result of a distributed sort."""

    buf: jnp.ndarray  # (p, cap) global layout or (cap,) SPMD layout
    count: jnp.ndarray  # (p,) or scalar — valid prefix length
    overflow: jnp.ndarray  # bool — any capacity violated (retriable fault)


@dataclasses.dataclass
class PreparedSort:
    """Tier-invariant state of a sort, reusable across capacity-tier retries.

    Invariants (what makes escalation sound):

    * Every field is identical for every rung of ``cfg.tier_ladder()``: the
      ladder only varies capacity/routing fields, which enter the pipeline
      strictly after this state is built (see ``SortConfig.prepare_key``).
      The escalation driver therefore builds a ``PreparedSort`` once and
      re-enters only the route stage per rung.
    * ``xs`` is the *stable* local sort of the input run for ``det``/``iran``
      (Ph2), and the untouched input run for ``ran``/``bitonic`` (classic
      sample sort samples the raw run and local-sorts last). ``vals`` carry
      the same permutation, so key-value payloads survive retries.
    * ``splits`` is populated only for ``det``: regular oversampling and the
      Lemma 5.1 splitter selection are deterministic and rank-only, hence
      tier-invariant. For ``iran``/``ran`` the sample is *redrawn inside the
      route stage* from a per-tier folded rng — a retry must be an
      independent splitter trial, so the random Ph3 is deliberately NOT
      carried here.
    * Duplicate-key tagging stays transparent (§5.1.1): only the o(n)
      sample/splitter records in ``splits`` carry (proc, idx) tags; ``xs``
      keys rely on their implicit position, which the stable Ph2 sort fixed
      once and for all — no per-tier re-tagging is ever needed.

    Layout matches the runner that built it: global ``(p, n_per_proc)``
    leading dims from the drivers, bare SPMD shapes inside an axis region.
    """

    xs: jnp.ndarray  # local run (sorted for det/iran, raw for ran/bitonic)
    vals: Tuple[jnp.ndarray, ...]  # payloads permuted like xs
    # det: tagged (keys, procs, idxs) splitters.
    # route="radix": a 1-tuple holding the counted (p+1,) bucket boundaries
    # of the local run — exact, tier-invariant, and host-readable, which is
    # what lets the launch driver size the single rung to the true counts.
    splits: Optional[tuple]


jax.tree_util.register_pytree_node(
    PreparedSort,
    lambda prep: ((prep.xs, prep.vals, prep.splits), None),
    lambda _, children: PreparedSort(*children),
)
