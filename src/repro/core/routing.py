"""Key routing (Fig. 1 steps 10-11) — the single balanced h-relation.

On the Cray T3D this superstep is a ragged BSPlib ``bsp_put`` h-relation of
cost g·n_max. XLA collectives are fixed-shape, so we rely on the paper's own
theory to make the port sound: Lemma 5.1 (det) / Claim 5.1 (randomized) bound
the receive side at compile time, giving a static capacity ``cap = n_max``.

Three schedules (DESIGN.md §3):

* ``a2a_dense`` — one ``lax.all_to_all`` over a (p, pair_cap) send buffer.
  ``pair_cap`` is per-(src,dst): ``exact`` mode uses n/p (distribution
  independent — an adversarial input can aim a whole local run at one
  bucket); ``whp`` mode uses the Chernoff-scale (n/p²)(1+1/ω)+ω·p bound that
  holds w.h.p. for the randomized algorithm — overflow is *detected* (pmax of
  counts) and surfaced as a retriable fault, since a sort may not drop keys.
* ``allgather`` — reference schedule; every proc gathers all runs and slices
  its bucket. Volume g·n but one superstep and always exact.
* ``ring`` — p-1 ``ppermute`` supersteps rotating an n/p-word visitor block;
  exact, memory O(n/p), the literal BSP superstep structure.

Fused exchange (``SortConfig.exchange``)
----------------------------------------
The paper's Ph5 is ONE h-relation superstep; a key-value sort must not pay
one collective per array. Under ``exchange="fused"`` (the default) the key
and every payload row are bitcast to bytes and concatenated along the
trailing dim into a single send buffer, so each data superstep issues
exactly ONE collective regardless of payload count — one ``all_to_all`` for
``a2a_dense`` (plus the tiny (p,)-word Ph4 count bookkeeping superstep), one
``all_gather`` for ``allgather`` (plus the boundary bookkeeping gather), and
one ``ppermute`` per ring superstep (visitor arrays AND the rotating
boundary vector share the packed buffer). The buffer is unpacked (bitcast
back) after delivery; packing is bit-exact, so the fused path is
byte-identical to ``exchange="per_array"`` (the one-collective-per-array
layout, kept as the measured baseline — see the ``hotpath`` benchmark
table). The pack/unpack helpers (:func:`pack_bytes` / :func:`unpack_bytes`)
are shared with the MoE EP dispatch (models/moe.py).

All schedules preserve source order: the receive buffer is compacted by
(source proc, local index), which is what makes the final merge stable and
the §5.1.1 duplicate handling free.

Capacity-tier ladder & retry semantics
--------------------------------------
A sort may never drop keys, but every fixed-shape schedule above has a
static capacity an adversarial input can exceed. Overflow is therefore
*detected* here (pmax of send/receive counts vs pair_cap / n_max), carried
out of the collective region as the ``overflow`` flag, and treated by the
host-side driver (``api.bsp_sort_safe`` / ``api.bsp_sort_sharded_safe``) as
a retriable fault: the driver re-runs the jitted sort at the next rung of
``SortConfig.tier_ladder()`` —

    whp        Claim 5.1 w.h.p. pair capacity (production default)
    whp2       the same bound Chernoff-scaled ×2
    exact      pair_cap = n/p; Lemma 5.1 receive bound (det: a priori safe)
    allgather  reference schedule, full-size (n) receive buffer — cannot
               overflow for any input, so the ladder always terminates

On a clean flag the partially-filled buffers of the failed attempt are
discarded (nothing was written back), so retries are idempotent; per-tier
attempt counters (``api.TierStats``) surface how often the cheap tier
actually sufficed per workload. A retry re-enters the pipeline *here* (the
route stage), not at Ph2: the driver reuses the tier-invariant
``PreparedSort`` (local sort + det splitters) and only re-runs
Ph3b..Ph6 per rung — see ``api.SortExecutor``.

Values (payload arrays with leading dim n_p) ride along with the keys — this
is the key-value form used by MoE token dispatch (models/moe.py) and the
segmented SortService composites. With ``merge="tree"`` they also ride the
rank-merge tail (:func:`route_and_merge`): rank positions are computed once
on the keys and applied to every payload, so key-value callers skip the
``compact_rows`` scatter + full re-sort entirely.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

import jax.numpy as jnp
from jax import lax

from . import merge as merge_mod
from . import primitives as prim
from .types import SortConfig, sentinel_for


def _pad_value_for(arr: jnp.ndarray) -> jnp.ndarray:
    return jnp.zeros((), arr.dtype)


# ------------------------------------------------------ fused byte packing
def _nbytes(dtype, trail) -> int:
    return int(np.prod(trail, dtype=np.int64)) * jnp.dtype(dtype).itemsize


def pack_bytes(
    arrs: Sequence[jnp.ndarray], lead: int = 2
) -> Tuple[jnp.ndarray, tuple]:
    """Bitcast arrays sharing ``lead`` leading dims into ONE uint8 buffer.

    Each (l0, .., l_{lead-1}, ...) array contributes its trailing dims as a
    flat byte run along a new last axis; the concatenation is the single
    send buffer of a fused collective. Returns ``(buffer, metas)`` where
    ``metas`` is the static recipe :func:`unpack_bytes` inverts bit-exactly.
    """
    parts, metas = [], []
    for a in arrs:
        b = lax.bitcast_convert_type(a, jnp.uint8)
        parts.append(b.reshape(a.shape[:lead] + (-1,)))
        metas.append((a.dtype, a.shape[lead:]))
    return jnp.concatenate(parts, axis=-1), tuple(metas)


def unpack_bytes(
    buf: jnp.ndarray, metas: tuple, lead: int = 2
) -> List[jnp.ndarray]:
    """Invert :func:`pack_bytes` after delivery (bit-exact)."""
    out, off = [], 0
    head = buf.shape[:lead]
    for dtype, trail in metas:
        dtype = jnp.dtype(dtype)
        nb = _nbytes(dtype, trail)
        b = buf[..., off : off + nb]
        off += nb
        shape = head + tuple(trail)
        if dtype.itemsize > 1:
            shape = shape + (dtype.itemsize,)
        out.append(lax.bitcast_convert_type(b.reshape(shape), dtype))
    return out


def pack_bytes_flat(arrs: Sequence[jnp.ndarray]) -> Tuple[jnp.ndarray, tuple]:
    """Pack arbitrarily-shaped arrays into one flat uint8 vector.

    The ring schedule's visitor block (local run + payloads + the (p+1,)
    boundary vector) has mixed shapes; a flat byte vector lets the whole
    block rotate in ONE ``ppermute`` per superstep.
    """
    parts, metas = [], []
    for a in arrs:
        parts.append(lax.bitcast_convert_type(a, jnp.uint8).reshape(-1))
        metas.append((a.dtype, a.shape))
    return jnp.concatenate(parts), tuple(metas)


def unpack_bytes_flat(vec: jnp.ndarray, metas: tuple) -> List[jnp.ndarray]:
    """Invert :func:`pack_bytes_flat` (bit-exact)."""
    out, off = [], 0
    for dtype, shape in metas:
        dtype = jnp.dtype(dtype)
        nb = _nbytes(dtype, shape)
        b = vec[off : off + nb]
        off += nb
        full = tuple(shape)
        if dtype.itemsize > 1:
            full = full + (dtype.itemsize,)
        out.append(lax.bitcast_convert_type(b.reshape(full), dtype))
    return out


def send_counts(boundaries: jnp.ndarray) -> jnp.ndarray:
    """(p,) keys this proc sends to each destination."""
    return jnp.diff(boundaries)


# ---------------------------------------------- host-side observability math
def packed_row_bytes(key_dtype, value_dtypes=()) -> int:
    """Bytes one routed row carries in the fused exchange (key + payloads).

    Pure host math for the tracer: the fused Ph5 collective moves
    byte-packed (key, payload...) rows, so a traced h-relation's byte
    volume is ``counts × packed_row_bytes`` and its BSP h (32-bit words,
    the paper's unit) is that over 4.
    """
    return int(sum(np.dtype(d).itemsize for d in (key_dtype, *value_dtypes)))


def route_supersteps(routing: str, p: int) -> int:
    """Data supersteps one route-stage execution issues under ``routing``.

    The tracer charges each route span ``supersteps × L`` in the (g, L)
    fit: ``a2a_dense`` is the (p,)-word count bookkeeping all_to_all plus
    ONE fused data all_to_all (see :func:`recv_rows`); ``allgather`` is a
    single fused all_gather; ``ring`` is p−1 ppermute visitor supersteps.
    """
    if routing == "a2a_dense":
        return 2
    if routing == "allgather":
        return 1
    if routing == "ring":
        return max(1, p - 1)
    raise ValueError(f"unknown routing {routing!r}")


def recv_counts(counts: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Transpose the (implicit) p×p count matrix: r[j] = counts_on_proc_j[me].

    One all_to_all of p words — the Ph4 prefix bookkeeping superstep.
    """
    return lax.all_to_all(counts.reshape(-1, 1), axis, 0, 0).reshape(-1)


def _segment_rows(
    arrs: Sequence[jnp.ndarray],
    boundaries: jnp.ndarray,
    counts: jnp.ndarray,
    width: int,
    key_sentinel: jnp.ndarray,
) -> List[jnp.ndarray]:
    """Slice the local run into p destination rows of static width.

    rows[i, t] = arr[b[i] + t] for t < c_i else pad — one gather per array.
    """
    n_p = arrs[0].shape[0]
    t = jnp.arange(width)[None, :]
    idx = jnp.clip(boundaries[:-1][:, None] + t, 0, n_p - 1)
    valid = t < counts[:, None]
    rows = []
    for i, a in enumerate(arrs):
        g = a[idx]  # (p, width, ...)
        fill = key_sentinel if i == 0 else _pad_value_for(a)
        mask = valid.reshape(valid.shape + (1,) * (g.ndim - 2))
        rows.append(jnp.where(mask, g, fill))
    return rows


def _all_to_all_rows(rows: List[jnp.ndarray], cfg: SortConfig, axis: str):
    """Deliver (p, w, ...) rows: ONE fused all_to_all, or one per array."""
    if cfg.exchange == "fused" and len(rows) > 1:
        buf, metas = pack_bytes(rows, lead=2)
        return unpack_bytes(lax.all_to_all(buf, axis, 0, 0), metas, lead=2)
    return [lax.all_to_all(r, axis, 0, 0) for r in rows]


def recv_rows(
    x_sorted: jnp.ndarray,
    boundaries: jnp.ndarray,
    cfg: SortConfig,
    axis: str,
    values: Sequence[jnp.ndarray] = (),
) -> Tuple[List[jnp.ndarray], jnp.ndarray, jnp.ndarray]:
    """Deliver bucket ``me`` of every source as padded rows.

    Returns ``(rows, rcounts, overflow)`` where rows[a] has shape
    (p, width, ...): row j = the run received from source j (sorted, padded),
    rcounts[j] its valid length. Width = pair_cap (a2a_dense) or n_p
    (allgather).
    """
    sent = sentinel_for(x_sorted.dtype)
    counts = send_counts(boundaries)
    arrs = [x_sorted, *values]

    if cfg.routing == "a2a_dense":
        pair_cap = cfg.pair_cap
        rcounts = recv_counts(counts, axis)
        over = (jnp.any(counts > pair_cap) | (rcounts.sum() > cfg.n_max)).astype(
            jnp.int32
        )
        overflow = lax.pmax(over, axis) > 0
        rows = _segment_rows(arrs, boundaries, counts, pair_cap, sent)
        rows = _all_to_all_rows(rows, cfg, axis)
        return rows, rcounts, overflow

    if cfg.routing == "allgather":
        me = prim.proc_id(axis)
        b_all = lax.all_gather(boundaries, axis)  # (p, p+1) — bookkeeping
        starts = b_all[:, me]
        rcounts = b_all[:, me + 1] - starts
        n_p = x_sorted.shape[0]
        t = jnp.arange(n_p)[None, :]
        idx = jnp.clip(starts[:, None] + t, 0, n_p - 1)
        valid = t < rcounts[:, None]
        if cfg.exchange == "fused" and len(arrs) > 1:
            buf, metas = pack_bytes(arrs, lead=1)
            gathered = unpack_bytes(lax.all_gather(buf, axis), metas, lead=2)
        else:
            gathered = [lax.all_gather(a, axis) for a in arrs]  # (p, n_p, ...)
        rows = []
        for i, a_all in enumerate(gathered):
            g = jnp.take_along_axis(
                a_all, idx.reshape(idx.shape + (1,) * (a_all.ndim - 2)), axis=1
            )
            fill = sent if i == 0 else _pad_value_for(arrs[i])
            mask = valid.reshape(valid.shape + (1,) * (g.ndim - 2))
            rows.append(jnp.where(mask, g, fill))
        over = (rcounts.sum() > cfg.n_max).astype(jnp.int32)
        overflow = lax.pmax(over, axis) > 0
        return rows, rcounts, overflow

    raise ValueError(f"recv_rows: unsupported routing {cfg.routing!r}")


def compact_rows(
    rows: Sequence[jnp.ndarray],
    rcounts: jnp.ndarray,
    cap: int,
    key_sentinel: jnp.ndarray,
) -> List[jnp.ndarray]:
    """Scatter (p, w, ...) rows into a (cap, ...) buffer ordered by source.

    Row j's first r_j entries land at offsets[j]..; the rest are dropped
    (index == cap with mode='drop'). Pads end at the tail.
    """
    offsets = prim.exclusive_cumsum(rcounts)
    p, w = rows[0].shape[:2]
    t = jnp.arange(w)[None, :]
    valid = t < rcounts[:, None]
    idx = jnp.where(valid, offsets[:, None] + t, cap).reshape(-1)
    out = []
    for i, r in enumerate(rows):
        fill = key_sentinel if i == 0 else _pad_value_for(r)
        buf = jnp.full((cap,) + r.shape[2:], fill, r.dtype)
        out.append(buf.at[idx].set(r.reshape((p * w,) + r.shape[2:]), mode="drop"))
    return out


def route(
    x_sorted: jnp.ndarray,
    boundaries: jnp.ndarray,
    cfg: SortConfig,
    axis: str,
    values: Sequence[jnp.ndarray] = (),
) -> Tuple[jnp.ndarray, List[jnp.ndarray], jnp.ndarray, jnp.ndarray]:
    """Route bucket i of every proc to proc i, compacted by source.

    Returns ``(buf, value_bufs, count, overflow)``: the (cap,) receive buffer
    ordered by (src, idx), its valid prefix length, and the capacity fault
    flag (retriable — the driver re-runs with the next capacity tier).
    """
    sent = sentinel_for(x_sorted.dtype)
    cap = cfg.n_max

    if cfg.routing == "ring":
        return _route_ring(x_sorted, boundaries, cfg, axis, values, sent)

    rows, rcounts, overflow = recv_rows(x_sorted, boundaries, cfg, axis, values)
    out = compact_rows(rows, rcounts, cap, sent)
    total = jnp.minimum(rcounts.sum(), cap)
    return out[0], out[1:], total, overflow


def _fit(arr: jnp.ndarray, cap: int, fill: jnp.ndarray) -> jnp.ndarray:
    """Slice or pad-extend the merged run to the (cap, ...) result shape.

    The tree tail's run length is p·width, which can undershoot ``n_max``
    for a planner-shrunk pair capacity — pad with ``fill`` so every tier
    returns the same result shape as the sort tail.
    """
    if arr.shape[0] >= cap:
        return arr[:cap]
    pad = jnp.full((cap - arr.shape[0],) + arr.shape[1:], fill, arr.dtype)
    return jnp.concatenate([arr, pad], axis=0)


def route_and_merge(
    x_sorted: jnp.ndarray,
    boundaries: jnp.ndarray,
    cfg: SortConfig,
    axis: str,
    values: Sequence[jnp.ndarray] = (),
) -> Tuple[jnp.ndarray, List[jnp.ndarray], jnp.ndarray, jnp.ndarray]:
    """Ph5 + Ph6 tail shared by det/iran: route, then stable merge.

    Requires bucket i of the local run (``x_sorted[b[i]:b[i+1]]``) to be
    sorted, so each received row is a sorted run — which is what makes the
    ``merge=tree`` rank-merge path valid (``ran`` routes dest-grouped, not
    key-sorted, rows and must keep its own sort-based tail). The tree tail
    is payload-generic: received rows (key + payloads) go straight into
    :func:`merge.merge_tree`, skipping the ``compact_rows`` scatter and the
    full O(n_max·lg²n_max) re-sort of the sort tail.
    """
    if cfg.merge == "tree" and cfg.routing != "ring":
        rows, rcounts, overflow = recv_rows(x_sorted, boundaries, cfg, axis, values)
        merged, mvals, count = merge_mod.merge_tree(
            rows[0], rcounts, values=rows[1:], backend=cfg.merge_backend,
            cap=cfg.n_max,
        )
        cap = cfg.n_max
        sent = sentinel_for(x_sorted.dtype)
        merged = _fit(merged, cap, sent)
        mvals = [_fit(v, cap, _pad_value_for(v)) for v in mvals]
        return merged, mvals, jnp.minimum(count, cap), overflow

    buf, vbufs, count, overflow = route(x_sorted, boundaries, cfg, axis, values)
    merged, mvals = merge_mod.merge_by_sort(buf, vbufs)
    return merged, mvals, count, overflow


def _route_ring(x_sorted, boundaries, cfg, axis, values, sent):
    """p-1 ppermute supersteps; visitor block = one local run + boundaries.

    Under ``exchange="fused"`` the whole visitor block (keys, payloads AND
    the boundary vector) rotates as one packed byte vector — one collective
    per superstep regardless of payload count.
    """
    p, cap = cfg.p, cfg.n_max
    n_p = x_sorted.shape[0]
    me = prim.proc_id(axis)
    arrs = [x_sorted, *values]

    counts = send_counts(boundaries)
    rcounts = recv_counts(counts, axis)
    offsets = prim.exclusive_cumsum(rcounts)
    total = rcounts.sum()
    overflow = lax.pmax((total > cap).astype(jnp.int32), axis) > 0

    bufs = []
    for i, a in enumerate(arrs):
        fill = sent if i == 0 else _pad_value_for(a)
        bufs.append(jnp.full((cap,) + a.shape[1:], fill, a.dtype))

    vis_arrs, vis_b = tuple(arrs), boundaries
    for r in range(p):  # r=0 places the local segment; then p-1 rotations
        src = (me - r) % p
        start = vis_b[me]
        cnt = vis_b[me + 1] - start
        t = jnp.arange(n_p)
        idx = jnp.clip(start + t, 0, n_p - 1)
        valid = t < cnt
        dst = jnp.where(valid, offsets[src] + t, cap)
        bufs = [
            buf.at[dst].set(a[idx], mode="drop") for buf, a in zip(bufs, vis_arrs)
        ]
        if r != p - 1:
            if cfg.exchange == "fused":
                vec, metas = pack_bytes_flat(list(vis_arrs) + [vis_b])
                vec = prim.ppermute_shift(vec, axis, 1, p=p)
                *vis_list, vis_b = unpack_bytes_flat(vec, metas)
                vis_arrs = tuple(vis_list)
            else:
                vis_arrs = prim.ppermute_shift(vis_arrs, axis, 1, p=p)
                vis_b = prim.ppermute_shift(vis_b, axis, 1, p=p)
    return bufs[0], bufs[1:], jnp.minimum(total, cap), overflow
