"""SORT_DET_BSP (Fig. 1) — deterministic regular-oversampling sample sort.

Phases (paper Tables 4-7 naming):
  Ph2 SeqSort  — stable local sort of the n/p-key run;
  Ph3 Sampling — regular oversampling (s = ⌈ω⌉·p evenly spaced keys + max),
                 parallel sample sort, splitter selection + broadcast;
  Ph4 Prefix   — tagged binary-search partition + count bookkeeping;
  Ph5 Routing  — the single balanced h-relation (cap = Lemma 5.1's n_max);
  Ph6 Merging  — stable multi-way merge of the received sorted runs.

Duplicate keys are handled transparently per §5.1.1: only the o(n) sample /
splitter records carry (proc, idx) tags; the partition comparator and every
sort/merge are stable, so the output is the stable sort of the input even
when *all* keys are equal — with no doubling of computation or communication.

The body is an explicit two-stage pipeline (``prepare`` → ``route``): Ph2/Ph3
are independent of the capacity tier (regular oversampling is deterministic
and rank-only), so the overflow-safe driver runs :func:`prepare_det_spmd`
once and re-enters :func:`route_det_spmd` per ladder rung.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import routing, splitters
from .local_sort import local_sort
from .types import PreparedSort, SortConfig


def prepare_det_spmd(
    x: jnp.ndarray,
    cfg: SortConfig,
    axis: str,
    values: Sequence[jnp.ndarray] = (),
    rng: jax.Array | None = None,  # unused; uniform pipeline signature
) -> PreparedSort:
    """Tier-invariant stages: Ph2 local sort + Ph3 sample/splitters."""
    del rng
    xs, vals = local_sort(x, cfg.local_sort, values)  # Ph2
    splits = splitters.splitter_stage(xs, cfg, axis)  # Ph3 (deterministic)
    return PreparedSort(xs=xs, vals=tuple(vals), splits=splits)


def route_det_spmd(
    prep: PreparedSort,
    cfg: SortConfig,
    axis: str,
    rng: jax.Array | None = None,  # unused; uniform pipeline signature
) -> Tuple[jnp.ndarray, List[jnp.ndarray], jnp.ndarray, jnp.ndarray]:
    """Tier-dependent stages: Ph4 partition, Ph5 routing, Ph6 merge."""
    del rng
    bounds = splitters.searchsorted_tagged(prep.xs, prep.splits, axis)  # Ph4
    return routing.route_and_merge(prep.xs, bounds, cfg, axis, list(prep.vals))


def sort_det_spmd(
    x: jnp.ndarray,
    cfg: SortConfig,
    axis: str,
    values: Sequence[jnp.ndarray] = (),
    rng: jax.Array | None = None,  # unused; uniform signature with iran
) -> Tuple[jnp.ndarray, List[jnp.ndarray], jnp.ndarray, jnp.ndarray]:
    return route_det_spmd(prepare_det_spmd(x, cfg, axis, values), cfg, axis)
