"""SORT_DET_BSP (Fig. 1) — deterministic regular-oversampling sample sort.

Phases (paper Tables 4-7 naming):
  Ph2 SeqSort  — stable local sort of the n/p-key run;
  Ph3 Sampling — regular oversampling (s = ⌈ω⌉·p evenly spaced keys + max),
                 parallel sample sort, splitter selection + broadcast;
  Ph4 Prefix   — tagged binary-search partition + count bookkeeping;
  Ph5 Routing  — the single balanced h-relation (cap = Lemma 5.1's n_max);
  Ph6 Merging  — stable multi-way merge of the received sorted runs.

Duplicate keys are handled transparently per §5.1.1: only the o(n) sample /
splitter records carry (proc, idx) tags; the partition comparator and every
sort/merge are stable, so the output is the stable sort of the input even
when *all* keys are equal — with no doubling of computation or communication.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import merge as merge_mod
from . import routing, splitters
from .local_sort import local_sort
from .types import SortConfig, sentinel_for


def sort_det_spmd(
    x: jnp.ndarray,
    cfg: SortConfig,
    axis: str,
    values: Sequence[jnp.ndarray] = (),
    rng: jax.Array | None = None,  # unused; uniform signature with iran
) -> Tuple[jnp.ndarray, List[jnp.ndarray], jnp.ndarray, jnp.ndarray]:
    del rng
    xs, vals = local_sort(x, cfg.local_sort, values)  # Ph2
    sample = splitters.regular_sample(xs, cfg, axis)  # Ph3
    splits = splitters.splitters_from_sorted_sample(cfg, sample, axis)
    bounds = splitters.searchsorted_tagged(xs, splits, axis)  # Ph4

    if cfg.merge == "tree" and not vals and cfg.routing != "ring":
        rows, rcounts, overflow = routing.recv_rows(xs, bounds, cfg, axis, vals)
        merged, count = merge_mod.merge_tree(rows[0], rcounts)
        merged = merged[: cfg.n_max]
        return merged, [], jnp.minimum(count, cfg.n_max), overflow

    buf, vbufs, count, overflow = routing.route(xs, bounds, cfg, axis, vals)  # Ph5
    merged, mvals = merge_mod.merge_by_sort(buf, vbufs)  # Ph6
    return merged, mvals, count, overflow
